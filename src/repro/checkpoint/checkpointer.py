"""Checkpoint/restart without orbax: per-leaf .npy shards + JSON manifest,
atomic directory commit, async background save, keep-N GC, and restore onto
a *different* mesh (leaves are saved as full host arrays and re-placed with
whatever shardings the new mesh dictates — elastic resume).

Layout:
    <dir>/step_000123.tmp/...   (during write)
    <dir>/step_000123/manifest.json
    <dir>/step_000123/leaf_00000.npy ...
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy cannot persist ml_dtypes types (.npy round-trips them as raw void):
# save as a same-width uint view and record the logical dtype in the manifest.
_EXOTIC = {
    "bfloat16": ml_dtypes.bfloat16,
    "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
    "float8_e5m2": ml_dtypes.float8_e5m2,
}
_UINT_OF_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save_pytree(tree: Any, path: str, step: int) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, paths, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (leaf, p) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        logical = str(arr.dtype)
        if logical in _EXOTIC:
            arr = arr.view(_UINT_OF_WIDTH[arr.dtype.itemsize])
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"path": p, "file": fname,
                                   "dtype": logical,
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)        # atomic commit
    return final


def restore_pytree(template: Any, path: str, step: Optional[int] = None,
                   shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `template`. If `shardings` is given
    (tree of NamedSharding), leaves are device_put with them — this is how a
    checkpoint from a 256-chip mesh resumes on a different mesh."""
    step_dir = latest_step_dir(path) if step is None else \
        os.path.join(path, f"step_{step:08d}")
    if step_dir is None or not os.path.isdir(step_dir):
        raise FileNotFoundError(f"no checkpoint under {path}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, paths, treedef = _flatten_with_paths(template)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    shard_leaves = jax.tree_util.tree_leaves(shardings) if shardings is not None \
        else [None] * len(leaves)
    for leaf, p, sh in zip(leaves, paths, shard_leaves):
        e = by_path[p]
        arr = np.load(os.path.join(step_dir, e["file"]))
        if e["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[e["dtype"]])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {p}: ckpt {arr.shape} vs "
                             f"template {leaf.shape}")
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr.astype(leaf.dtype)))
    return treedef.unflatten(out), manifest["step"]


def latest_step_dir(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(path, steps[-1]) if steps else None


class Checkpointer:
    """Async keep-N checkpointer."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def save(self, tree: Any, step: int, blocking: bool = False):
        self.wait()
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            save_pytree(host_tree, self.path, step)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        self.wait()
        return restore_pytree(template, self.path, step, shardings)

    def latest_step(self) -> Optional[int]:
        d = latest_step_dir(self.path)
        if d is None:
            return None
        return int(os.path.basename(d).split("_")[1])

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.path)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, d), ignore_errors=True)
