"""Public wrapper: full chunked SSD scan = Pallas intra-chunk kernel +
XLA inter-chunk recurrence (cheap (s x ph)-state scan over S/chunk steps).

Drop-in for ``repro.models.ssm.ssd_chunked`` (same signature/returns), which
together with ``ssd_scan.ref.ssd_chunked_ref`` (naive recurrence) forms its
two-level oracle chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_intra_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(X, dtv, A, Bh, Ch, chunk: int, init_state=None,
                       *, interpret: bool = False):
    """X: (B,S,nh,p); dtv: (B,S,nh); A: (nh,); Bh/Ch: (B,S,nh,s).

    Returns (y (B,S,nh,p) X.dtype, final_state (B,nh,s,p) f32)."""
    B_, S, nh, ph = X.shape
    s = Bh.shape[-1]
    nc = S // chunk

    # fold (B, nh) -> BH for a flat 2-D grid
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape((B_ * nh,) + t.shape[1:2] + t.shape[3:])
    Xf = fold(X)                                        # (BH, S, ph)
    dtf = jnp.moveaxis(dtv, 2, 1).reshape(B_ * nh, S)   # (BH, S)
    Bf, Cf = fold(Bh), fold(Ch)                         # (BH, S, s)
    Af = jnp.tile(A.astype(jnp.float32), B_)            # (BH,)

    Y_intra, S_chunk, expcum, chunk_decay = ssd_intra_chunk(
        Xf, dtf, Af, Bf, Cf, chunk=chunk, interpret=interpret)

    # ---- inter-chunk recurrence (XLA scan over nc steps) ----
    if init_state is None:
        init0 = jnp.zeros((B_ * nh, s, ph), jnp.float32)
    else:
        init0 = init_state.reshape(B_ * nh, s, ph).astype(jnp.float32)

    def step(carry, inp):
        dec, Sc = inp                                   # (BH,), (BH,s,ph)
        new = dec[:, None, None] * carry + Sc
        return new, carry

    final, S_prev = jax.lax.scan(
        step, init0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_chunk, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                 # (BH, nc, s, ph)

    # Y_inter[t] = expcum[t] * C[t] . S_prev[chunk(t)]
    Cc = Cf.reshape(B_ * nh, nc, chunk, s)
    Y_inter = jnp.einsum("ints,insp->intp", Cc * expcum.reshape(B_ * nh, nc, chunk)[..., None],
                         S_prev).reshape(B_ * nh, S, ph)

    y = Y_intra + Y_inter                               # (BH, S, ph)
    y = jnp.moveaxis(y.reshape(B_, nh, S, ph), 1, 2)    # (B, S, nh, ph)
    return y.astype(X.dtype), final.reshape(B_, nh, s, ph)
