from .ops import ssd_chunked_pallas
from .ref import ssd_chunked_ref
