"""Pure-jnp oracle for the chunked SSD scan: the naive O(S^2)-free sequential
recurrence, h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T ; y_t = C_t h_t.

Slow but unambiguous — the gold standard both the XLA chunked path
(models.ssm.ssd_chunked) and the Pallas kernel are tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked_ref(X, dtv, A, Bh, Ch, init_state=None):
    """X: (B,S,nh,p); dtv: (B,S,nh) (already softplus'd); A: (nh,) negative;
    Bh/Ch: (B,S,nh,s). Returns (y (B,S,nh,p) f32, final_state (B,nh,s,p))."""
    B_, S, nh, ph = X.shape
    s = Bh.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((B_, nh, s, ph), jnp.float32)

    Xf = X.astype(jnp.float32)
    dtf = dtv.astype(jnp.float32)
    Bf = Bh.astype(jnp.float32)
    Cf = Ch.astype(jnp.float32)

    def step(h, t):
        dec = jnp.exp(dtf[:, t] * A)                        # (B,nh)
        inc = jnp.einsum("bns,bnp,bn->bnsp", Bf[:, t], Xf[:, t], dtf[:, t])
        h = dec[:, :, None, None] * h + inc
        y = jnp.einsum("bns,bnsp->bnp", Cf[:, t], h)
        return h, y

    final, ys = jax.lax.scan(step, init_state, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), final
