"""Pallas TPU kernel: Mamba2 SSD intra-chunk scan (state-space duality).

TPU adaptation of the SSD algorithm (arXiv:2405.21060). The GPU reference
implementation leans on warp-level primitives for the intra-chunk cumsum;
on TPU we restate the whole intra-chunk computation as dense MXU matmuls
over (chunk x chunk) and (chunk x state) tiles held in VMEM:

    per (batch*head, chunk) grid step, with Q = chunk length:
      cum   = cumsum(dt * A)                       (Q,)      VPU
      M     = tril(exp(cum_i - cum_j))             (Q, Q)    VPU
      S     = (C @ B^T) * M                        (Q, Q)    MXU
      Yin   = S @ (dt * X)                         (Q, ph)   MXU
      Sc    = (B * dt * exp(cum_Q - cum))^T @ X    (s, ph)   MXU  (chunk state)

    outputs: Yin, Sc, exp(cum) and exp(cum_Q) — the cheap inter-chunk
    recurrence (a length-S/Q scan over (s, ph) states) and the Y_inter
    correction are XLA-side in ops.py.

VMEM budget per step (Q=256, s=128, ph=64, f32): X 64 KiB, B/C 128 KiB each,
M + S 256 KiB each — well under the ~16 MiB VMEM arena; all tile dims are
multiples of (8, 128) after the (Q, s/ph) layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            y_ref, state_ref, expcum_ref, decay_ref, *, chunk: int):
    A = a_ref[0]                                         # scalar for this head
    dt = dt_ref[0].astype(jnp.float32)                   # (Q,)
    l = dt * A                                           # (Q,) <= 0
    cum = jnp.cumsum(l)                                  # (Q,)

    X = x_ref[0].astype(jnp.float32)                     # (Q, ph)
    Bm = b_ref[0].astype(jnp.float32)                    # (Q, s)
    Cm = c_ref[0].astype(jnp.float32)                    # (Q, s)

    diff = cum[:, None] - cum[None, :]                   # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(cols <= rows, jnp.exp(diff), 0.0)      # causal decay mask

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * M
    dX = dt[:, None] * X                                 # (Q, ph)
    y_ref[0] = jax.lax.dot_general(scores, dX, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    decay_end = jnp.exp(cum[-1] - cum)                   # (Q,)
    Bw = Bm * (dt * decay_end)[:, None]                  # (Q, s)
    state_ref[0, 0] = jax.lax.dot_general(Bw, X, (((0,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32)
    expcum_ref[0] = jnp.exp(cum)
    decay_ref[0, 0] = jnp.exp(cum[-1])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk(X, dtv, A, Bh, Ch, *, chunk: int, interpret: bool = False):
    """X: (BH, S, ph); dtv: (BH, S); A: (BH,); Bh/Ch: (BH, S, s). S % chunk == 0.

    Returns (Y_intra (BH,S,ph) f32, S_chunk (BH,nc,s,ph) f32,
             expcum (BH,S) f32, chunk_decay (BH,nc) f32)."""
    BH, S, ph = X.shape
    s = Bh.shape[-1]
    assert S % chunk == 0
    nc = S // chunk

    grid = (BH, nc)
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, ph), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1,), lambda i, c: (i,)),
            pl.BlockSpec((1, chunk, s), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, ph), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, s, ph), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, chunk), lambda i, c: (i, c)),
            pl.BlockSpec((1, 1), lambda i, c: (i, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, ph), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, s, ph), jnp.float32),
            jax.ShapeDtypeStruct((BH, S), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="ssd_intra_chunk",
    )(X, dtv, A, Bh, Ch)
