"""Pallas API compat across the pinned jax 0.4.37 and newer releases.

Newer pallas renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(and made ``dimension_semantics`` & co. keyword-only along the way); kernel
modules must build their compiler params through this helper instead of
naming either class directly.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def tpu_compiler_params(**kwargs):
    """Construct the TPU compiler-params object under whichever name this
    pallas release exposes (``CompilerParams`` vs ``TPUCompilerParams``)."""
    return _CLS(**kwargs)
