"""Pallas TPU kernels for the compute hot-spots (each with ops.py wrapper and
ref.py pure-jnp oracle, validated via interpret=True on CPU):

* ``fused_update``    — the paper's per-push mechanism at LM scale: momentum
                        update (Eq. 1) + parameter step + gradient-gap norm
                        (Eq. 4) in ONE HBM pass.
* ``flash_attention`` — blocked causal online-softmax attention (GQA); makes
                        prefill_32k memory-feasible on TPU.
* ``ssd_scan``        — Mamba2 SSD intra-chunk scan as MXU matmuls; used by
                        the ssm/hybrid archs.
"""
from . import flash_attention, fused_update, ssd_scan
from .compat import tpu_compiler_params

__all__ = ["flash_attention", "fused_update", "ssd_scan",
           "tpu_compiler_params"]
