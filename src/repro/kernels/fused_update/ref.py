"""Pure-jnp oracle for the fused momentum + gap-norm update."""
from __future__ import annotations

import jax.numpy as jnp


def fused_update_flat_ref(theta, v, g, eta, beta):
    """theta/v/g: flat (or 2-D) f32 arrays.

    Returns (theta', v', sumsq):
        v'     = beta * v + (1 - beta) * g
        theta' = theta - eta * v'
        sumsq  = Sum(v'^2)
    """
    v_new = beta * v + (1.0 - beta) * g
    theta_new = theta - eta * v_new
    return theta_new, v_new, jnp.sum(jnp.square(v_new))
