"""Pure-jnp oracles for the fused momentum update and the server apply."""
from __future__ import annotations

import jax.numpy as jnp


def fused_update_flat_ref(theta, v, g, eta, beta):
    """theta/v/g: flat (or 2-D) f32 arrays.

    Returns (theta', v', sumsq):
        v'     = beta * v + (1 - beta) * g
        theta' = theta - eta * v'
        sumsq  = Sum(v'^2)
    """
    v_new = beta * v + (1.0 - beta) * g
    theta_new = theta - eta * v_new
    return theta_new, v_new, jnp.sum(jnp.square(v_new))


def fused_apply_flat_ref(cur, v, new, w, inv_eta, beta):
    """cur/v/new: flat (or 2-D) f32 arrays; the server push-apply contract
    (``AsyncParameterServer.push`` / ``serve.server._apply_shard``).

    Returns (mixed, v', sumsq):
        mixed = w * new + (1 - w) * cur
        s     = (cur - mixed) * inv_eta
        v'    = beta * v + (1 - beta) * s
        sumsq = Sum(v'^2)
    """
    mixed = w * new + (1.0 - w) * cur
    s = (cur - mixed) * inv_eta
    v_new = beta * v + (1.0 - beta) * s
    return mixed, v_new, jnp.sum(jnp.square(v_new))
