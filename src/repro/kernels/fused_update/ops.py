"""jit'd public wrappers: flat-array and whole-pytree fused updates.

``fused_momentum_gap_update_pallas`` is the drop-in Pallas version of
``repro.optim.gap.fused_momentum_gap_update`` (its oracle): it flattens the
parameter pytree once, runs the single-pass kernel, and unflattens — the
gap norm (Eq. 4) comes out of the same HBM pass as the update.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_ROWS, LANES, fused_update_2d


def _pad_to_grid(x, block_rows):
    n = x.size
    per_block = block_rows * LANES
    padded = ((n + per_block - 1) // per_block) * per_block
    x = jnp.pad(x.reshape(-1), (0, padded - n))
    return x.reshape(padded // LANES, LANES), n


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_update_flat(theta, v, g, eta, beta, *,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = False):
    """Flat f32 arrays of any (identical) size; zero-pads to the block grid.

    Returns (theta', v', sumsq). Padding is zeros in v and g, so v' padding
    stays zero and contributes nothing to sumsq."""
    shape = theta.shape
    t2, n = _pad_to_grid(theta.astype(jnp.float32), block_rows)
    v2, _ = _pad_to_grid(v.astype(jnp.float32), block_rows)
    g2, _ = _pad_to_grid(g.astype(jnp.float32), block_rows)
    t_o, v_o, sumsq = fused_update_2d(t2, v2, g2, eta, beta,
                                      block_rows=block_rows, interpret=interpret)
    return (t_o.reshape(-1)[:n].reshape(shape),
            v_o.reshape(-1)[:n].reshape(shape), sumsq)


def fused_momentum_gap_update_pallas(params: Any, v: Any, grads: Any, *,
                                     eta: float, beta: float, lag,
                                     block_rows: int = DEFAULT_BLOCK_ROWS,
                                     interpret: bool = False):
    """Pytree version; same contract as optim.gap.fused_momentum_gap_update.

    Returns (new_params, new_v, gap_norm) with
    gap_norm = eta * (1 - beta^lag) / (1 - beta) * ||v'||_2."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    v_leaves = jax.tree_util.tree_leaves(v)
    g_leaves = jax.tree_util.tree_leaves(grads)
    sizes = [l.size for l in leaves]
    flat_p = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    flat_v = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in v_leaves])
    flat_g = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in g_leaves])
    p_o, v_o, sumsq = fused_update_flat(flat_p, flat_v, flat_g, eta, beta,
                                        block_rows=block_rows, interpret=interpret)
    offs = [0]
    for s in sizes:
        offs.append(offs[-1] + s)
    new_p, new_v = [], []
    for i, l in enumerate(leaves):
        new_p.append(p_o[offs[i]:offs[i + 1]].reshape(l.shape).astype(l.dtype))
        new_v.append(v_o[offs[i]:offs[i + 1]].reshape(l.shape))
    scale = eta * (1.0 - beta ** jnp.asarray(lag, jnp.float32)) / (1.0 - beta)
    return (treedef.unflatten(new_p), treedef.unflatten(new_v),
            scale * jnp.sqrt(sumsq))
