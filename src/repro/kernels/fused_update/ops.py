"""jit'd public wrappers: flat-array and whole-pytree fused updates.

``fused_momentum_gap_update_pallas`` is the drop-in Pallas version of
``repro.optim.gap.fused_momentum_gap_update`` (its oracle): it flattens the
parameter pytree once, runs the single-pass kernel, and unflattens — the
gap norm (Eq. 4) comes out of the same HBM pass as the update.

``fused_weighted_apply_pallas`` / ``fused_apply_flat`` are the server-push
twins (mix + momentum + post-update norm — the aggregation hot path), the
Pallas versions of ``repro.optim.gap.fused_weighted_apply``.

``resolve_kernel_mode`` is the one dispatch rule every apply site shares
(``SimConfig.kernel`` / the servers' ``kernel=`` knob): ``"pallas"`` and
``"reference"`` are explicit, ``"auto"`` picks Pallas on TPU and the
reference path elsewhere — interpret mode exists for validation, not
production CPU dispatch — so CI and the loop oracle stay bit-stable.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernel import (DEFAULT_BLOCK_ROWS, LANES, fused_apply_2d,
                     fused_update_2d)

# the knob every apply site accepts; "auto" = Pallas iff the default
# backend is a TPU (elsewhere the kernels only run in interpret mode,
# which validates, not accelerates)
KERNEL_MODES = ("auto", "pallas", "reference")

# smallest grid block: (8, 128) f32 = the TPU f32 tile — going lower
# would just re-pad inside the hardware tile
MIN_BLOCK_ROWS = 8


def resolve_kernel_mode(mode: str) -> str:
    """``"auto"|"pallas"|"reference"`` -> ``"pallas"|"reference"``."""
    if mode not in KERNEL_MODES:
        raise ValueError(f"unknown kernel mode {mode!r}; expected one of "
                         f"{KERNEL_MODES}")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return mode


def kernel_interpret() -> bool:
    """Whether a forced-Pallas run must use interpret mode (no TPU)."""
    return jax.default_backend() != "tpu"


def clamp_block_rows(n: int, block_rows: int = DEFAULT_BLOCK_ROWS) -> int:
    """Largest power-of-two block (in rows of 128 lanes) that is <=
    ``block_rows`` and not wastefully larger than the ``n``-element
    payload: for tiny params / sub-block shards the default 1024-row
    block would pad 512 KiB around a few KiB of data (pad waste > payload).
    The clamp halves the block until one block covers the payload (floored
    at the (8, 128) f32 hardware tile), so pad waste is bounded by one
    block and grids of multi-block payloads keep the requested block."""
    if block_rows <= MIN_BLOCK_ROWS:
        return MIN_BLOCK_ROWS
    rows = max(-(-n // LANES), MIN_BLOCK_ROWS)
    while block_rows > MIN_BLOCK_ROWS and block_rows // 2 >= rows:
        block_rows //= 2
    return block_rows


def _pad_to_grid(x, block_rows):
    n = x.size
    per_block = block_rows * LANES
    padded = ((n + per_block - 1) // per_block) * per_block
    x = jnp.pad(x.reshape(-1), (0, padded - n))
    return x.reshape(padded // LANES, LANES), n


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_update_flat(theta, v, g, eta, beta, *,
                      block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = False):
    """Flat f32 arrays of any (identical) size; zero-pads to the block grid.

    Returns (theta', v', sumsq). Padding is zeros in v and g, so v' padding
    stays zero and contributes nothing to sumsq. ``block_rows`` is clamped
    to the payload (``clamp_block_rows``); empty arrays short-circuit."""
    shape = theta.shape
    if theta.size == 0:
        return (theta.astype(jnp.float32), v.astype(jnp.float32),
                jnp.zeros((), jnp.float32))
    block_rows = clamp_block_rows(theta.size, block_rows)
    t2, n = _pad_to_grid(theta.astype(jnp.float32), block_rows)
    v2, _ = _pad_to_grid(v.astype(jnp.float32), block_rows)
    g2, _ = _pad_to_grid(g.astype(jnp.float32), block_rows)
    t_o, v_o, sumsq = fused_update_2d(t2, v2, g2, eta, beta,
                                      block_rows=block_rows, interpret=interpret)
    return (t_o.reshape(-1)[:n].reshape(shape),
            v_o.reshape(-1)[:n].reshape(shape), sumsq)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_apply_flat(cur, v, new, w, inv_eta, beta, *,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False):
    """Server push apply on flat f32 arrays (mix + momentum + sq-norm in
    one pass); zero-pads to the block grid. Padding mixes 0 with 0, so the
    padded lanes of ``mixed``/``v'`` stay zero and contribute nothing to
    sumsq. ``w``/``inv_eta``/``beta`` are traced scalars — one executable
    per shape, shared across rules and knob values.

    Returns (mixed, v', sumsq)."""
    shape = cur.shape
    if cur.size == 0:
        return (cur.astype(jnp.float32), v.astype(jnp.float32),
                jnp.zeros((), jnp.float32))
    block_rows = clamp_block_rows(cur.size, block_rows)
    c2, n = _pad_to_grid(cur.astype(jnp.float32), block_rows)
    v2, _ = _pad_to_grid(v.astype(jnp.float32), block_rows)
    n2, _ = _pad_to_grid(new.astype(jnp.float32), block_rows)
    m_o, v_o, sumsq = fused_apply_2d(c2, v2, n2, w, inv_eta, beta,
                                     block_rows=block_rows,
                                     interpret=interpret)
    return (m_o.reshape(-1)[:n].reshape(shape),
            v_o.reshape(-1)[:n].reshape(shape), sumsq)


def _flatten_concat(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])


def _split_back(flat, leaves, treedef, keep_dtype: bool):
    offs = [0]
    for l in leaves:
        offs.append(offs[-1] + l.size)
    out = []
    for i, l in enumerate(leaves):
        piece = flat[offs[i]:offs[i + 1]].reshape(l.shape)
        out.append(piece.astype(l.dtype) if keep_dtype else piece)
    return treedef.unflatten(out)


def fused_momentum_gap_update_pallas(params: Any, v: Any, grads: Any, *,
                                     eta: float, beta: float, lag,
                                     block_rows: int = DEFAULT_BLOCK_ROWS,
                                     interpret: bool = False):
    """Pytree version; same contract as optim.gap.fused_momentum_gap_update.

    Returns (new_params, new_v, gap_norm) with
    gap_norm = eta * (1 - beta^lag) / (1 - beta) * ||v'||_2."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    flat_p = _flatten_concat(params)
    flat_v = _flatten_concat(v)
    flat_g = _flatten_concat(grads)
    p_o, v_o, sumsq = fused_update_flat(flat_p, flat_v, flat_g, eta, beta,
                                        block_rows=block_rows, interpret=interpret)
    scale = eta * (1.0 - beta ** jnp.asarray(lag, jnp.float32)) / (1.0 - beta)
    return (_split_back(p_o, leaves, treedef, keep_dtype=True),
            _split_back(v_o, leaves, treedef, keep_dtype=False),
            scale * jnp.sqrt(sumsq))


def fused_weighted_apply_pallas(params: Any, v: Any, new_params: Any, *,
                                w, eta: float, beta: float,
                                block_rows: int = DEFAULT_BLOCK_ROWS,
                                interpret: bool = False):
    """Pytree version of the server push apply; same contract as
    optim.gap.fused_weighted_apply (its oracle): one flatten, ONE kernel
    pass over the whole model for the weighted mix + server momentum
    recursion + post-update norm, one unflatten — no separate
    ``tree_l2_norm`` traversal.

    Returns (mixed_params, new_v, v_norm) with v_norm = ||v'||_2 (a 0-d
    f32 scalar — callers float() it on demand)."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    flat_p = _flatten_concat(params)
    flat_v = _flatten_concat(v)
    flat_n = _flatten_concat(new_params)
    inv_eta = 1.0 / max(eta, 1e-12)
    m_o, v_o, sumsq = fused_apply_flat(flat_p, flat_v, flat_n, w, inv_eta,
                                       beta, block_rows=block_rows,
                                       interpret=interpret)
    return (_split_back(m_o, leaves, treedef, keep_dtype=True),
            _split_back(v_o, leaves, treedef, keep_dtype=False),
            jnp.sqrt(sumsq))
