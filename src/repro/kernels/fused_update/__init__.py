from .ops import fused_momentum_gap_update_pallas, fused_update_flat
from .ref import fused_update_flat_ref
