from .ops import (KERNEL_MODES, clamp_block_rows, fused_apply_flat,
                  fused_momentum_gap_update_pallas, fused_update_flat,
                  fused_weighted_apply_pallas, kernel_interpret,
                  resolve_kernel_mode)
from .ref import fused_apply_flat_ref, fused_update_flat_ref
