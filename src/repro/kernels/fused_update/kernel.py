"""Pallas TPU kernels: fused momentum update / server apply + gap norm.

Two kernels share one layout and one motivation. The paper's per-push
work over every parameter is HBM-bound either way — the arithmetic
intensity is so low (~4 FLOPs / 20 bytes) that memory traffic IS the
cost — so each fuses its multi-pass naive schedule into ONE pass with
the sum-of-squares reduction accumulated on-chip.

``_kernel`` (the CLIENT step, Eq. 1 + Eq. 4):

    v'     = beta * v + (1 - beta) * g          (read v, g; write v')
    theta' = theta - eta * v'                   (read theta, v'; write theta')
    gap    = scale * ||v'||_2                   (read v')

i.e. 5 reads + 2 writes of N floats naively; fused: 3 reads + 2 writes —
a ~7/5 = 1.4x traffic cut vs. the best 2-pass schedule, ~2x vs. naive.

``_apply_kernel`` (the SERVER push apply — the aggregation hot path of
``core/server.py`` / ``serve/server.py`` / the fused real-ML push scan):

    mixed = w * new + (1 - w) * cur             (read new, cur; write mixed)
    s     = (cur - mixed) / eta                 (re-read cur, mixed)
    v'    = beta * v + (1 - beta) * s           (read v; write v')
    norm  = ||v'||_2                            (re-read v')

i.e. 7 array passes naively (what ``AsyncParameterServer.push`` +
``tree_l2_norm`` dispatch); fused: 3 reads (cur, v, new) + 2 writes
(mixed, v') = the same 1.4x/2x traffic cut, per push.

Layout: the parameter pytree is flattened and concatenated to a single f32
vector, padded and viewed as (rows, 128) — the last dim matches the TPU
lane width, rows are tiled in VMEM-sized blocks. Grid is 1-D over row
blocks; each step reduces its block's Sum(v'^2) into a (1,1) partial output
(summed by the XLA epilogue in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 1024   # (1024, 128) f32 = 512 KiB per operand in VMEM


def _kernel(theta_ref, v_ref, g_ref, eta_ref, beta_ref,
            theta_out_ref, v_out_ref, partial_ref):
    eta = eta_ref[0]
    beta = beta_ref[0]
    v_new = beta * v_ref[...] + (1.0 - beta) * g_ref[...]
    theta_out_ref[...] = theta_ref[...] - eta * v_new
    v_out_ref[...] = v_new
    partial_ref[0, 0] = jnp.sum(v_new * v_new)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_update_2d(theta, v, g, eta, beta, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """theta/v/g: (rows, 128) f32, rows % block_rows == 0.

    Returns (theta', v', sumsq) with sumsq = Sum(v'^2) (f32 scalar)."""
    rows, lanes = theta.shape
    assert lanes == LANES and rows % block_rows == 0, (rows, lanes)
    nblk = rows // block_rows
    eta = jnp.asarray([eta], jnp.float32)
    beta = jnp.asarray([beta], jnp.float32)

    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec(memory_space=pltpu.SMEM)
    theta_o, v_o, partials = pl.pallas_call(
        _kernel,
        grid=(nblk,),
        in_specs=[block, block, block, scalar, scalar],
        out_specs=[block, block,
                   pl.BlockSpec((1, 1), lambda i: (i, 0),
                                memory_space=pltpu.SMEM)],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        ],
        interpret=interpret,
        name="fused_momentum_gap_update",
    )(theta, v, g, eta, beta)
    return theta_o, v_o, jnp.sum(partials)


def _apply_kernel(cur_ref, v_ref, new_ref, w_ref, inv_eta_ref, beta_ref,
                  mixed_ref, v_out_ref, partial_ref):
    w = w_ref[0]
    inv_eta = inv_eta_ref[0]
    beta = beta_ref[0]
    mixed = w * new_ref[...] + (1.0 - w) * cur_ref[...]
    s = (cur_ref[...] - mixed) * inv_eta
    v_new = beta * v_ref[...] + (1.0 - beta) * s
    mixed_ref[...] = mixed
    v_out_ref[...] = v_new
    partial_ref[0, 0] = jnp.sum(v_new * v_new)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_apply_2d(cur, v, new, w, inv_eta, beta, *,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False):
    """Server push apply. cur/v/new: (rows, 128) f32, rows % block_rows == 0;
    ``w``/``inv_eta``/``beta`` are traced scalars (SMEM operands), so every
    push of a given shape shares one executable regardless of rule/knobs.

    Returns (mixed, v', sumsq) with sumsq = Sum(v'^2) (f32 scalar)."""
    rows, lanes = cur.shape
    assert lanes == LANES and rows % block_rows == 0, (rows, lanes)
    nblk = rows // block_rows
    w = jnp.asarray(w, jnp.float32).reshape(1)
    inv_eta = jnp.asarray(inv_eta, jnp.float32).reshape(1)
    beta = jnp.asarray(beta, jnp.float32).reshape(1)

    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec(memory_space=pltpu.SMEM)
    mixed, v_o, partials = pl.pallas_call(
        _apply_kernel,
        grid=(nblk,),
        in_specs=[block, block, block, scalar, scalar, scalar],
        out_specs=[block, block,
                   pl.BlockSpec((1, 1), lambda i: (i, 0),
                                memory_space=pltpu.SMEM)],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        ],
        interpret=interpret,
        name="fused_weighted_apply",
    )(cur, v, new, w, inv_eta, beta)
    return mixed, v_o, jnp.sum(partials)
