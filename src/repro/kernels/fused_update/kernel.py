"""Pallas TPU kernel: fused momentum update + gradient-gap partial norm.

The paper's per-push work over every parameter (Eq. 1 + Eq. 4) is three
HBM-bound passes when written naively:

    v'     = beta * v + (1 - beta) * g          (read v, g; write v')
    theta' = theta - eta * v'                   (read theta, v'; write theta')
    gap    = scale * ||v'||_2                   (read v')

i.e. 5 reads + 2 writes of N floats. This kernel fuses them into ONE pass:
3 reads (theta, v, g) + 2 writes (theta', v') and the sum-of-squares
reduction accumulated on-chip — the arithmetic intensity is so low
(~4 FLOPs / 20 bytes) that HBM traffic IS the cost, so the fusion is a
~7/5 = 1.4x traffic cut vs. the best 2-pass schedule and ~2x vs. naive.

Layout: the parameter pytree is flattened and concatenated to a single f32
vector, padded and viewed as (rows, 128) — the last dim matches the TPU
lane width, rows are tiled in VMEM-sized blocks. Grid is 1-D over row
blocks; each step reduces its block's Sum(v'^2) into a (1,1) partial output
(summed by the XLA epilogue in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 1024   # (1024, 128) f32 = 512 KiB per operand in VMEM


def _kernel(theta_ref, v_ref, g_ref, eta_ref, beta_ref,
            theta_out_ref, v_out_ref, partial_ref):
    eta = eta_ref[0]
    beta = beta_ref[0]
    v_new = beta * v_ref[...] + (1.0 - beta) * g_ref[...]
    theta_out_ref[...] = theta_ref[...] - eta * v_new
    v_out_ref[...] = v_new
    partial_ref[0, 0] = jnp.sum(v_new * v_new)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_update_2d(theta, v, g, eta, beta, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = False):
    """theta/v/g: (rows, 128) f32, rows % block_rows == 0.

    Returns (theta', v', sumsq) with sumsq = Sum(v'^2) (f32 scalar)."""
    rows, lanes = theta.shape
    assert lanes == LANES and rows % block_rows == 0, (rows, lanes)
    nblk = rows // block_rows
    eta = jnp.asarray([eta], jnp.float32)
    beta = jnp.asarray([beta], jnp.float32)

    block = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec(memory_space=pltpu.SMEM)
    theta_o, v_o, partials = pl.pallas_call(
        _kernel,
        grid=(nblk,),
        in_specs=[block, block, block, scalar, scalar],
        out_specs=[block, block,
                   pl.BlockSpec((1, 1), lambda i: (i, 0),
                                memory_space=pltpu.SMEM)],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
            jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        ],
        interpret=interpret,
        name="fused_momentum_gap_update",
    )(theta, v, g, eta, beta)
    return theta_o, v_o, jnp.sum(partials)
