"""Public wrapper: shape-flexible flash attention (pads to block multiples).

The model layer (``repro.models.attention``) calls this with
cfg.attention_impl == "flash"; the XLA `_sdpa` einsum path is the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d) -> (B, H, Sq, d).

    Pads Sq/Sk up to block multiples; key padding is masked by giving padded
    keys -inf scores only when causal masking does not already exclude them —
    we pad on the RIGHT, and pass `causal` through, so for causal use padded
    keys are beyond every real query's row limit iff Sk == Sq. For the
    non-causal / ragged case we clamp block sizes to the padded extent."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    bq = min(block_q, _round_up(Sq))
    bk = min(block_k, _round_up(Sk))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pk and not (causal and Sk == Sq and pq == pk):
        # right-padded keys would receive finite scores; fall back to masking
        # via explicit -inf bias is not supported in this wrapper — require
        # callers to pad (all launch shapes are powers of two).
        raise ValueError(f"Sk={Sk} must be a multiple of block_k={bk} "
                         "for non-causal use")
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_kernel(q, k, v, causal=causal, scale=scale,
                                 block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :Sq, :]


def _round_up(n: int, mult: int = 128) -> int:
    return ((n + mult - 1) // mult) * mult
