"""Pure-jnp oracle: causal (or full) softmax attention with GQA."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d); H % KV == 0.

    f32 softmax accumulation, output cast back to q.dtype."""
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = (scale if scale is not None else d ** -0.5)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * s
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, vf)
    return out.reshape(B, H, Sq, d).astype(q.dtype)
