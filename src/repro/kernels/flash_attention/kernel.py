"""Pallas TPU flash attention: blocked causal online-softmax with GQA.

TPU adaptation of the FlashAttention schedule: instead of the CUDA
shared-memory/warp formulation, blocks of Q stay resident in VMEM while the
grid's innermost dimension streams K/V blocks HBM->VMEM; the online-softmax
running max/denominator live in VMEM scratch that persists across the
innermost grid steps (Mosaic revisits the same output block). MXU work is
the two (block_q x d) @ (d x block_k) / (block_q x block_k) @ (block_k x d)
matmuls per step; block sizes default to 512x512 so both matmul operands and
the f32 accumulator fit VMEM (~(512*128 + 512*128 + 512*512)*4B ~ 1.5 MiB)
with dims multiples of the 128-lane / 8-sublane tiling.

Causal skipping: grid steps with block_k_start > block_q_end contribute
nothing and exit early via pl.when (Mosaic still schedules the step, but no
DMA compute is issued) — the standard ~2x saving for causal masks comes from
the index-map never mapping those blocks... they are mapped but skipped;
on-TPU the bandwidth win comes from the compute predicate.

GQA: query head h reads KV head h * KV // H via the k/v index_maps —
no repeat/broadcast materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, block_q: int, block_k: int,
            num_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    needed = (not causal) or (k_start <= q_start + block_q - 1)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        pl.when(needed)(compute)
    else:
        compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False):
    """q: (B, H, Sq, d); k/v: (B, KV, Sk, d). Sq % block_q == Sk % block_k == 0."""
    B, H, Sq, d = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0 and Sq % block_q == 0 and Sk % block_k == 0
    grid = (B, H, Sq // block_q, Sk // block_k)
    s = (scale if scale is not None else d ** -0.5)

    kernel = functools.partial(
        _kernel, scale=s, causal=causal, block_q=block_q, block_k=block_k,
        num_kv_blocks=Sk // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, qi, ki: (b, h * KV // H, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, qi, ki: (b, h * KV // H, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),     # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),     # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
