from .optimizers import (OptState, adamw, momentum_sgd, apply_updates,
                         global_norm, clip_by_global_norm)
from .gap import (fused_momentum_gap_update, fused_weighted_apply,
                  gap_aware_scale, delay_compensate)
from .compression import (topk_compress, topk_decompress, int8_quantize,
                          int8_dequantize, ErrorFeedback)

__all__ = [
    "OptState", "adamw", "momentum_sgd", "apply_updates", "global_norm",
    "clip_by_global_norm",
    "fused_momentum_gap_update", "fused_weighted_apply", "gap_aware_scale",
    "delay_compensate",
    "topk_compress", "topk_decompress", "int8_quantize", "int8_dequantize",
    "ErrorFeedback",
]
