"""Gradient compression for async federated pushes over slow links:
top-k sparsification with error feedback, and int8 symmetric quantization.

At datacenter scale these shrink the cross-island (pod-to-server) update
traffic — the analogue of the paper's 2.5 MB LeNet model push over 4G.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TopK(NamedTuple):
    values: jnp.ndarray      # (k,)
    indices: jnp.ndarray     # (k,) int32 into the flattened tensor
    shape: tuple


@partial(jax.jit, static_argnums=(1,))
def _topk_select(flat: jnp.ndarray, k: int):
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    return flat[idx], idx


@jax.jit
def _identity_select(flat: jnp.ndarray):
    return flat, jnp.arange(flat.shape[0], dtype=jnp.int32)


@partial(jax.jit, static_argnums=(2,))
def _scatter(values: jnp.ndarray, indices: jnp.ndarray, size: int):
    return jnp.zeros(size, jnp.float32).at[indices].set(values)


def topk_compress(x: jnp.ndarray, k: int) -> TopK:
    # host-side flat size (math.prod, never jnp): the selection cores are
    # jitted per (shape, k) and the wrapper must not touch device values
    size = math.prod(x.shape) if x.shape else 1
    flat = x.reshape(-1).astype(jnp.float32)
    if size == 0:       # empty tensor (e.g. a zero-size shard slice)
        return TopK(flat, jnp.zeros(0, jnp.int32), x.shape)
    k = max(1, min(int(k), size))
    if k == size:       # dense: every entry survives, skip the top_k sort
        values, idx = _identity_select(flat)
    else:
        values, idx = _topk_select(flat, k)
    return TopK(values, idx, x.shape)


def topk_decompress(t: TopK) -> jnp.ndarray:
    # math.prod on the host: jnp.prod here forced a device sync (and a
    # tiny compile) per decompress
    size = math.prod(t.shape) if t.shape else 1
    return _scatter(t.values, t.indices, size).reshape(t.shape)


@jax.jit
def _int8_quantize(x: jnp.ndarray):
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_quantize(x: jnp.ndarray):
    return _int8_quantize(x)


@jax.jit
def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class ErrorFeedback:
    """Stateful error-feedback wrapper: compress(residual + update), carry
    the quantization error forward so the compression is unbiased over time."""

    def __init__(self, ratio: float = 0.01, min_k: int = 1):
        self.ratio = ratio
        self.min_k = min_k
        self.residual: Any = None

    def compress(self, tree: Any):
        if self.residual is None:
            self.residual = jax.tree.map(
                lambda x: jnp.zeros_like(x, jnp.float32), tree)
        corrected = jax.tree.map(lambda x, r: x.astype(jnp.float32) + r,
                                 tree, self.residual)
        payload = jax.tree.map(
            lambda x: topk_compress(x, max(int(x.size * self.ratio), self.min_k)),
            corrected)
        # `corrected` is a structural prefix of `payload` (TopK subtrees sit at
        # its leaf positions), so tree.map hands us the whole TopK per leaf.
        self.residual = jax.tree.map(
            lambda x, t: x - topk_decompress(t), corrected, payload)
        return payload

    @staticmethod
    def decompress(payload: Any):
        return jax.tree.map(topk_decompress, payload,
                            is_leaf=lambda x: isinstance(x, TopK))
