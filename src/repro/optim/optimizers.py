"""Optimizers built from scratch (no optax): momentum SGD (paper Eq. 1)
and AdamW for the LM-scale configs. Functional, pjit-friendly."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any                  # first moment / momentum vector v_t
    nu: Any                  # second moment (None for SGD)


def momentum_sgd(lr: float, beta: float = 0.9):
    """Paper Eq. (1): v = beta*v + (1-beta)*g ; theta -= lr*v."""

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(jnp.zeros_like, params), None)

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda v, g: beta * v + (1 - beta) * g,
                          state.mu, grads)
        updates = jax.tree.map(lambda v: -lr * v, mu)
        return updates, OptState(state.step + 1, mu, None)

    return init, update


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1):
    def init(params):
        z = jax.tree.map(jnp.zeros_like, params)
        return OptState(jnp.zeros((), jnp.int32), z,
                        jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g),
                          state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m, n, p):
            return -lr * ((m / c1) / (jnp.sqrt(n / c2) + eps) + weight_decay * p)

        updates = jax.tree.map(u, mu, nu, params)
        return updates, OptState(step, mu, nu)

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda l: l * scale, tree), n
