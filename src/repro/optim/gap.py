"""The paper's mechanism at LM scale: fused momentum update + gradient-gap
norm, gap-aware scaling [31], and delay compensation [10].

``fused_momentum_gap_update`` is the single-HBM-pass version of Eq. (1) +
Eq. (4): it produces the new momentum, the updated params, AND the
sum-of-squares needed for the gradient gap — the Pallas kernel
(`repro.kernels.fused_update`) implements the same contract on TPU; this is
the XLA path (also its oracle).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def fused_momentum_gap_update(params: Any, v: Any, grads: Any, *,
                              eta: float, beta: float, lag: jnp.ndarray):
    """Returns (new_params, new_v, gap_norm) where
    gap_norm = || eta*(1-beta^lag)/(1-beta) * v_new ||_2 (Eq. 4)."""
    scale = eta * (1.0 - beta ** lag.astype(jnp.float32)) / (1.0 - beta)

    def leaf(p, vv, g):
        v_new = beta * vv + (1 - beta) * g.astype(vv.dtype)
        p_new = (p.astype(jnp.float32) - eta * v_new).astype(p.dtype)
        partial = jnp.sum(jnp.square(v_new.astype(jnp.float32)))
        return p_new, v_new, partial

    out = jax.tree.map(leaf, params, v, grads)
    treedef = jax.tree.structure(params)
    leaves = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_v = treedef.unflatten([l[1] for l in leaves])
    sq = sum(l[2] for l in leaves)
    return new_p, new_v, scale * jnp.sqrt(sq)


def fused_weighted_apply(params: Any, v: Any, new_params: Any, *,
                         w, eta: float, beta: float):
    """The server push apply (``AsyncParameterServer.push`` contract) as one
    pytree traversal: weighted mix toward the pushed params, server momentum
    recursion on the implied step, and the post-update ||v'||_2 — the XLA
    path (and oracle) of ``fused_weighted_apply_pallas``.

    Returns (mixed_params, new_v, v_norm):
        mixed = w * new + (1 - w) * params
        s     = (params - mixed) / eta
        v'    = beta * v + (1 - beta) * s
        v_norm = ||v'||_2
    """
    inv_eta = 1.0 / max(eta, 1e-12)

    def leaf(p, vv, n):
        p32 = p.astype(jnp.float32)
        mixed = w * n.astype(jnp.float32) + (1.0 - w) * p32
        s = (p32 - mixed) * inv_eta
        v_new = beta * vv.astype(jnp.float32) + (1.0 - beta) * s
        return mixed.astype(p.dtype), v_new, jnp.sum(jnp.square(v_new))

    out = jax.tree.map(leaf, params, v, new_params)
    treedef = jax.tree.structure(params)
    leaves = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    mixed = treedef.unflatten([l[0] for l in leaves])
    new_v = treedef.unflatten([l[1] for l in leaves])
    sq = sum(l[2] for l in leaves)
    return mixed, new_v, jnp.sqrt(sq)


def gap_aware_scale(gap: jnp.ndarray, gap_ref: jnp.ndarray):
    """Gap-aware staleness dampening [31]: scale update by 1/(1+gap/ref)."""
    return 1.0 / (1.0 + gap / jnp.maximum(gap_ref, 1e-9))


def delay_compensate(grads: Any, params_now: Any, params_then: Any,
                     lambda_dc: float = 0.5):
    """DC-ASGD [10]: g_dc = g + lambda * g*g*(theta_now - theta_then)
    (diagonal Hessian approximation via gradient outer-product)."""
    return jax.tree.map(
        lambda g, pn, pt: g + lambda_dc * g * g * (pn - pt).astype(g.dtype),
        grads, params_now, params_then)
