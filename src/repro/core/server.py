"""Parameter servers: asynchronous (paper Sec. VI) and synchronous (FedAvg).

The async server implements the paper's protocol: clients pull the current
global model, train locally with momentum SGD (Eq. 1), and push; the server
applies the push immediately (lock-free) and advances the version counter.
HOW a push is applied is delegated to a first-class ``AggregationRule``
(core/aggregation.py): ``aggregation="replace"`` reproduces the paper,
while ``fedasync_poly`` / ``gap_aware`` / ``hetero_aware`` mix stale
pushes at reduced weight — the same registry the simulator engines thread
(``SimConfig.aggregation``), so the loop oracle and the batched engines
see one rule implementation.

The server also maintains the global momentum-norm estimate that drives the
Eq. (4) gradient-gap predictions: v <- beta * v + (1-beta) * s with
s = (theta_old - theta_new) / eta, so only ||v||2 (a scalar) ever travels to
clients — the paper's O(1)-per-client distributed implementation.

``kernel="pallas"`` routes the entire apply (mix + momentum + post-update
norm) through the single-HBM-pass Pallas kernel
(``kernels/fused_update.fused_weighted_apply_pallas``) instead of the
three-traversal reference; ``"auto"`` (the default) picks Pallas on TPU and
the bit-stable reference elsewhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.fused_update import (fused_weighted_apply_pallas,
                                    kernel_interpret, resolve_kernel_mode)
from .aggregation import AggregationRule, configure_aggregation
from .staleness import LagTracker, gradient_gap, tree_l2_norm


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def _tree_axpy(alpha, x, y):
    """alpha*x + y"""
    return jax.tree.map(lambda a_, b_: alpha * a_ + b_, x, y)


def _tree_mix(new, old, alpha):
    return jax.tree.map(lambda n, o: alpha * n + (1 - alpha) * o, new, old)


@dataclasses.dataclass
class PushResult:
    lag: int
    gap_estimate: float     # Eq. (4) gap at push ARRIVAL (pre-application)
    applied_weight: float   # the rule's mixing weight, 1.0 under replace
    version: int


class AsyncParameterServer:
    def __init__(self, params: Any, eta: float, beta: float,
                 aggregation: Union[str, AggregationRule] = "replace",
                 fedasync_alpha: float = 0.6, fedasync_a: float = 0.5,
                 gap_ref: float = 1.0, fleet=None, kernel: str = "auto"):
        """``aggregation`` is a registry name or ``AggregationRule``
        instance (core/aggregation.py). The legacy knob kwargs
        (``fedasync_alpha``/``fedasync_a``/``gap_ref``) still construct
        the matching rule when a name is given with non-default values;
        new code should pass a configured rule instance. ``fleet`` binds
        the run's ``FleetSpec`` for fleet-conditioned rules
        (``hetero_aware``) — ``FederatedSim`` binds it automatically.
        ``kernel`` selects the push-apply implementation:
        ``"pallas"`` fuses mix + momentum + norm into one kernel pass,
        ``"reference"`` keeps the multi-traversal jnp path (bit-stable),
        ``"auto"`` = Pallas on TPU, reference elsewhere."""
        self.params = params
        self.eta = eta
        self.beta = beta
        self.rule: AggregationRule = configure_aggregation(
            aggregation, fedasync_alpha=fedasync_alpha,
            fedasync_a=fedasync_a, gap_ref=gap_ref)
        self.aggregation = self.rule.name
        self.fleet_spec = fleet
        self.kernel = resolve_kernel_mode(kernel)
        self.lag_tracker = LagTracker()
        self._v = jax.tree.map(jnp.zeros_like, params)
        self.v_norm = 0.0
        self.in_flight: set = set()

    # ------------------------------------------------------------------ pull
    def pull(self, client_id) -> tuple[Any, int]:
        self.lag_tracker.on_pull(client_id)
        self.in_flight.add(client_id)
        return self.params, self.lag_tracker.version

    def lag_estimate(self, client_id) -> int:
        """Alg. 2 line 4: server-side lag estimate = concurrent tasks."""
        return max(len(self.in_flight) - (1 if client_id in self.in_flight else 0), 0)

    # ------------------------------------------------------------------ push
    def push(self, client_id, new_params: Any) -> PushResult:
        lag = self.lag_tracker.on_push(client_id)
        self.in_flight.discard(client_id)
        old = self.params

        # Eq. (4) gap at push arrival — the momentum norm BEFORE this
        # push is applied (the norm the loop oracle's push log records).
        # Computed once: the rule's weight and the returned gap_estimate
        # share it.
        gap = gradient_gap(self.v_norm, lag, self.eta, self.beta)
        weight = float(self.rule.weight(lag, gap, self.v_norm,
                                        fleet=self.fleet_spec,
                                        users=client_id))
        if self.kernel == "pallas":
            # one fused dispatch over the whole model: mix, server momentum,
            # and ||v'||_2 come out of a single HBM pass — no tree_l2_norm
            # re-traversal
            self.params, self._v, v_norm = fused_weighted_apply_pallas(
                old, self._v, new_params, w=weight, eta=self.eta,
                beta=self.beta, interpret=kernel_interpret())
            self.v_norm = float(v_norm)
        else:
            self.params = _tree_mix(new_params, old, weight)

            # server momentum for Eq. (4): s = (theta_old - theta_new)/eta
            s = jax.tree.map(lambda o, n: (o - n) / max(self.eta, 1e-12),
                             old, self.params)
            self._v = jax.tree.map(
                lambda v, g_: self.beta * v + (1 - self.beta) * g_,
                self._v, s)
            self.v_norm = tree_l2_norm(self._v)
        return PushResult(lag=lag, gap_estimate=gap, applied_weight=weight,
                          version=self.lag_tracker.version)


class SyncServer:
    """FedAvg (McMahan et al.): lock-step rounds, average over the cohort."""

    def __init__(self, params: Any):
        self.params = params
        self.round = 0
        self._pending: list[Any] = []

    def pull(self, client_id=None):
        return self.params, self.round

    def submit(self, new_params: Any):
        self._pending.append(new_params)

    def aggregate(self) -> int:
        if not self._pending:
            return self.round
        n = len(self._pending)
        stacked = jax.tree.map(lambda *xs: sum(xs) / n, *self._pending)
        self.params = stacked
        self._pending = []
        self.round += 1
        return self.round
