"""Struct-of-arrays vectorized simulator engine.

Replaces ``FederatedSim``'s per-slot, per-user Python object loop with
batched per-user state arrays — mode, cooldown, app id, app/train remaining,
pulled-at version, energy, idle gap all live in ``(n_users,)`` NumPy arrays,
and the Table II catalog is flattened into ``(n_devices, n_apps)`` lookup
tables (``energy.catalog_tables``) gathered per user once at startup. Every
phase of a slot — app arrivals, cooldown transitions, policy decisions,
training progression, Eq. (10) energy accounting, Eq. (15)/(16) queue
updates — is a handful of vector ops instead of an O(n) Python loop.

Equivalence contract: seeded runs reproduce the reference loop engine
(``FederatedSim._run_loop``) — identical decision sequences, update counts,
push logs and queue traces; energies match to float-sum reordering
(pairwise vs sequential reduction, ~1e-15 relative). The only sequential
coupling inside a slot is the online policy's lag estimate (every user that
schedules raises the next user's in-flight count); ``OnlineScheduler.
decide_batch`` collapses it to one elementwise comparison when H == 0 (the
gap term then cannot affect the argmin) and replays it exactly otherwise.

``backend="jax"`` additionally compiles the whole trace-mode horizon into a
single ``jax.lax.scan`` over slots (jit-compiled once per config shape,
scalar knobs like V/L_b passed as traced operands so policy sweeps reuse
the executable). The jax backend covers sync/immediate/online; offline's
knapsack DP stays on the numpy path. It returns an empty push log (per-push
dicts cannot stream out of a scan); enable jax x64 for f64 parity with the
numpy engines.
"""
from __future__ import annotations

import functools
import warnings
from typing import List, Tuple

import numpy as np

from .energy import catalog_tables, device_ids
from .offline import knapsack_schedule, lemma1_lag_bounds
from .simulator import SimResult, n_slots, trace_v_norm
from .staleness import gradient_gap

MODE_WAIT, MODE_TRAIN, MODE_COOL = 0, 1, 2
PLAN_HOLD, PLAN_CORUN, PLAN_SEP = 0, 1, 2


def run_vectorized(sim, backend: str = "vectorized") -> SimResult:
    """Run ``sim`` (a constructed FederatedSim) on a batched engine."""
    if backend == "jax":
        return _run_jax(sim)
    return _run_numpy(sim)


def _user_tables(sim):
    """Gather the catalog rows for each user's device, once per run."""
    tab = catalog_tables()
    dev = device_ids([u.device.name for u in sim.users])
    return (tab.p_train[dev], tab.t_train[dev], tab.p_idle[dev],
            tab.p_sched[dev], tab.p_app[dev], tab.p_corun[dev],
            tab.t_corun[dev], tab.saving_rate[dev])


# ======================================================================
# NumPy backend
# ======================================================================
def _run_numpy(sim) -> SimResult:
    cfg = sim.cfg
    n = cfg.n_users
    T = n_slots(cfg)
    t_d = cfg.t_d
    policy = cfg.policy
    PT, TT, PI, PS, P_APP, P_COR, T_COR, SRATE = _user_tables(sim)
    OVERHEAD = PS - PI
    app_sched, app_choice = sim.app_sched, sim.app_choice
    sched = sim.sched                      # queue state (Q, H) + decide_batch
    v_hook = sim.ml.get("v_norm")
    ar = np.arange(n)

    # ---- per-user state, struct-of-arrays -----------------------------
    mode = np.full(n, MODE_COOL, dtype=np.int8)
    cooldown = np.zeros(n, dtype=np.int64)
    app = np.full(n, -1, dtype=np.int64)
    app_rem = np.zeros(n)
    train_rem = np.zeros(n)
    corun = np.zeros(n, dtype=bool)
    idle_gap = np.zeros(n)
    pulled_at = np.zeros(n, dtype=np.int64)
    energy = np.zeros(n)
    updates = np.zeros(n, dtype=np.int64)
    plan = np.full(n, PLAN_HOLD, dtype=np.int8)
    # App-dependent lookups, maintained incrementally on the (rare) app
    # arrival/expiry events instead of re-gathered every slot:
    #   p_if_train  = Eq. 10 power if training (P^{a'} with app, else P^b)
    #   p_if_idle   = Eq. 10 power if not     (P^a with app, else P^d)
    #   t_if_corun  = co-run training duration for the current app
    p_if_train = PT.copy()
    p_if_idle = PI.copy()
    t_if_corun = np.zeros(n)

    version = 0
    in_flight = 0
    sync_round_open = False
    next_offline_plan = 0.0
    sum_Q = sum_H = 0.0
    corun_updates = 0
    trace_t: List[int] = []
    trace_E: List[float] = []
    trace_Q: List[float] = []
    trace_H: List[float] = []
    # push log collected as per-slot array chunks, expanded to dicts at the end
    push_chunks: List[Tuple] = []

    def v_norm(ver):
        """ver may be a scalar or an array of per-finisher versions; the
        v_norm hook (slot-constant by contract) broadcasts."""
        if v_hook is not None:
            return v_hook()
        return trace_v_norm(cfg.v_norm0, ver)

    def begin_training(idx):
        """idx: user indices starting training this slot (corun iff app)."""
        nonlocal in_flight
        ha = app[idx] >= 0
        corun[idx] = ha
        train_rem[idx] = np.where(ha, t_if_corun[idx], TT[idx])
        mode[idx] = MODE_TRAIN
        pulled_at[idx] = version
        in_flight += len(idx)

    for t in range(T):
        # --- app arrivals / progression -------------------------------
        srow = app_sched[t]
        has_app = app >= 0
        new_app = srow & ~has_app
        if has_app.any():
            app_rem[has_app] -= t_d
            ended = has_app & (app_rem <= 0.0)
            if ended.any():
                app[ended] = -1
                app_rem[ended] = 0.0
                p_if_train[ended] = PT[ended]
                p_if_idle[ended] = PI[ended]
        if new_app.any():
            nidx = np.nonzero(new_app)[0]
            aid = app_choice[t, nidx]
            app[nidx] = aid
            app_rem[nidx] = T_COR[nidx, aid]
            p_if_train[nidx] = P_COR[nidx, aid]
            p_if_idle[nidx] = P_APP[nidx, aid]
            t_if_corun[nidx] = T_COR[nidx, aid]

        # --- cooldown -> waiting (queue arrival) -----------------------
        arrivals = 0
        cooling = mode == MODE_COOL
        if cooling.any():
            cooldown[cooling] -= 1
            to_wait = cooling & (cooldown <= 0)
            arrivals = int(np.count_nonzero(to_wait))
            if arrivals:
                mode[to_wait] = MODE_WAIT
                plan[to_wait] = PLAN_HOLD
        waiting = mode == MODE_WAIT
        has_app = app >= 0
        served = 0
        gap_sum = 0.0

        # --- policy decisions for waiting users ------------------------
        if policy == "sync":
            if not sync_round_open and \
                    int(np.count_nonzero(waiting)) == n:
                begin_training(ar)
                served = n
                sync_round_open = True
        elif policy == "immediate":
            if waiting.any():
                widx = np.nonzero(waiting)[0]
                begin_training(widx)
                served = len(widx)
        elif policy == "online":
            if waiting.any():
                widx = np.nonzero(waiting)[0]
                vn = v_norm(version)
                d = sched.decide_batch(p_if_train[widx], p_if_idle[widx],
                                       idle_gap[widx], in_flight, vn)
                if d.n_served:
                    begin_training(widx[d.schedule])
                if d.n_served != len(widx):
                    idle_gap[widx[~d.schedule]] += cfg.epsilon
                served = d.n_served
                gap_sum = d.gap_sum
        else:  # offline
            if t >= next_offline_plan:
                next_offline_plan = t + cfg.offline_window
                _plan_offline_vec(cfg, t, np.nonzero(waiting)[0], app,
                                  app_sched, app_choice, T_COR, SRATE,
                                  plan, v_norm(version))
            start = waiting & (((plan == PLAN_CORUN) & has_app) |
                               (plan == PLAN_SEP))
            if start.any():
                sidx = np.nonzero(start)[0]
                begin_training(sidx)
                served = len(sidx)

        # --- training progression --------------------------------------
        training = mode == MODE_TRAIN
        if training.any():
            train_rem[training] -= t_d
            fin = training & (train_rem <= 0.0)
            fidx = np.nonzero(fin)[0]
            k = len(fidx)
            if k:
                if policy == "sync":
                    lags = version - pulled_at[fidx]
                    vns = v_norm(version)
                else:
                    # async finishers bump the version one by one, in user
                    # order — each sees the versions of earlier finishers
                    vers = version + np.arange(k)
                    lags = vers - pulled_at[fidx]
                    vns = v_norm(vers)
                    version += k
                updates[fidx] += 1
                mode[fidx] = MODE_COOL
                cooldown[fidx] = cfg.ready_delay
                idle_gap[fidx] = 0.0
                in_flight -= k
                corun_updates += int(np.count_nonzero(corun[fidx]))
                if cfg.collect_push_log:
                    gaps = gradient_gap(vns, lags, cfg.eta, cfg.beta)
                    push_chunks.append((t, fidx, lags, gaps,
                                        corun[fidx].copy()))
        if policy == "sync" and sync_round_open and \
                not np.any(mode == MODE_TRAIN):
            sync_round_open = False
            version += 1

        # --- energy accounting (Eq. 10) --------------------------------
        training = mode == MODE_TRAIN
        p = np.where(training, p_if_train, p_if_idle)
        if cfg.include_scheduler_overhead and policy == "online":
            p = np.where(mode == MODE_WAIT, p + OVERHEAD, p)
        if t_d != 1.0:     # p * 1.0 == p bitwise; skip the alloc
            p *= t_d
        energy += p

        # --- queues -----------------------------------------------------
        sched.update_queues(arrivals, served, gap_sum)
        sum_Q += sched.Q
        sum_H += sched.H
        if t % cfg.trace_every == 0:
            trace_t.append(t)
            trace_E.append(float(energy.sum()))
            trace_Q.append(sched.Q)
            trace_H.append(sched.H)

    push_log = []
    for t, fidx, lags, gaps, cor in push_chunks:
        for j in range(len(fidx)):
            push_log.append({"t": t, "user": int(fidx[j]),
                             "lag": int(lags[j]), "gap": float(gaps[j]),
                             "corun": bool(cor[j])})
    updates_total = int(updates.sum())
    return SimResult(
        energy_j=float(energy.sum()),
        updates=updates_total,
        trace_t=np.array(trace_t), trace_energy=np.array(trace_E),
        trace_Q=np.array(trace_Q), trace_H=np.array(trace_H),
        push_log=push_log, accuracy=[],
        mean_Q=sum_Q / T if T else 0.0,
        mean_H=sum_H / T if T else 0.0,
        corun_fraction=corun_updates / max(updates_total, 1))


def _plan_offline_vec(cfg, t, widx, app, app_sched, app_choice, T_COR,
                      SRATE, plan, vn):
    """Vectorized Alg. 1 window plan (mirrors FederatedSim._plan_offline).

    Candidates are waiting users with an app running now or an (oracle
    lookahead) arrival inside the window; the knapsack picks which of them
    wait to co-run, the rest train immediately. Users without an in-window
    arrival hold until the next plan."""
    if not len(widx):
        return
    W = int(cfg.offline_window)
    horizon = min(t + W, app_sched.shape[0])
    sub = app_sched[t:horizon][:, widx]              # (window, n_waiting)
    has_arr = sub.any(axis=0)
    first = sub.argmax(axis=0)                       # first arrival offset
    ha = app[widx] >= 0
    cand = ha | has_arr
    plan[widx[~cand]] = PLAN_HOLD
    cidx = widx[cand]
    if not len(cidx):
        return
    ta = np.where(ha[cand], t, t + first[cand])
    aid = np.where(ha[cand], app[cidx], app_choice[ta, cidx])
    durs = T_COR[cidx, aid]
    savings = SRATE[cidx, aid] * durs
    lags = lemma1_lag_bounds(np.full(len(cidx), t), ta, durs)
    gaps = np.asarray(gradient_gap(vn, lags, cfg.eta, cfg.beta), dtype=float)
    x, _ = knapsack_schedule(savings, gaps, cfg.L_b,
                             resolution=cfg.offline_resolution)
    plan[cidx] = np.where(x, PLAN_CORUN, PLAN_SEP)


# ======================================================================
# JAX backend: the whole horizon as one lax.scan, jitted per config shape
# ======================================================================
# jnp twins of the shared numpy formulas: np ufuncs don't dispatch on jax
# tracers (this JAX version), so the scan needs its own expressions. Any
# change to the originals MUST land here too — tests/test_sim_engines.py's
# jax-vs-loop parity suite is the tripwire.
def _jax_trace_v_norm(v_norm0, version, jnp):
    """Mirror of simulator.trace_v_norm."""
    return v_norm0 / jnp.sqrt(1.0 + 0.05 * version)


def _jax_gradient_gap(v_norm, lag, eta, beta):
    """Mirror of staleness.gradient_gap/momentum_scale (Eq. 4). beta is a
    traced scalar, so no beta==0 branch: 0**0==1 makes the closed form
    agree at lag=0."""
    return eta * (1.0 - beta ** lag) / (1.0 - beta) * v_norm


@functools.lru_cache(maxsize=16)
def _jax_step_fn(n: int, T: int, policy: str, overhead: bool):
    """Build + jit the scan over slots. Static: shapes, policy, overhead
    flag. Scalar knobs (V, L_b, ...) are traced operands, so e.g. a V-sweep
    compiles once."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def simulate(tables, app_sched, app_choice, scalars):
        PT, TT, PI, PS, P_APP, P_COR, T_COR = tables
        (V, L_b, epsilon, eta, beta, v_norm0, t_d, ready_delay) = scalars
        f = PT.dtype
        i = jnp.asarray(0).dtype     # default int dtype (honors x64)
        ar = jnp.arange(n)

        def step(carry, xs):
            (mode, cooldown, app, app_rem, train_rem, corun, idle_gap,
             pulled_at, energy, updates, version, in_flight, round_open,
             Q, H, sum_Q, sum_H, corun_upd) = carry
            srow, crow = xs

            # apps
            has_app0 = app >= 0
            new_app = srow & ~has_app0
            app_rem = jnp.where(has_app0, app_rem - t_d, app_rem)
            ended = has_app0 & (app_rem <= 0.0)
            app = jnp.where(ended, -1, app)
            app_rem = jnp.where(ended, 0.0, app_rem)
            app = jnp.where(new_app, crow, app)
            aid = jnp.maximum(app, 0)
            tcor_g = T_COR[ar, aid]
            papp_g = P_APP[ar, aid]
            pcor_g = P_COR[ar, aid]
            app_rem = jnp.where(new_app, tcor_g, app_rem)

            # cooldown -> waiting
            cooling = mode == MODE_COOL
            cooldown = jnp.where(cooling, cooldown - 1, cooldown)
            to_wait = cooling & (cooldown <= 0)
            mode = jnp.where(to_wait, MODE_WAIT, mode)
            arrivals = jnp.sum(to_wait)
            waiting = mode == MODE_WAIT
            has_app = app >= 0

            # decisions
            gap_sum = jnp.asarray(0.0, f)
            if policy == "sync":
                open_now = (~round_open) & (jnp.sum(waiting) == n)
                start = waiting & open_now
                round_open = round_open | open_now
            elif policy == "immediate":
                start = waiting
            else:  # online
                vn = _jax_trace_v_norm(v_norm0, version, jnp)
                p_s = jnp.where(has_app, pcor_g, PT)
                p_i = jnp.where(has_app, papp_g, PI)
                base = V * p_s * t_d - Q
                rhs = V * p_i * t_d
                gap_idle_v = idle_gap + epsilon
                lag_idx = in_flight + jnp.arange(n + 1)
                gap_vec = _jax_gradient_gap(vn, lag_idx, eta, beta)

                def fast(_):
                    # H == 0: the gap term adds exactly 0 to both branches
                    sched = waiting & (base <= rhs)
                    before = jnp.cumsum(sched) - sched
                    gaps = jnp.where(sched, gap_vec[before], gap_idle_v)
                    return sched, jnp.sum(jnp.where(waiting, gaps, 0.0))

                def slow(_):
                    # sequential in-slot lag coupling, user-index order
                    def body(c, xs_i):
                        j, gs = c
                        w_i, b_i, r_i, gi_i = xs_i
                        do = w_i & (b_i + H * gap_vec[j] <= r_i + H * gi_i)
                        gap_i = jnp.where(do, gap_vec[j], gi_i)
                        gs = gs + jnp.where(w_i, gap_i, 0.0)
                        return (j + do.astype(i), gs), do
                    (j, gs), sched = lax.scan(
                        body, (jnp.asarray(0, i), jnp.asarray(0.0, f)),
                        (waiting, base, rhs, gap_idle_v))
                    return sched, gs

                start, gap_sum = lax.cond(H > 0.0, slow, fast, None)
                idle_gap = jnp.where(waiting & ~start,
                                     idle_gap + epsilon, idle_gap)
            served = jnp.sum(start)

            # begin training
            mode = jnp.where(start, MODE_TRAIN, mode)
            corun = jnp.where(start, has_app, corun)
            train_rem = jnp.where(start, jnp.where(has_app, tcor_g, TT),
                                  train_rem)
            pulled_at = jnp.where(start, version, pulled_at)
            in_flight = in_flight + served

            # training progression
            training = mode == MODE_TRAIN
            train_rem = jnp.where(training, train_rem - t_d, train_rem)
            fin = training & (train_rem <= 0.0)
            kfin = jnp.sum(fin)
            updates = updates + fin
            mode = jnp.where(fin, MODE_COOL, mode)
            cooldown = jnp.where(fin, ready_delay, cooldown)
            idle_gap = jnp.where(fin, 0.0, idle_gap)
            in_flight = in_flight - kfin
            corun_upd = corun_upd + jnp.sum(fin & corun)
            if policy == "sync":
                closed = round_open & (jnp.sum(mode == MODE_TRAIN) == 0)
                version = version + closed
                round_open = round_open & ~closed
            else:
                version = version + kfin

            # energy (Eq. 10)
            training = mode == MODE_TRAIN
            p = jnp.where(training,
                          jnp.where(has_app, pcor_g, PT),
                          jnp.where(has_app, papp_g, PI))
            if overhead and policy == "online":
                p = jnp.where(mode == MODE_WAIT, p + (PS - PI), p)
            energy = energy + p * t_d

            # queues (Eqs. 15-16)
            Q = jnp.maximum(Q - served, 0.0) + arrivals
            H = jnp.maximum(H + gap_sum - L_b, 0.0)
            sum_Q = sum_Q + Q
            sum_H = sum_H + H
            carry = (mode, cooldown, app, app_rem, train_rem, corun,
                     idle_gap, pulled_at, energy, updates, version,
                     in_flight, round_open, Q, H, sum_Q, sum_H, corun_upd)
            return carry, (Q, H, jnp.sum(energy))

        init = (jnp.full(n, MODE_COOL, i), jnp.zeros(n, i),
                jnp.full(n, -1, i), jnp.zeros(n, f), jnp.zeros(n, f),
                jnp.zeros(n, bool), jnp.zeros(n, f), jnp.zeros(n, i),
                jnp.zeros(n, f), jnp.zeros(n, i), jnp.asarray(0, i),
                jnp.asarray(0, i), jnp.asarray(False),
                jnp.asarray(0.0, f), jnp.asarray(0.0, f),
                jnp.asarray(0.0, f), jnp.asarray(0.0, f), jnp.asarray(0, i))
        carry, traces = lax.scan(step, init, (app_sched, app_choice))
        return carry, traces

    return jax.jit(simulate)


def _run_jax(sim) -> SimResult:
    import jax.numpy as jnp

    cfg = sim.cfg
    if cfg.policy == "offline":  # resolve_engine already reroutes; be safe
        return _run_numpy(sim)
    if cfg.collect_push_log:
        warnings.warn(
            "engine='jax' cannot stream per-push records out of lax.scan; "
            "SimResult.push_log will be empty (set collect_push_log=False "
            "to silence, or use engine='vectorized' for push logs)",
            RuntimeWarning, stacklevel=3)
    n = cfg.n_users
    T = n_slots(cfg)
    PT, TT, PI, PS, P_APP, P_COR, T_COR, _ = _user_tables(sim)
    f = jnp.zeros(0).dtype          # honors jax_enable_x64
    tables = tuple(jnp.asarray(a, f)
                   for a in (PT, TT, PI, PS, P_APP, P_COR, T_COR))
    app_sched = jnp.asarray(sim.app_sched[:T])
    app_choice = jnp.asarray(sim.app_choice[:T], jnp.int32)
    scalars = tuple(jnp.asarray(s, f) for s in (
        cfg.V, cfg.L_b, cfg.epsilon, cfg.eta, cfg.beta, cfg.v_norm0,
        cfg.t_d)) + (jnp.asarray(cfg.ready_delay),)

    fn = _jax_step_fn(n, T, cfg.policy, cfg.include_scheduler_overhead)
    carry, (qs, hs, es) = fn(tables, app_sched, app_choice, scalars)
    energy_total = float(jnp.sum(carry[8]))
    updates_total = int(jnp.sum(carry[9]))
    sum_Q, sum_H = float(carry[15]), float(carry[16])
    corun_updates = int(carry[17])
    idx = np.arange(0, T, cfg.trace_every)
    qs, hs, es = (np.asarray(a, dtype=float) for a in (qs, hs, es))
    return SimResult(
        energy_j=energy_total,
        updates=updates_total,
        trace_t=idx.copy(), trace_energy=es[idx],
        trace_Q=qs[idx], trace_H=hs[idx],
        push_log=[], accuracy=[],
        mean_Q=sum_Q / T if T else 0.0,
        mean_H=sum_H / T if T else 0.0,
        corun_fraction=corun_updates / max(updates_total, 1))
