"""Struct-of-arrays vectorized simulator engine.

Replaces ``FederatedSim``'s per-slot, per-user Python object loop with
batched per-user state arrays — mode, cooldown, app id, app/train remaining,
pulled-at version, energy, idle gap all live in ``(n_users,)`` NumPy arrays,
and the fleet's catalog is flattened into ``(n_devices, n_apps)`` lookup
tables (``FleetSpec.tables``) gathered per user once at startup. Every
phase of a slot — app arrivals, cooldown transitions, policy decisions,
training progression, Eq. (10) energy accounting, Eq. (15)/(16) queue
updates — is a handful of vector ops instead of an O(n) Python loop.

Policy dispatch is pluggable (core/policies.py): the engine exposes its
batched state as ``_NumpyEngine`` attributes and calls the policy's
``decide_vectorized`` hook once per slot; registered paper policies and
any custom policy with the hook run here unmodified.

Real-ML runs are batched too (core/realml.py): with an ``ml_backend`` the
engine snapshots pulls per starting cohort (``pull_batch``) and, when a
slot's trainers finish, dispatches ONE vmap'd local-train over the whole
finisher cohort followed by ordered server pushes
(``_finish_cohort``) — instead of the loop engine's n Python callbacks.
Accuracy is sampled on the same cadence as the loop oracle.

Equivalence contract: seeded runs reproduce the reference loop engine
(``FederatedSim._run_loop``) — identical decision sequences, update counts,
push logs and queue traces; energies match to float-sum reordering
(pairwise vs sequential reduction, ~1e-15 relative). The only sequential
coupling inside a slot is the online policy's lag estimate (every user that
schedules raises the next user's in-flight count); ``OnlineScheduler.
decide_batch`` collapses it to one elementwise comparison when H == 0 (the
gap term then cannot affect the argmin) and replays it exactly otherwise.

``backend="jax"`` additionally compiles the whole trace-mode horizon into a
single ``jax.lax.scan`` over slots (jit-compiled once per (shape, policy
object), scalar knobs like V/L_b passed as traced operands so policy sweeps
reuse the executable). The jax backend covers policies implementing the
``jax_decide`` hook; others (e.g. offline's knapsack DP) stay on the numpy
path. It returns an empty push log (per-push dicts cannot stream out of a
scan); enable jax x64 for f64 parity with the numpy engines.
"""
from __future__ import annotations

import warnings
from types import SimpleNamespace
from typing import List, Tuple

import numpy as np

from .policies import (MODE_COOL, MODE_TRAIN, MODE_WAIT, PLAN_CORUN,
                       PLAN_HOLD, PLAN_SEP)
from .simulator import SimResult, n_slots, trace_v_norm
from .staleness import gradient_gap

__all__ = ["run_vectorized", "MODE_WAIT", "MODE_TRAIN", "MODE_COOL",
           "PLAN_HOLD", "PLAN_CORUN", "PLAN_SEP"]


def run_vectorized(sim, backend: str = "vectorized") -> SimResult:
    """Run ``sim`` (a constructed FederatedSim) on a batched engine."""
    if backend == "jax":
        return _run_jax(sim)
    return _NumpyEngine(sim).run()


def _user_tables(sim):
    """Gather the fleet's catalog rows for each user's device, once per
    run. Any fleet works — the tables come from ``sim.fleet_spec``, not
    the frozen Table II catalog."""
    tab = sim.fleet_spec.tables
    dev = sim.fleet_spec.device_ids
    return (tab.p_train[dev], tab.t_train[dev], tab.p_idle[dev],
            tab.p_sched[dev], tab.p_app[dev], tab.p_corun[dev],
            tab.t_corun[dev], tab.saving_rate[dev])


# ======================================================================
# NumPy backend
# ======================================================================
class _NumpyEngine:
    """Per-run batched state + the slot loop. Policies read/mutate the
    public attributes from their ``decide_vectorized`` hook:

    - ``waiting`` / ``has_app``: this slot's masks (set before dispatch)
    - ``p_if_train`` / ``p_if_idle``: Eq. (10) powers of the train/idle
      branch per user (co-run aware, maintained incrementally)
    - ``idle_gap``, ``plan``, ``app``, ``T_COR``, ``SRATE``, ``app_sched``,
      ``app_choice``: policy-specific state and lookahead tables
    - ``in_flight``, ``version``, ``round_open``: server-side counters
    - ``begin_training(idx)``: schedule users ``idx`` this slot
    - ``v_norm(ver)``: momentum-norm model (honors the ``v_norm`` hook)
    - ``sched``: the OnlineScheduler queue state (Q, H) + decide_batch
    """

    def __init__(self, sim):
        cfg = sim.cfg
        self.cfg = cfg
        self.n = cfg.n_users
        self.T = n_slots(cfg)
        (self.PT, self.TT, self.PI, self.PS, self.P_APP, self.P_COR,
         self.T_COR, self.SRATE) = _user_tables(sim)
        self.OVERHEAD = self.PS - self.PI
        self.app_sched, self.app_choice = sim.app_sched, sim.app_choice
        self.sched = sim.sched             # queue state (Q, H) + decide_batch
        self.policy = sim.policy
        self._v_hook = sim.ml.get("v_norm")
        # batched real-ML backend (core/realml.py): pull/train/push whole
        # cohorts instead of per-user callbacks; None for trace runs
        self.backend = sim.ml_backend
        self.ar = np.arange(self.n)

        # ---- per-user state, struct-of-arrays -------------------------
        n = self.n
        self.mode = np.full(n, MODE_COOL, dtype=np.int8)
        self.cooldown = np.zeros(n, dtype=np.int64)
        self.app = np.full(n, -1, dtype=np.int64)
        self.app_rem = np.zeros(n)
        self.train_rem = np.zeros(n)
        self.corun = np.zeros(n, dtype=bool)
        self.idle_gap = np.zeros(n)
        self.pulled_at = np.zeros(n, dtype=np.int64)
        self.energy = np.zeros(n)
        self.updates = np.zeros(n, dtype=np.int64)
        self.plan = np.full(n, PLAN_HOLD, dtype=np.int8)
        # App-dependent lookups, maintained incrementally on the (rare) app
        # arrival/expiry events instead of re-gathered every slot:
        #   p_if_train  = Eq. 10 power if training (P^{a'} with app, else P^b)
        #   p_if_idle   = Eq. 10 power if not     (P^a with app, else P^d)
        #   t_if_corun  = co-run training duration for the current app
        self.p_if_train = self.PT.copy()
        self.p_if_idle = self.PI.copy()
        self.t_if_corun = np.zeros(n)

        self.version = 0
        self.in_flight = 0
        self.round_open = False
        self.waiting = np.zeros(n, dtype=bool)
        self.has_app = np.zeros(n, dtype=bool)

    def v_norm(self, ver):
        """ver may be a scalar or an array of per-finisher versions; the
        v_norm hook (slot-constant by contract) broadcasts."""
        if self._v_hook is not None:
            return self._v_hook()
        return trace_v_norm(self.cfg.v_norm0, ver)

    def _finish_cohort(self, fidx, lags):
        """Real-ML finish: one batched local-train for the slot's whole
        finisher cohort, then sequential server application in user order
        (the loop oracle's push ordering — each finisher's Eq. (4) gap
        sees the momentum norm left by the previous one). Returns the
        per-finisher gaps for the push log."""
        b = self.backend
        cfg = self.cfg
        if b.sync == self.policy.sync_rounds:
            if b.sync:
                trained = b.local_train_batch(fidx, self.pulled_at[fidx])
                return b.submit_batch(fidx, trained, lags, cfg.eta, cfg.beta)
            return b.finish_async_batch(fidx, self.pulled_at[fidx], lags,
                                        cfg.eta, cfg.beta,
                                        need_gaps=cfg.collect_push_log)
        # policy/backend round-mode mismatch: the loop oracle finds no
        # matching hook and skips training; keep the log gaps consistent
        return np.asarray(gradient_gap(b.v_norm(), lags, cfg.eta, cfg.beta),
                          dtype=float)

    def begin_training(self, idx):
        """idx: user indices starting training this slot (corun iff app)."""
        ha = self.app[idx] >= 0
        self.corun[idx] = ha
        self.train_rem[idx] = np.where(ha, self.t_if_corun[idx],
                                       self.TT[idx])
        self.mode[idx] = MODE_TRAIN
        self.pulled_at[idx] = self.version
        self.in_flight += len(idx)
        if self.backend is not None:
            self.backend.pull_batch(np.asarray(idx), self.version)

    def run(self) -> SimResult:
        cfg = self.cfg
        policy = self.policy
        t_d = cfg.t_d
        n, T = self.n, self.T
        sched = self.sched
        app_sched, app_choice = self.app_sched, self.app_choice
        mode, app, app_rem = self.mode, self.app, self.app_rem
        pstate = policy.vec_init(self)

        sum_Q = sum_H = 0.0
        corun_updates = 0
        trace_t: List[int] = []
        trace_E: List[float] = []
        trace_Q: List[float] = []
        trace_H: List[float] = []
        accuracy: List[Tuple] = []
        eval_every = self.backend.eval_every if self.backend is not None \
            else 0
        # push log collected as per-slot array chunks, expanded at the end
        push_chunks: List[Tuple] = []

        for t in range(T):
            # --- app arrivals / progression -------------------------------
            srow = app_sched[t]
            has_app = app >= 0
            new_app = srow & ~has_app
            if has_app.any():
                app_rem[has_app] -= t_d
                ended = has_app & (app_rem <= 0.0)
                if ended.any():
                    app[ended] = -1
                    app_rem[ended] = 0.0
                    self.p_if_train[ended] = self.PT[ended]
                    self.p_if_idle[ended] = self.PI[ended]
            if new_app.any():
                nidx = np.nonzero(new_app)[0]
                aid = app_choice[t, nidx]
                app[nidx] = aid
                app_rem[nidx] = self.T_COR[nidx, aid]
                self.p_if_train[nidx] = self.P_COR[nidx, aid]
                self.p_if_idle[nidx] = self.P_APP[nidx, aid]
                self.t_if_corun[nidx] = self.T_COR[nidx, aid]

            # --- cooldown -> waiting (queue arrival) -----------------------
            arrivals = 0
            cooling = mode == MODE_COOL
            if cooling.any():
                self.cooldown[cooling] -= 1
                to_wait = cooling & (self.cooldown <= 0)
                arrivals = int(np.count_nonzero(to_wait))
                if arrivals:
                    mode[to_wait] = MODE_WAIT
                    self.plan[to_wait] = PLAN_HOLD
            self.waiting = mode == MODE_WAIT
            self.has_app = app >= 0

            # --- policy decisions for waiting users ------------------------
            served, gap_sum = policy.decide_vectorized(self, t, pstate)

            # --- training progression --------------------------------------
            training = mode == MODE_TRAIN
            if training.any():
                self.train_rem[training] -= t_d
                fin = training & (self.train_rem <= 0.0)
                fidx = np.nonzero(fin)[0]
                k = len(fidx)
                if k:
                    gaps = None
                    if policy.sync_rounds:
                        lags = self.version - self.pulled_at[fidx]
                        if self.backend is None and cfg.collect_push_log:
                            gaps = gradient_gap(self.v_norm(self.version),
                                                lags, cfg.eta, cfg.beta)
                    else:
                        # async finishers bump the version one by one, in
                        # user order — each sees the versions of earlier
                        # finishers
                        vers = self.version + np.arange(k)
                        lags = vers - self.pulled_at[fidx]
                        if self.backend is None and cfg.collect_push_log:
                            gaps = gradient_gap(self.v_norm(vers), lags,
                                                cfg.eta, cfg.beta)
                        self.version += k
                    if self.backend is not None:
                        # one vmap'd local-train + ordered server pushes
                        gaps = self._finish_cohort(fidx, lags)
                    self.updates[fidx] += 1
                    mode[fidx] = MODE_COOL
                    self.cooldown[fidx] = cfg.ready_delay
                    self.idle_gap[fidx] = 0.0
                    self.in_flight -= k
                    corun_updates += int(np.count_nonzero(self.corun[fidx]))
                    if cfg.collect_push_log:
                        push_chunks.append((t, fidx, lags, gaps,
                                            self.corun[fidx].copy()))
            if policy.sync_rounds and self.round_open and \
                    not np.any(mode == MODE_TRAIN):
                self.round_open = False
                self.version += 1
                if self.backend is not None and self.backend.sync:
                    self.backend.sync_aggregate()

            # --- energy accounting (Eq. 10) --------------------------------
            training = mode == MODE_TRAIN
            p = np.where(training, self.p_if_train, self.p_if_idle)
            if cfg.include_scheduler_overhead and policy.uses_online_queue:
                p = np.where(mode == MODE_WAIT, p + self.OVERHEAD, p)
            if t_d != 1.0:     # p * 1.0 == p bitwise; skip the alloc
                p *= t_d
            self.energy += p

            # --- queues -----------------------------------------------------
            sched.update_queues(arrivals, served, gap_sum)
            sum_Q += sched.Q
            sum_H += sched.H
            if t % cfg.trace_every == 0:
                trace_t.append(t)
                trace_E.append(float(self.energy.sum()))
                trace_Q.append(sched.Q)
                trace_H.append(sched.H)
            if eval_every and t % eval_every == 0 and t > 0:
                accuracy.append((t, self.backend.evaluate()))

        if self.backend is not None:
            accuracy.append((T, self.backend.evaluate()))
        push_log = []
        for t, fidx, lags, gaps, cor in push_chunks:
            for j in range(len(fidx)):
                push_log.append({"t": t, "user": int(fidx[j]),
                                 "lag": int(lags[j]), "gap": float(gaps[j]),
                                 "corun": bool(cor[j])})
        updates_total = int(self.updates.sum())
        return SimResult(
            energy_j=float(self.energy.sum()),
            updates=updates_total,
            trace_t=np.array(trace_t), trace_energy=np.array(trace_E),
            trace_Q=np.array(trace_Q), trace_H=np.array(trace_H),
            push_log=push_log, accuracy=accuracy,
            mean_Q=sum_Q / T if T else 0.0,
            mean_H=sum_H / T if T else 0.0,
            corun_fraction=corun_updates / max(updates_total, 1))


# ======================================================================
# JAX backend: the whole horizon as one lax.scan, jitted per config shape
# ======================================================================
_JAX_FN_CACHE: dict = {}
_JAX_FN_CACHE_MAX = 16


def _jax_step_fn(n: int, T: int, policy, overhead: bool):
    """Build + jit the scan over slots, memoized on (shapes,
    ``policy.jax_cache_key()``, overhead flag). Parameter-free registry
    policies key by class, so both ``SimConfig(policy="online")`` and a
    fresh ``OnlinePolicy()`` per run share one executable; scalar knobs
    (V, L_b, ...) are traced operands, so e.g. a V-sweep compiles once.
    The policy's ``jax_decide`` hook supplies the decision block;
    everything else — arrivals, cooldowns, training progression, Eq. 10
    energy, Eq. 15/16 queues — is engine code shared by every policy."""
    key = (n, T, policy.jax_cache_key(), overhead)
    fn = _JAX_FN_CACHE.pop(key, None)   # pop+reinsert = LRU order
    if fn is None:
        fn = _build_jax_step_fn(n, T, policy, overhead)
        if len(_JAX_FN_CACHE) >= _JAX_FN_CACHE_MAX:
            _JAX_FN_CACHE.pop(next(iter(_JAX_FN_CACHE)))  # evict LRU
    _JAX_FN_CACHE[key] = fn
    return fn


def _build_jax_step_fn(n: int, T: int, policy, overhead: bool):
    import jax
    import jax.numpy as jnp
    from jax import lax

    def simulate(tables, app_sched, app_choice, scalars):
        PT, TT, PI, PS, P_APP, P_COR, T_COR = tables
        (V, L_b, epsilon, eta, beta, v_norm0, t_d, ready_delay) = scalars
        f = PT.dtype
        i = jnp.asarray(0).dtype     # default int dtype (honors x64)
        ar = jnp.arange(n)

        def step(carry, xs):
            (mode, cooldown, app, app_rem, train_rem, corun, idle_gap,
             pulled_at, energy, updates, version, in_flight, round_open,
             Q, H, sum_Q, sum_H, corun_upd) = carry
            srow, crow = xs

            # apps
            has_app0 = app >= 0
            new_app = srow & ~has_app0
            app_rem = jnp.where(has_app0, app_rem - t_d, app_rem)
            ended = has_app0 & (app_rem <= 0.0)
            app = jnp.where(ended, -1, app)
            app_rem = jnp.where(ended, 0.0, app_rem)
            app = jnp.where(new_app, crow, app)
            aid = jnp.maximum(app, 0)
            tcor_g = T_COR[ar, aid]
            papp_g = P_APP[ar, aid]
            pcor_g = P_COR[ar, aid]
            app_rem = jnp.where(new_app, tcor_g, app_rem)

            # cooldown -> waiting
            cooling = mode == MODE_COOL
            cooldown = jnp.where(cooling, cooldown - 1, cooldown)
            to_wait = cooling & (cooldown <= 0)
            mode = jnp.where(to_wait, MODE_WAIT, mode)
            arrivals = jnp.sum(to_wait)
            waiting = mode == MODE_WAIT
            has_app = app >= 0

            # decisions: the policy's jax hook, on a mutable slot view
            sv = SimpleNamespace(
                jnp=jnp, lax=lax, n=n, float_dtype=f, int_dtype=i,
                waiting=waiting, has_app=has_app,
                pcor_g=pcor_g, papp_g=papp_g, tcor_g=tcor_g,
                PT=PT, TT=TT, PI=PI, PS=PS,
                idle_gap=idle_gap, in_flight=in_flight, version=version,
                round_open=round_open, Q=Q, H=H,
                V=V, L_b=L_b, epsilon=epsilon, eta=eta, beta=beta,
                v_norm0=v_norm0, t_d=t_d)
            start, gap_sum = policy.jax_decide(sv)
            idle_gap = sv.idle_gap
            round_open = sv.round_open
            served = jnp.sum(start)

            # begin training
            mode = jnp.where(start, MODE_TRAIN, mode)
            corun = jnp.where(start, has_app, corun)
            train_rem = jnp.where(start, jnp.where(has_app, tcor_g, TT),
                                  train_rem)
            pulled_at = jnp.where(start, version, pulled_at)
            in_flight = in_flight + served

            # training progression
            training = mode == MODE_TRAIN
            train_rem = jnp.where(training, train_rem - t_d, train_rem)
            fin = training & (train_rem <= 0.0)
            kfin = jnp.sum(fin)
            updates = updates + fin
            mode = jnp.where(fin, MODE_COOL, mode)
            cooldown = jnp.where(fin, ready_delay, cooldown)
            idle_gap = jnp.where(fin, 0.0, idle_gap)
            in_flight = in_flight - kfin
            corun_upd = corun_upd + jnp.sum(fin & corun)
            if policy.sync_rounds:
                closed = round_open & (jnp.sum(mode == MODE_TRAIN) == 0)
                version = version + closed
                round_open = round_open & ~closed
            else:
                version = version + kfin

            # energy (Eq. 10)
            training = mode == MODE_TRAIN
            p = jnp.where(training,
                          jnp.where(has_app, pcor_g, PT),
                          jnp.where(has_app, papp_g, PI))
            if overhead and policy.uses_online_queue:
                p = jnp.where(mode == MODE_WAIT, p + (PS - PI), p)
            energy = energy + p * t_d

            # queues (Eqs. 15-16)
            Q = jnp.maximum(Q - served, 0.0) + arrivals
            H = jnp.maximum(H + gap_sum - L_b, 0.0)
            sum_Q = sum_Q + Q
            sum_H = sum_H + H
            carry = (mode, cooldown, app, app_rem, train_rem, corun,
                     idle_gap, pulled_at, energy, updates, version,
                     in_flight, round_open, Q, H, sum_Q, sum_H, corun_upd)
            return carry, (Q, H, jnp.sum(energy))

        init = (jnp.full(n, MODE_COOL, i), jnp.zeros(n, i),
                jnp.full(n, -1, i), jnp.zeros(n, f), jnp.zeros(n, f),
                jnp.zeros(n, bool), jnp.zeros(n, f), jnp.zeros(n, i),
                jnp.zeros(n, f), jnp.zeros(n, i), jnp.asarray(0, i),
                jnp.asarray(0, i), jnp.asarray(False),
                jnp.asarray(0.0, f), jnp.asarray(0.0, f),
                jnp.asarray(0.0, f), jnp.asarray(0.0, f), jnp.asarray(0, i))
        carry, traces = lax.scan(step, init, (app_sched, app_choice))
        return carry, traces

    return jax.jit(simulate)


def _run_jax(sim) -> SimResult:
    import jax.numpy as jnp

    cfg = sim.cfg
    if not sim.policy.supports_jax:  # resolve_engine reroutes; be safe
        return _NumpyEngine(sim).run()
    if cfg.collect_push_log:
        warnings.warn(
            "engine='jax' cannot stream per-push records out of lax.scan; "
            "SimResult.push_log will be empty (set collect_push_log=False "
            "to silence, or use engine='vectorized' for push logs)",
            RuntimeWarning, stacklevel=3)
    n = cfg.n_users
    T = n_slots(cfg)
    PT, TT, PI, PS, P_APP, P_COR, T_COR, _ = _user_tables(sim)
    f = jnp.zeros(0).dtype          # honors jax_enable_x64
    tables = tuple(jnp.asarray(a, f)
                   for a in (PT, TT, PI, PS, P_APP, P_COR, T_COR))
    app_sched = jnp.asarray(sim.app_sched[:T])
    app_choice = jnp.asarray(sim.app_choice[:T], jnp.int32)
    scalars = tuple(jnp.asarray(s, f) for s in (
        cfg.V, cfg.L_b, cfg.epsilon, cfg.eta, cfg.beta, cfg.v_norm0,
        cfg.t_d)) + (jnp.asarray(cfg.ready_delay),)

    fn = _jax_step_fn(n, T, sim.policy, cfg.include_scheduler_overhead)
    carry, (qs, hs, es) = fn(tables, app_sched, app_choice, scalars)
    energy_total = float(jnp.sum(carry[8]))
    updates_total = int(jnp.sum(carry[9]))
    sum_Q, sum_H = float(carry[15]), float(carry[16])
    corun_updates = int(carry[17])
    idx = np.arange(0, T, cfg.trace_every)
    qs, hs, es = (np.asarray(a, dtype=float) for a in (qs, hs, es))
    return SimResult(
        energy_j=energy_total,
        updates=updates_total,
        trace_t=idx.copy(), trace_energy=es[idx],
        trace_Q=qs[idx], trace_H=hs[idx],
        push_log=[], accuracy=[],
        mean_Q=sum_Q / T if T else 0.0,
        mean_H=sum_H / T if T else 0.0,
        corun_fraction=corun_updates / max(updates_total, 1))
