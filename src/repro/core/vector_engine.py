"""Struct-of-arrays vectorized simulator engine.

Replaces ``FederatedSim``'s per-slot, per-user Python object loop with
batched per-user state arrays — the run's ``EngineState``
(core/engine_state.py): mode, cooldown, app id, app/train remaining,
pulled-at version, energy, idle gap all live in ``(n_users,)`` NumPy
arrays, and the fleet's catalog is flattened into ``(n_devices, n_apps)``
lookup tables (``FleetSpec.tables``) gathered per user once at startup.
Every phase of a slot — app arrivals, cooldown transitions, policy
decisions, training progression, Eq. (10) energy accounting, Eq. (15)/(16)
queue updates — is a handful of vector ops instead of an O(n) Python loop.

Policy dispatch is pluggable (core/policies.py): the engine exposes the
shared state as ``eng.s`` (an ``EngineState``) plus per-slot masks and
catalog gathers, threads the policy's carry pytree
(``Policy.init_carry``), and calls the ``decide_vectorized`` hook once per
slot; registered paper policies and any custom policy with the hook run
here unmodified.

Real-ML runs are batched too (core/realml.py): with an ``ml_backend`` the
engine snapshots pulls per starting cohort (``pull_batch``, at the
EngineState's global version) and, when a slot's trainers finish,
dispatches ONE vmap'd local-train over the whole finisher cohort followed
by ordered server pushes (``_finish_cohort``) — instead of the loop
engine's n Python callbacks. Accuracy is sampled on the same cadence as
the loop oracle.

Equivalence contract: seeded runs reproduce the reference loop engine
(``FederatedSim._run_loop``) — identical decision sequences, update counts,
push logs and queue traces; energies match to float-sum reordering
(pairwise vs sequential reduction, ~1e-15 relative). The only sequential
coupling inside a slot is the online policy's lag estimate (every user that
schedules raises the next user's in-flight count); ``OnlineScheduler.
decide_batch`` collapses it to one elementwise comparison when H == 0 (the
gap term then cannot affect the argmin) and replays it exactly otherwise.

``backend="jax"`` compiles the horizon into ``lax.scan`` chunks of
``SimConfig.jax_chunk`` slots whose carry is the SAME ``EngineState``
pytree (jit-compiled once per (shape, policy class); scalar knobs like
V/L_b and policy ``scan_operands`` passed as traced operands so sweeps
reuse the executable). The jax backend covers every policy implementing
the ``scan_step`` carry hook — all registry policies, including offline
(host knapsack via ``jax.pure_callback`` at plan slots) and greedy (wait
counters carried through the scan); others stay on the numpy path.

Push logs stream out of the scan through a fixed-width event buffer
(``engine_state.PushBuffer``): each finishing user scatters one
``(t, user, lag, gap, corun, weight)`` row at the buffer cursor — the
``weight`` column is the aggregation rule's applied mixing weight
(core/aggregation.py, ``SimConfig.aggregation``), computed in-jit through
the rule's ``scan_weight`` hook with its carry riding in
``EngineState.agg_carry`` — the host drains
and resets the buffer after every chunk, and an overflowing chunk is
re-run with a doubled buffer (``count`` always records the true push
total) — so ``collect_push_log=True`` costs O(chunk) memory at any fleet
size, never O(T * n). Enable jax x64 for f64 parity with the numpy
engines; in f32, user ids stay exact up to 2**24.

``SimConfig.n_devices`` > 0 shards the SAME chunked scan over a 1-D
``("users",)`` mesh (launch/mesh.py ``make_sim_mesh``) via GSPMD
constraint steering: per-user EngineState leaves, catalog gathers and
arrival columns carry ``PartitionSpec("users")`` constraints, scheduler
scalars stay replicated, and XLA's SPMD partitioner inserts the
collectives. Bit-consistency with the single-device scan is by
construction, not luck: every input of the POLICY DECISION phase is
constrained replicated before the ``scan_step`` hook runs, so Alg. 2's
float reductions (Eq. 16's gap sum feeding H) compile to the exact
single-device reduction order — a shard-local partial sum + AllReduce
would reassociate them and could flip a decision. The surrounding
per-user phases (arrivals, training progression, Eq. 10 energy, churn)
stay sharded; their cross-user reductions are integer counts, which
psum exactly. A non-divisible ``n_users`` pads the axis with INERT rows
(``engine_state.pad_state_per_user``: MODE_OFF, zeroed catalog rows,
arrival columns that never fire, dynamics rows pinned up) and
stochastic hooks draw at the LIVE n, padding draws with fill 1.0
(threefry output is shape-dependent) — so push logs, queue traces and
decisions are digest-identical to the unsharded engine at any
(n, mesh) combination; only energy sums differ by float reduction
order. ``jax_chunk=0`` / ``push_log_capacity=0`` auto-tune from the
per-device memory budget (core/autotune.py).
"""
from __future__ import annotations

import logging
from types import SimpleNamespace
from typing import List, Tuple

import numpy as np

from .engine_state import (EngineState, PushBuffer, PushLog, MODE_COOL,
                           MODE_OFF, MODE_TRAIN, MODE_WAIT, PLAN_CORUN,
                           PLAN_HOLD, PLAN_SEP, _PER_USER_FIELDS,
                           pad_state_per_user, pad_to_devices,
                           state_shardings, unpad_state_per_user)
from .policies import _jax_gradient_gap, _jax_trace_v_norm
from .simulator import SimResult, n_slots, trace_v_norm
from .staleness import gradient_gap

__all__ = ["run_vectorized", "run_jax_sweep", "sweep_bucket_key",
           "jax_cache_stats", "reserve_jax_cache_capacity",
           "MODE_WAIT", "MODE_TRAIN", "MODE_COOL",
           "PLAN_HOLD", "PLAN_CORUN", "PLAN_SEP"]

_LOG = logging.getLogger(__name__)


def run_vectorized(sim, backend: str = "vectorized") -> SimResult:
    """Run ``sim`` (a constructed FederatedSim) on a batched engine."""
    if backend == "jax":
        return _run_jax(sim)
    return _NumpyEngine(sim).run()


def _user_tables(sim):
    """Gather the fleet's catalog rows for each user's device, once per
    run. Any fleet works — the tables come from ``sim.fleet_spec``, not
    the frozen Table II catalog."""
    tab = sim.fleet_spec.tables
    dev = sim.fleet_spec.device_ids
    return (tab.p_train[dev], tab.t_train[dev], tab.p_idle[dev],
            tab.p_sched[dev], tab.p_app[dev], tab.p_corun[dev],
            tab.t_corun[dev], tab.saving_rate[dev])


# ======================================================================
# NumPy backend
# ======================================================================
class _NumpyEngine:
    """Per-run slot loop over the shared ``EngineState``. Policies
    read/mutate state from their ``decide_vectorized`` hook:

    - ``s``: the run's ``EngineState`` (``sim.state``) — per-user arrays,
      scheduler scalars (version, in_flight, round_open, Q, H) and the
      policy carry
    - ``waiting`` / ``has_app``: this slot's masks (set before dispatch)
    - ``p_if_train`` / ``p_if_idle``: Eq. (10) powers of the train/idle
      branch per user (co-run aware, maintained incrementally — derived
      caches over ``s.app``, not canonical state)
    - ``T_COR``, ``SRATE``, ``app_sched``, ``app_choice``: lookahead tables
    - ``begin_training(idx)``: schedule users ``idx`` this slot
    - ``v_norm(ver)``: momentum-norm model (honors the ``v_norm`` hook)
    - ``sched``: the OnlineScheduler queue-update rule + decide_batch
      (``s.Q``/``s.H`` mirror its state after every slot)
    """

    def __init__(self, sim):
        cfg = sim.cfg
        self.cfg = cfg
        self.n = cfg.n_users
        self.T = n_slots(cfg)
        (self.PT, self.TT, self.PI, self.PS, self.P_APP, self.P_COR,
         self.T_COR, self.SRATE) = _user_tables(sim)
        self.OVERHEAD = self.PS - self.PI
        self.app_sched, self.app_choice = sim.app_sched, sim.app_choice
        self.sched = sim.sched             # queue update rule + decide_batch
        self.policy = sim.policy
        self.agg = sim.agg                 # aggregation rule (weight path)
        self.dynamics = sim.dynamics       # device churn (core/dynamics.py)
        self.fleet_spec = sim.fleet_spec
        self._v_hook = sim.ml.get("v_norm")
        # batched real-ML backend (core/realml.py): pull/train/push whole
        # cohorts instead of per-user callbacks; None for trace runs
        self.backend = sim.ml_backend
        self.ar = np.arange(self.n)

        # ---- the shared state container -------------------------------
        self.s = sim.state
        # App-dependent lookups, maintained incrementally on the (rare) app
        # arrival/expiry events instead of re-gathered every slot:
        #   p_if_train  = Eq. 10 power if training (P^{a'} with app, else P^b)
        #   p_if_idle   = Eq. 10 power if not     (P^a with app, else P^d)
        #   t_if_corun  = co-run training duration for the current app
        self.p_if_train = self.PT.copy()
        self.p_if_idle = self.PI.copy()
        self.t_if_corun = np.zeros(self.n)

        self.waiting = np.zeros(self.n, dtype=bool)
        self.has_app = np.zeros(self.n, dtype=bool)

    def v_norm(self, ver):
        """ver may be a scalar or an array of per-finisher versions; the
        v_norm hook (slot-constant by contract) broadcasts."""
        if self._v_hook is not None:
            return self._v_hook()
        return trace_v_norm(self.cfg.v_norm0, ver)

    def _finish_cohort(self, fidx, lags):
        """Real-ML finish: one batched local-train for the slot's whole
        finisher cohort, then sequential server application in user order
        (the loop oracle's push ordering — each finisher's Eq. (4) gap
        sees the momentum norm left by the previous one). Returns the
        per-finisher ``(gaps, weights)`` for the push log."""
        b = self.backend
        cfg = self.cfg
        if b.sync == self.policy.sync_rounds:
            if b.sync:
                trained = b.local_train_batch(fidx, self.s.pulled_at[fidx])
                return b.submit_batch(fidx, trained, lags, cfg.eta,
                                      cfg.beta)
            return b.finish_async_batch(fidx, self.s.pulled_at[fidx], lags,
                                        cfg.eta, cfg.beta,
                                        need_gaps=cfg.collect_push_log)
        # policy/backend round-mode mismatch: the loop oracle finds no
        # matching hook and skips training; keep the log gaps AND the
        # rule-fallback weights consistent with the oracle's
        vn = b.v_norm()
        gaps = np.asarray(gradient_gap(vn, lags, cfg.eta, cfg.beta),
                          dtype=float)
        if self.policy.sync_rounds:
            return gaps, np.ones(len(lags))
        return gaps, np.asarray(self.agg.weight(lags, gaps, vn,
                                                fleet=self.fleet_spec,
                                                users=fidx), dtype=float)

    def begin_training(self, idx):
        """idx: user indices starting training this slot (corun iff app)."""
        s = self.s
        ha = s.app[idx] >= 0
        s.corun[idx] = ha
        s.train_rem[idx] = np.where(ha, self.t_if_corun[idx], self.TT[idx])
        s.mode[idx] = MODE_TRAIN
        s.pulled_at[idx] = s.version
        s.in_flight += len(idx)
        if self.backend is not None:
            self.backend.pull_batch(np.asarray(idx), s.version)

    def run(self) -> SimResult:
        cfg = self.cfg
        policy = self.policy
        t_d = cfg.t_d
        n, T = self.n, self.T
        s = self.s
        sched = self.sched
        app_sched, app_choice = self.app_sched, self.app_choice
        mode, app, app_rem = s.mode, s.app, s.app_rem
        carry = s.carry

        trace_t: List[int] = []
        trace_E: List[float] = []
        trace_Q: List[float] = []
        trace_H: List[float] = []
        accuracy: List[Tuple] = []
        eval_every = self.backend.eval_every if self.backend is not None \
            else 0
        push_log = PushLog()      # fixed-width blocks, decoded lazily
        dynamics = self.dynamics
        dyn_active = dynamics.active
        dyn_lose = dynamics.dropout == "lose"
        up = net_extra = None

        for t in range(T):
            departures = 0

            # --- device dynamics (churn) -----------------------------------
            # Same shared host transition as the loop oracle, effects
            # applied as masked writes: waiting -> off is a queue
            # departure, training -> off follows the dropout rule,
            # cooling parks in off, and recovered users re-enter through
            # cooldown with the network state's extra delay.
            if dyn_active:
                s.dyn, s.rng_key, eff = dynamics.host_step(
                    s.dyn, s.rng_key, mode, s.corun, t_d)
                up = np.asarray(eff.up)
                net_extra = np.asarray(eff.net_extra)
                wd = np.asarray(eff.went_down)
                if wd.any():
                    dwait = wd & (mode == MODE_WAIT)
                    dtrain = wd & (mode == MODE_TRAIN)
                    dcool = wd & (mode == MODE_COOL)
                    departures = int(np.count_nonzero(dwait))
                    mode[dwait | dcool] = MODE_OFF
                    if dyn_lose:
                        mode[dtrain] = MODE_OFF
                        s.train_rem[dtrain] = 0.0
                        s.in_flight -= int(np.count_nonzero(dtrain))
                    else:       # resume: paused, pays the extra seconds
                        s.train_rem[dtrain] += float(eff.resume_penalty)
                ret = np.asarray(eff.went_up) & (mode == MODE_OFF)
                if ret.any():
                    mode[ret] = MODE_COOL
                    s.cooldown[ret] = cfg.ready_delay + net_extra[ret]

            # --- app arrivals / progression -------------------------------
            srow = app_sched[t]
            has_app = app >= 0
            new_app = srow & ~has_app
            if has_app.any():
                app_rem[has_app] -= t_d
                ended = has_app & (app_rem <= 0.0)
                if ended.any():
                    app[ended] = -1
                    app_rem[ended] = 0.0
                    self.p_if_train[ended] = self.PT[ended]
                    self.p_if_idle[ended] = self.PI[ended]
            if new_app.any():
                nidx = np.nonzero(new_app)[0]
                aid = app_choice[t, nidx]
                app[nidx] = aid
                app_rem[nidx] = self.T_COR[nidx, aid]
                self.p_if_train[nidx] = self.P_COR[nidx, aid]
                self.p_if_idle[nidx] = self.P_APP[nidx, aid]
                self.t_if_corun[nidx] = self.T_COR[nidx, aid]

            # --- cooldown -> waiting (queue arrival) -----------------------
            arrivals = 0
            cooling = mode == MODE_COOL
            if cooling.any():
                s.cooldown[cooling] -= 1
                to_wait = cooling & (s.cooldown <= 0)
                arrivals = int(np.count_nonzero(to_wait))
                if arrivals:
                    mode[to_wait] = MODE_WAIT
                    s.plan[to_wait] = PLAN_HOLD
            self.waiting = mode == MODE_WAIT
            self.has_app = app >= 0

            # --- policy decisions for waiting users ------------------------
            served, gap_sum = policy.decide_vectorized(self, t, carry)

            # --- training progression --------------------------------------
            # under churn a down trainer is paused (resume rule) and
            # makes no progress
            training = (mode == MODE_TRAIN) & up if dyn_active \
                else mode == MODE_TRAIN
            if training.any():
                s.train_rem[training] -= t_d
                fin = training & (s.train_rem <= 0.0)
                fidx = np.nonzero(fin)[0]
                k = len(fidx)
                if k:
                    gaps = weights = None
                    if policy.sync_rounds:
                        lags = s.version - s.pulled_at[fidx]
                        if self.backend is None and cfg.collect_push_log:
                            gaps = gradient_gap(self.v_norm(s.version),
                                                lags, cfg.eta, cfg.beta)
                            # FedAvg rounds average; no per-push weight
                            weights = np.ones(k)
                    else:
                        # async finishers bump the version one by one, in
                        # user order — each sees the versions of earlier
                        # finishers
                        vers = s.version + np.arange(k)
                        lags = vers - s.pulled_at[fidx]
                        if self.backend is None and cfg.collect_push_log:
                            vns = self.v_norm(vers)
                            gaps = gradient_gap(vns, lags, cfg.eta,
                                                cfg.beta)
                            weights = self.agg.weight(
                                lags, gaps, vns, fleet=self.fleet_spec,
                                users=fidx)
                        s.version += k
                    if self.backend is not None:
                        # one vmap'd local-train + ordered server pushes
                        gaps, weights = self._finish_cohort(fidx, lags)
                    s.updates[fidx] += 1
                    mode[fidx] = MODE_COOL
                    s.cooldown[fidx] = cfg.ready_delay if not dyn_active \
                        else cfg.ready_delay + net_extra[fidx]
                    s.idle_gap[fidx] = 0.0
                    s.in_flight -= k
                    s.corun_updates += int(np.count_nonzero(s.corun[fidx]))
                    if cfg.collect_push_log:
                        push_log.extend(t, fidx, lags, gaps, s.corun[fidx],
                                        weights)
            if policy.sync_rounds and s.round_open and \
                    not np.any(mode == MODE_TRAIN):
                s.round_open = False
                s.version += 1
                if self.backend is not None and self.backend.sync:
                    self.backend.sync_aggregate()

            # --- energy accounting (Eq. 10) --------------------------------
            training = mode == MODE_TRAIN
            p = np.where(training, self.p_if_train, self.p_if_idle)
            if cfg.include_scheduler_overhead and policy.uses_online_queue:
                p = np.where(mode == MODE_WAIT, p + self.OVERHEAD, p)
            if dyn_active:     # a down device draws nothing
                p = np.where(up, p, 0.0)
            if t_d != 1.0:     # p * 1.0 == p bitwise; skip the alloc
                p *= t_d
            s.energy += p

            # --- queues -----------------------------------------------------
            sched.update_queues(arrivals, served, gap_sum, departures)
            s.Q, s.H = sched.Q, sched.H
            s.sum_Q += s.Q
            s.sum_H += s.H
            if t % cfg.trace_every == 0:
                trace_t.append(t)
                trace_E.append(float(s.energy.sum()))
                trace_Q.append(s.Q)
                trace_H.append(s.H)
            if eval_every and t % eval_every == 0 and t > 0:
                accuracy.append((t, self.backend.evaluate()))

        if self.backend is not None:
            accuracy.append((T, self.backend.evaluate()))
        updates_total = int(s.updates.sum())
        return SimResult(
            energy_j=float(s.energy.sum()),
            updates=updates_total,
            trace_t=np.array(trace_t), trace_energy=np.array(trace_E),
            trace_Q=np.array(trace_Q), trace_H=np.array(trace_H),
            push_log=push_log, accuracy=accuracy,
            mean_Q=s.sum_Q / T if T else 0.0,
            mean_H=s.sum_H / T if T else 0.0,
            corun_fraction=s.corun_updates / max(updates_total, 1),
            drops=self.dynamics.total_drops(s.dyn))


# ======================================================================
# JAX backend: the horizon as chunked lax.scans over the EngineState
# pytree, jitted per (shape, policy class, chunk, buffer capacity)
# ======================================================================
_JAX_FN_CACHE: dict = {}
_JAX_FN_CACHE_MAX = 32
_JAX_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def jax_cache_stats() -> dict:
    """Counters for the jitted-chunk cache: ``hits`` (executable reused),
    ``misses`` (a trace + compile happened), ``evictions`` (an LRU entry
    was dropped to make room — if these climb during a sweep the cap is
    too small; see :func:`reserve_jax_cache_capacity`)."""
    return dict(_JAX_CACHE_STATS)


def reserve_jax_cache_capacity(k: int) -> None:
    """Raise (never lower) the jitted-chunk cache cap so every bucket of
    a shape-bucketed sweep stays resident for the sweep's whole lifetime.
    ``run_sweep`` calls this before running its buckets; evicting a hot
    bucket mid-sweep would silently recompile it on the next chunk."""
    global _JAX_FN_CACHE_MAX
    _JAX_FN_CACHE_MAX = max(_JAX_FN_CACHE_MAX, int(k))


def _mesh_key(mesh):
    """Hashable signature of a sharding mesh for the executable caches:
    axis names, axis sizes AND the concrete device ids — two meshes over
    different devices must never alias one executable (their compiled
    collectives bake in device assignments). ``None`` = unsharded."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in np.asarray(mesh.devices).flat))


def _jax_chunk_fn(n: int, chunk: int, T: int, policy, overhead: bool,
                  collect: bool, capacity: int, statics: tuple = (),
                  agg=None, dynamics=None, batch: int = 0,
                  mesh=None, n_arr: int = 0):
    """Build + jit one scan chunk, memoized on (shapes,
    ``policy.jax_cache_key()``, overhead/collect flags, event-buffer
    capacity, the policy's ``scan_statics``, and — when the push log is
    collected — the aggregation rule's ``jax_cache_key()``). Policies
    and rules key by class by default, so both
    ``SimConfig(policy="online")`` and a fresh ``OnlinePolicy()`` per
    run share one executable; scalar knobs (V, L_b, ...,
    ``scan_operands``) are traced operands, so e.g. a V-sweep compiles
    once. With ``batch`` > 0 the chunk is ``jax.vmap``-ped over a
    leading config axis on every operand except ``t0`` — one program
    advances ``batch`` stacked scenarios a chunk at a time (the sweep
    path). With ``mesh`` set the chunk is built with GSPMD sharding
    constraints over the mesh's ``users`` axis at the padded length
    ``n_arr`` — the mesh signature (axes, sizes, device ids) and
    ``n_arr`` join the memo key so sharded and unsharded executables of
    the same shape NEVER alias. The policy's ``scan_step`` hook supplies
    the decision block and the rule's ``scan_weight`` the push-log
    weight column; everything else — arrivals, cooldowns, training
    progression, Eq. 10 energy, Eq. 15/16 queues, the push-event scatter
    — is engine code shared by every policy."""
    if agg is None:
        from .aggregation import resolve_aggregation
        agg = resolve_aggregation("replace")
    if dynamics is None:
        from .dynamics import resolve_dynamics
        dynamics = resolve_dynamics("none")
    key = (n, chunk, T, policy.jax_cache_key(), overhead, collect, capacity,
           statics, agg.jax_cache_key() if collect else None,
           dynamics.jax_cache_key() if dynamics.active else None, batch,
           _mesh_key(mesh), n_arr or n)
    fn = _JAX_FN_CACHE.pop(key, None)   # pop+reinsert = LRU order
    if fn is None:
        _JAX_CACHE_STATS["misses"] += 1
        fn = _build_jax_chunk_fn(n, chunk, T, policy, overhead, collect,
                                 capacity, statics, agg, dynamics, batch,
                                 mesh, n_arr)
        while _JAX_FN_CACHE and len(_JAX_FN_CACHE) >= _JAX_FN_CACHE_MAX:
            old = next(iter(_JAX_FN_CACHE))
            _JAX_FN_CACHE.pop(old)      # evict LRU
            _JAX_CACHE_STATS["evictions"] += 1
            _LOG.info("jax chunk cache full (max=%d): evicted %r",
                      _JAX_FN_CACHE_MAX, old[:4])
    else:
        _JAX_CACHE_STATS["hits"] += 1
    _JAX_FN_CACHE[key] = fn
    return fn


def _build_jax_chunk_fn(n: int, chunk: int, T: int, policy, overhead: bool,
                        collect: bool, capacity: int, statics: tuple = (),
                        agg=None, dynamics=None, batch: int = 0,
                        mesh=None, n_arr: int = 0):
    import jax
    import jax.numpy as jnp
    from jax import lax

    # device churn (core/dynamics.py): the phase is compiled in only for
    # active dynamics — inactive runs trace the exact historical step —
    # and the dropout rule is a static structural branch (both are part
    # of the _jax_chunk_fn cache key)
    dyn_active = dynamics is not None and dynamics.active
    dyn_lose = dyn_active and dynamics.dropout == "lose"
    # uneven horizon: the driver pads arrivals to a whole number of
    # chunks and the scan skips slots past T, so the tail chunk reuses
    # THIS executable instead of compiling a second one per horizon
    pad = chunk > 0 and (T % chunk) != 0
    # sharded build (see module docstring): n_arr is the padded user-axis
    # length, shard/repl insert the GSPMD constraints, place dispatches
    # per leaf — all identity on the unsharded build, whose traced graph
    # stays byte-identical to the historical one
    n_arr = int(n_arr) or n
    if mesh is not None:
        if batch:
            raise ValueError("sharded chunks never batch: the mesh IS the "
                             "parallelism (sweep_bucket_key returns None)")
        from jax.sharding import NamedSharding, PartitionSpec
        _sh_users = NamedSharding(mesh, PartitionSpec("users"))
        _sh_repl = NamedSharding(mesh, PartitionSpec())

        def shard(x):
            return lax.with_sharding_constraint(x, _sh_users)

        def repl(x):
            return lax.with_sharding_constraint(x, _sh_repl)

        def place(x):       # carry/dyn leaves: per-user iff (n_arr,)-led
            if getattr(x, "ndim", 0) >= 1 and x.shape[0] == n_arr:
                return shard(x)
            return repl(x)

        def constrain_state(s2):
            # pin the scan carry's layout at the end of every slot so
            # GSPMD keeps per-user leaves sharded and the scheduler
            # scalars replicated across chunks — without this the
            # partitioner may pick a gather-heavy layout for the carry
            kw = {fld: shard(getattr(s2, fld)) for fld in _PER_USER_FIELDS}
            for fld in ("version", "in_flight", "round_open", "Q", "H",
                        "sum_Q", "sum_H", "corun_updates", "rng_key"):
                kw[fld] = repl(getattr(s2, fld))
            kw["carry"] = jax.tree.map(place, s2.carry)
            kw["agg_carry"] = jax.tree.map(place, s2.agg_carry)
            kw["dyn"] = jax.tree.map(place, s2.dyn)
            ev = s2.events
            if ev is not None:
                ev = PushBuffer(repl(ev.rows), repl(ev.count))
            kw["events"] = ev
            return EngineState(**kw)
    else:
        shard = repl = place = None

    def simulate(tables, app_sched, app_choice, scalars, pol_ops, agg_ops,
                 dyn_ops, t0, state):
        PT, TT, PI, PS, P_APP, P_COR, T_COR, SRATE = tables
        (V, L_b, epsilon, eta, beta, v_norm0, t_d, ready_delay,
         offline_window, offline_resolution, fp_zero) = scalars
        f = PT.dtype
        i = jnp.asarray(0).dtype     # default int dtype (honors x64)
        ar = jnp.arange(n_arr)
        sched_c = lax.dynamic_slice(app_sched, (t0, 0), (chunk, n_arr))
        choice_c = lax.dynamic_slice(app_choice, (t0, 0), (chunk, n_arr))
        ts = t0 + jnp.arange(chunk)

        if n_arr == n:
            def pad_users(x, fill):
                return x
        else:
            def pad_users(x, fill):
                ext = jnp.full(x.shape[:-1] + (n_arr - n,), fill, x.dtype)
                return jnp.concatenate([x, ext], axis=-1)

        # sv.repl / dv.repl: hooks pin a float-reduction OPERAND with
        # this before summing it, so GSPMD cannot pull the reduction
        # sharded through a downstream sharded consumer (a shard-local
        # partial sum + AllReduce reassociates the floats and flips
        # low bits of e.g. Eq. 16's gap_sum). Identity when unsharded.
        repl_pin = repl if mesh is not None else (lambda x: x)

        def step(s, xs):
            srow, crow, t = xs
            if pad:
                # padded tail slots skip the WHOLE step — state, rng
                # chains and queues stay exactly where slot T-1 left
                # them, matching the host engines' T-slot histories
                return lax.cond(
                    t < T, _live_step,
                    lambda s, *_: (s, (s.Q, s.H, jnp.sum(s.energy))),
                    s, srow, crow, t)
            return _live_step(s, srow, crow, t)

        def _live_step(s, srow, crow, t):
            mode, cooldown, app, app_rem = s.mode, s.cooldown, s.app, \
                s.app_rem
            train_rem, corun, idle_gap = s.train_rem, s.corun, s.idle_gap
            pulled_at, energy, updates = s.pulled_at, s.energy, s.updates
            version, in_flight = s.version, s.in_flight
            Q, H = s.Q, s.H
            rng_key = s.rng_key
            dyn = s.dyn

            # device dynamics (churn): the traced twin of the host
            # transition, FIRST in the slot like the other engines; the
            # dynamics rng draw precedes the policy's so the key chain
            # matches the host engines bit for bit
            if dyn_active:
                # dv.n is the LIVE user count — hooks draw at it and pad
                # via dv.pad_users so the threefry stream matches the
                # host engines at any padding (dv.n_arr == n unsharded)
                dv = SimpleNamespace(jnp=jnp, jax=jax, lax=lax, n=n,
                                     n_arr=n_arr, pad_users=pad_users,
                                     repl=repl_pin,
                                     float_dtype=f, int_dtype=i,
                                     rng_key=rng_key, mode=mode,
                                     corun=corun, t_d=t_d, fp_zero=fp_zero,
                                     consts=dyn_ops)
                dyn, eff = dynamics.scan_step(dyn, dv)
                rng_key = dv.rng_key
                up = eff.up
                wd, wu = eff.went_down, eff.went_up
                net_extra = eff.net_extra
                dwait = wd & (mode == MODE_WAIT)
                dtrain = wd & (mode == MODE_TRAIN)
                dcool = wd & (mode == MODE_COOL)
                departures = jnp.sum(dwait)
                if dyn_lose:
                    mode = jnp.where(dwait | dtrain | dcool, MODE_OFF,
                                     mode)
                    train_rem = jnp.where(dtrain, 0.0, train_rem)
                    in_flight = in_flight - jnp.sum(dtrain)
                else:       # resume: paused, pays the extra seconds
                    mode = jnp.where(dwait | dcool, MODE_OFF, mode)
                    train_rem = jnp.where(dtrain,
                                          train_rem + eff.resume_penalty,
                                          train_rem)
                ret = wu & (mode == MODE_OFF)
                mode = jnp.where(ret, MODE_COOL, mode)
                cooldown = jnp.where(ret, ready_delay + net_extra,
                                     cooldown)

            # apps
            has_app0 = app >= 0
            new_app = srow & ~has_app0
            app_rem = jnp.where(has_app0, app_rem - t_d, app_rem)
            ended = has_app0 & (app_rem <= 0.0)
            app = jnp.where(ended, -1, app)
            app_rem = jnp.where(ended, 0.0, app_rem)
            app = jnp.where(new_app, crow, app)
            aid = jnp.maximum(app, 0)
            tcor_g = T_COR[ar, aid]
            papp_g = P_APP[ar, aid]
            pcor_g = P_COR[ar, aid]
            app_rem = jnp.where(new_app, tcor_g, app_rem)

            # cooldown -> waiting
            cooling = mode == MODE_COOL
            cooldown = jnp.where(cooling, cooldown - 1, cooldown)
            to_wait = cooling & (cooldown <= 0)
            mode = jnp.where(to_wait, MODE_WAIT, mode)
            plan = jnp.where(to_wait, PLAN_HOLD, s.plan)
            arrivals = jnp.sum(to_wait)
            waiting = mode == MODE_WAIT
            has_app = app >= 0

            # decisions: the policy's carry hook, on a mutable slot view.
            # Under a mesh every hook input (and the carry) is constrained
            # REPLICATED first: the hook's cross-user float reductions —
            # Eq. 16's gap_sum driving H, the online slow path's in-slot
            # replay — then compile to the single-device reduction order,
            # so Alg. 2 decisions are bit-identical to the unsharded scan
            # (a shard-local partial sum + AllReduce would reassociate
            # them). The engine keeps its own sharded views of the same
            # arrays for the surrounding per-user phases.
            if mesh is None:
                pol_carry = s.carry
                sv_waiting, sv_has_app, sv_app = waiting, has_app, app
                sv_updates, sv_plan, sv_idle = updates, plan, idle_gap
                sv_pcor, sv_papp, sv_tcor = pcor_g, papp_g, tcor_g
                sv_PT, sv_TT, sv_PI, sv_PS = PT, TT, PI, PS
            else:
                pol_carry = jax.tree.map(repl, s.carry)
                sv_waiting, sv_has_app, sv_app = \
                    repl(waiting), repl(has_app), repl(app)
                sv_updates, sv_plan, sv_idle = \
                    repl(updates), repl(plan), repl(idle_gap)
                sv_pcor, sv_papp, sv_tcor = \
                    repl(pcor_g), repl(papp_g), repl(tcor_g)
                sv_PT, sv_TT, sv_PI, sv_PS = \
                    repl(PT), repl(TT), repl(PI), repl(PS)
            sv = SimpleNamespace(
                jnp=jnp, lax=lax, jax=jax, n=n, T=T,
                n_arr=n_arr, pad_users=pad_users, repl=repl_pin,
                float_dtype=f, int_dtype=i, t=t,
                waiting=sv_waiting, has_app=sv_has_app, app=sv_app,
                updates=sv_updates,
                pcor_g=sv_pcor, papp_g=sv_papp, tcor_g=sv_tcor,
                PT=sv_PT, TT=sv_TT, PI=sv_PI, PS=sv_PS,
                T_COR=T_COR, SRATE=SRATE,
                app_sched=app_sched, app_choice=app_choice,
                plan=sv_plan, idle_gap=sv_idle, in_flight=in_flight,
                version=version, round_open=s.round_open, Q=Q, H=H,
                rng_key=rng_key,
                V=V, L_b=L_b, epsilon=epsilon, eta=eta, beta=beta,
                v_norm0=v_norm0, t_d=t_d, fp_zero=fp_zero,
                offline_window=offline_window,
                offline_resolution=offline_resolution,
                consts=pol_ops, statics=statics)
            carry, (start, gap_sum) = policy.scan_step(pol_carry, sv)
            idle_gap = sv.idle_gap
            round_open = sv.round_open
            plan = sv.plan
            rng_key = sv.rng_key
            if mesh is not None:
                # hook outputs return to the sharded layout for the
                # per-user phases below. The inner repl() pin is load-
                # bearing: without it GSPMD back-propagates the sharded
                # consumer layout INTO the hook graph, reassociating its
                # float reductions (Eq. 16's gap_sum) and partitioning
                # its lax.scan bodies — the hook must compute fully
                # replicated to stay bit-identical to the unsharded scan
                start = shard(repl(start))
                idle_gap = shard(repl(idle_gap))
                plan = shard(repl(plan))
                carry = jax.tree.map(lambda x: place(repl(x)), carry)
            served = jnp.sum(start)

            # begin training
            mode = jnp.where(start, MODE_TRAIN, mode)
            corun = jnp.where(start, has_app, corun)
            train_rem = jnp.where(start, jnp.where(has_app, tcor_g, TT),
                                  train_rem)
            pulled_at = jnp.where(start, version, pulled_at)
            in_flight = in_flight + served

            # training progression (a down "resume" trainer is paused)
            training = (mode == MODE_TRAIN) & up if dyn_active \
                else mode == MODE_TRAIN
            train_rem = jnp.where(training, train_rem - t_d, train_rem)
            fin = training & (train_rem <= 0.0)
            kfin = jnp.sum(fin)
            updates = updates + fin
            mode = jnp.where(fin, MODE_COOL, mode)
            cooldown = jnp.where(fin, ready_delay + net_extra if dyn_active
                                 else ready_delay, cooldown)
            idle_gap = jnp.where(fin, 0.0, idle_gap)
            in_flight = in_flight - kfin
            corun_updates = s.corun_updates + jnp.sum(fin & corun)

            # push events: scatter one fixed-width row per finisher at the
            # buffer cursor (user-index order within the slot, the loop
            # oracle's push order); rows past capacity drop, count stays
            # exact so the driver can detect overflow and retry
            events = s.events
            agg_carry = s.agg_carry
            if collect:
                # the scatter runs REPLICATED under a mesh (pads never
                # finish, so the cumsum ranks and the buffer cursor match
                # the unsharded scan; the buffer itself is a replicated
                # carry leaf) — cheap, since only (n,) vectors and the
                # O(capacity) buffer are involved, never the big state
                if mesh is None:
                    fin_e, corun_e, pulled_e, ar_e = fin, corun, \
                        pulled_at, ar
                else:
                    fin_e, corun_e, pulled_e, ar_e = \
                        repl(fin), repl(corun), repl(pulled_at), repl(ar)
                rank = jnp.cumsum(fin_e) - fin_e
                if policy.sync_rounds:
                    lag = version - pulled_e
                    vn = _jax_trace_v_norm(v_norm0, version, jnp, fp_zero)
                else:
                    vers = version + rank
                    lag = vers - pulled_e
                    vn = _jax_trace_v_norm(v_norm0, vers, jnp, fp_zero)
                gap = _jax_gradient_gap(vn, lag, eta, beta)
                if policy.sync_rounds:
                    # FedAvg rounds average; no per-push weight
                    w = jnp.ones((n_arr,), f)
                else:
                    pv = SimpleNamespace(
                        jnp=jnp, lax=lax, jax=jax, float_dtype=f,
                        lag=lag, gap=gap, v_norm=vn, users=ar_e,
                        consts=agg_ops)
                    if mesh is not None:
                        agg_carry = jax.tree.map(repl, agg_carry)
                    agg_carry, w = agg.scan_weight(agg_carry, pv)
                    if mesh is not None:
                        agg_carry = jax.tree.map(place, agg_carry)
                    w = jnp.broadcast_to(w, (n_arr,))
                rows = jnp.stack(
                    [jnp.broadcast_to(t, (n_arr,)).astype(f),
                     ar_e.astype(f),
                     lag.astype(f), gap.astype(f), corun_e.astype(f),
                     w.astype(f)],
                    axis=1)
                pos = jnp.where(fin_e, events.count + rank, capacity)
                events = PushBuffer(
                    events.rows.at[pos].set(rows, mode="drop"),
                    events.count + kfin)

            if policy.sync_rounds:
                closed = round_open & (jnp.sum(mode == MODE_TRAIN) == 0)
                version = version + closed
                round_open = round_open & ~closed
            else:
                version = version + kfin

            # energy (Eq. 10)
            training = mode == MODE_TRAIN
            p = jnp.where(training,
                          jnp.where(has_app, pcor_g, PT),
                          jnp.where(has_app, papp_g, PI))
            if overhead and policy.uses_online_queue:
                p = jnp.where(mode == MODE_WAIT, p + (PS - PI), p)
            if dyn_active:     # a down device draws nothing
                p = jnp.where(up, p, 0.0)
            # + fp_zero: round p*t_d before accumulating, as the host does
            # (fma contraction would skip it — see _jax_trace_v_norm)
            energy = energy + (p * t_d + fp_zero)

            # queues (Eqs. 15-16; departures extend Eq. 15 under churn)
            if dyn_active:
                Q = jnp.maximum(Q - served - departures, 0.0) + arrivals
            else:
                Q = jnp.maximum(Q - served, 0.0) + arrivals
            H = jnp.maximum(H + gap_sum - L_b, 0.0)
            s2 = EngineState(
                mode=mode, cooldown=cooldown, app=app, app_rem=app_rem,
                train_rem=train_rem, corun=corun, idle_gap=idle_gap,
                pulled_at=pulled_at, energy=energy, updates=updates,
                plan=plan, version=version, in_flight=in_flight,
                round_open=round_open, Q=Q, H=H,
                sum_Q=s.sum_Q + Q, sum_H=s.sum_H + H,
                corun_updates=corun_updates, rng_key=rng_key,
                carry=carry, agg_carry=agg_carry, dyn=dyn, events=events)
            if mesh is not None:
                s2 = constrain_state(s2)
            return s2, (Q, H, jnp.sum(energy))

        return lax.scan(step, state, (sched_c, choice_c, ts))

    if batch:
        # the sweep path: one program advances `batch` stacked configs —
        # every operand carries a leading config axis except t0 (the
        # chunk cursor, shared by the whole batch)
        return jax.jit(jax.vmap(simulate,
                                in_axes=(0, 0, 0, 0, 0, 0, 0, None, 0)))
    return jax.jit(simulate)


def _state_to_np(es: EngineState, jax, f, i) -> EngineState:
    """Engine-dtype twin of a host EngineState with NUMPY leaves: floats
    to the run's float dtype (honors x64), ints to the default int
    dtype, bools and the uint32 rng key as-is; the policy carry pytree
    converts leaf-wise. The driver device-puts the whole pytree in one
    ``tree.map`` — the sweep path stacks B of these host-side first, so
    a 100-config batch costs one transfer per leaf, not 100."""
    def cast(x):
        a = np.asarray(x)
        if a.dtype == np.bool_ or a.dtype == np.uint32:
            return a
        if np.issubdtype(a.dtype, np.floating):
            return np.asarray(a, f)
        return np.asarray(a, i)

    return EngineState(
        mode=cast(es.mode), cooldown=cast(es.cooldown), app=cast(es.app),
        app_rem=cast(es.app_rem), train_rem=cast(es.train_rem),
        corun=cast(es.corun), idle_gap=cast(es.idle_gap),
        pulled_at=cast(es.pulled_at), energy=cast(es.energy),
        updates=cast(es.updates), plan=cast(es.plan),
        version=cast(es.version), in_flight=cast(es.in_flight),
        round_open=cast(es.round_open), Q=cast(es.Q), H=cast(es.H),
        sum_Q=cast(es.sum_Q), sum_H=cast(es.sum_H),
        corun_updates=cast(es.corun_updates), rng_key=cast(es.rng_key),
        carry=jax.tree.map(cast, es.carry),
        agg_carry=jax.tree.map(cast, es.agg_carry),
        dyn=jax.tree.map(cast, es.dyn), events=None)


def _state_to_host(state: EngineState, jax) -> EngineState:
    """Host (numpy) twin of the final device EngineState: arrays come
    back as numpy, scalars as python — so ``sim.state`` reads the same
    after a jax run as after a loop/vectorized one."""
    return EngineState(
        mode=np.asarray(state.mode), cooldown=np.asarray(state.cooldown),
        app=np.asarray(state.app), app_rem=np.asarray(state.app_rem),
        train_rem=np.asarray(state.train_rem),
        corun=np.asarray(state.corun), idle_gap=np.asarray(state.idle_gap),
        pulled_at=np.asarray(state.pulled_at),
        energy=np.asarray(state.energy), updates=np.asarray(state.updates),
        plan=np.asarray(state.plan),
        version=int(state.version), in_flight=int(state.in_flight),
        round_open=bool(state.round_open),
        Q=float(state.Q), H=float(state.H),
        sum_Q=float(state.sum_Q), sum_H=float(state.sum_H),
        corun_updates=int(state.corun_updates),
        rng_key=np.asarray(state.rng_key),
        carry=jax.tree.map(np.asarray, state.carry),
        agg_carry=jax.tree.map(np.asarray, state.agg_carry),
        dyn=jax.tree.map(np.asarray, state.dyn), events=None)


def _next_pow2(k: int) -> int:
    c = 1
    while c < k:
        c <<= 1
    return c


def _jax_run_setup(sim, jax, jnp, n_devices: int = 1):
    """HOST (numpy) operands + engine-dtype state for one sim, shared by
    the per-point path (`_run_jax`) and the batched sweep path
    (`run_jax_sweep`). Everything stays numpy here on purpose: the
    per-point path device-puts each leaf once via `_ops_to_device`,
    while the sweep path first np.stacks B of these along a config axis
    and THEN converts — so a B-config sweep pays one transfer per leaf,
    not B (host->device dispatch, not the vmapped scan, dominated sweep
    wall-clock before this). Arrivals are padded host-side to a whole
    number of ``jax_chunk`` chunks so an uneven horizon reuses the
    full-chunk executable — the scan skips padded slots (t >= T).
    ``jax_chunk=0`` resolves the chunk (and, for a sharded run without
    an explicit ``push_log_capacity``, the push-buffer size) against the
    per-device memory budget (core/autotune.py); ``n_devices`` is the
    LIVE mesh size the caller resolved, 1 for unsharded runs."""
    cfg = sim.cfg
    n = cfg.n_users
    T = n_slots(cfg)
    collect = cfg.collect_push_log
    f = jnp.zeros(0).dtype          # honors jax_enable_x64
    i = jnp.asarray(0).dtype        # (jax dtypes ARE numpy dtypes)
    tables = tuple(np.asarray(a, f) for a in _user_tables(sim))
    tune = None
    jax_chunk = cfg.jax_chunk
    if jax_chunk == 0 or (n_devices > 1 and collect
                          and not cfg.push_log_capacity):
        from .autotune import autotune_scan_params
        tune = autotune_scan_params(sim, n_devices=n_devices)
        if jax_chunk == 0:
            jax_chunk = tune.jax_chunk
    chunk = min(jax_chunk, T) if T else 0
    n_chunks = -(-T // chunk) if T else 0
    sched = np.asarray(sim.app_sched[:T])
    choice = np.asarray(sim.app_choice[:T], np.int32)
    T_pad = n_chunks * chunk
    if T_pad > T:
        sched = np.concatenate(
            [sched, np.zeros((T_pad - T, n), sched.dtype)])
        choice = np.concatenate(
            [choice, np.zeros((T_pad - T, n), choice.dtype)])
    # fp_zero: a runtime-opaque 0.0 the scan adds to products that the
    # host engines round before accumulating — defeats XLA's fma
    # contraction, which would skip that rounding (see _jax_trace_v_norm)
    scalars = tuple(np.asarray(s, f) for s in (
        cfg.V, cfg.L_b, cfg.epsilon, cfg.eta, cfg.beta, cfg.v_norm0,
        cfg.t_d)) + (np.asarray(cfg.ready_delay, i),) + tuple(
        np.asarray(s, f) for s in (cfg.offline_window,
                                   cfg.offline_resolution)) + (
        np.asarray(0.0, f),)
    pol_ops = tuple(np.asarray(v) for v in sim.policy.scan_operands(cfg))
    agg_ops = tuple(np.asarray(v) for v in sim.agg.scan_operands(cfg))
    # dynamics knobs: floats in the run's float dtype (f64 parity with
    # the host transition under x64), ints in the default int dtype
    dyn_ops = tuple(
        np.asarray(v, f) if isinstance(v, float) else np.asarray(v)
        for v in sim.dynamics.scan_operands(cfg)) \
        if sim.dynamics.active else ()
    # initial per-chunk event capacity; an overflowing chunk is re-run
    # from its saved entry state with a doubled buffer, so the guess
    # only costs (rare) recompiles, never correctness. The legacy
    # max(1024, 2n) guess is a ~960 MB replicated buffer at n=10M, so
    # sharded runs (and jax_chunk=0 runs) take the tuner's rate-based
    # capacity instead.
    if not collect:
        cap = 0
    elif cfg.push_log_capacity:
        cap = _next_pow2(cfg.push_log_capacity)
    elif tune is not None:
        cap = tune.push_capacity
    else:
        cap = _next_pow2(max(1024, 2 * n))
    return SimpleNamespace(
        n=n, T=T, chunk=chunk, n_chunks=n_chunks, collect=collect,
        f=f, i=i, tables=tables, app_sched=sched,
        app_choice=choice, scalars=scalars, pol_ops=pol_ops,
        agg_ops=agg_ops, dyn_ops=dyn_ops,
        statics=tuple(sim.policy.scan_statics(cfg)),
        overhead=cfg.include_scheduler_overhead, cap=cap,
        state=_state_to_np(sim.state, jax, f, i))


def _ops_to_device(rs, jax, jnp):
    """Device-put a `_jax_run_setup` namespace in place: exactly one
    transfer per operand leaf, whether the leaves are unbatched or
    already np.stacked along a config axis. jax canonicalizes dtypes on
    the way in (f64 -> f32 when x64 is off), matching what tracing the
    host values directly used to produce."""
    dev = lambda tree: jax.tree.map(jnp.asarray, tree)
    rs.tables = dev(rs.tables)
    rs.app_sched = jnp.asarray(rs.app_sched)
    rs.app_choice = jnp.asarray(rs.app_choice)
    rs.scalars = dev(rs.scalars)
    rs.pol_ops = dev(rs.pol_ops)
    rs.agg_ops = dev(rs.agg_ops)
    rs.dyn_ops = dev(rs.dyn_ops)
    rs.state = dev(rs.state)
    return rs


def _pad_setup(rs, n_arr, sim):
    """Host-pad a `_jax_run_setup` namespace from ``n`` to ``n_arr``
    users (a multiple of the mesh size) with INERT rows: zero catalog
    rows (zero idle power -> zero energy), all-False arrival columns,
    MODE_OFF state rows, and the dynamics' own ``pad_state`` rows
    (pinned up/on forever, so pads never enter the queues, never push,
    never draw energy — property-tested in tests/test_sharded_sim.py)."""
    n = rs.n
    if n_arr == n:
        return rs
    k = n_arr - n

    def pad_rows(a):
        a = np.asarray(a)
        return np.concatenate([a, np.zeros((k,) + a.shape[1:], a.dtype)])

    def pad_cols(a):
        a = np.asarray(a)
        return np.concatenate(
            [a, np.zeros(a.shape[:1] + (k,), a.dtype)], axis=1)

    rs.tables = tuple(pad_rows(t) for t in rs.tables)
    rs.app_sched = pad_cols(rs.app_sched)
    rs.app_choice = pad_cols(rs.app_choice)
    dyn_rows = sim.dynamics.pad_state(k) if sim.dynamics.active else None
    if sim.dynamics.active and dyn_rows is None:
        raise ValueError(
            f"{type(sim.dynamics).__name__} has no pad_state recipe; "
            "sharded runs need one when n_users is not a multiple of the "
            "mesh size (or pick n_users divisible by n_devices)")
    rs.state = pad_state_per_user(rs.state, n_arr, dyn_rows=dyn_rows)
    return rs


def _mesh_ops_to_device(rs, mesh, n_arr, jax, jnp):
    """Device-put a (padded) `_jax_run_setup` namespace onto the
    ``("users",)`` mesh: catalog tables shard along their leading user
    axis, arrival operands along their user COLUMN (axis 1), scheduler
    scalars and hook operand tuples replicate, and the EngineState
    pytree lands leaf-wise per ``state_shardings`` — one sharded
    transfer per leaf, so the first chunk starts with every operand
    already laid out and XLA inserts no resharding prologue."""
    from jax.sharding import NamedSharding, PartitionSpec

    x64 = jax.config.jax_enable_x64

    def canon(x):       # jnp.asarray's dtype canonicalization, host-side
        a = np.asarray(x)
        if not x64 and a.dtype.itemsize == 8 and a.dtype.kind in "fiu":
            a = a.astype({"f": np.float32, "i": np.int32,
                          "u": np.uint32}[a.dtype.kind])
        return a

    sh_users = NamedSharding(mesh, PartitionSpec("users"))
    sh_cols = NamedSharding(mesh, PartitionSpec(None, "users"))
    sh_repl = NamedSharding(mesh, PartitionSpec())

    def put(x, sh):
        return jax.device_put(canon(x), sh)

    def repl_tree(tree):
        return jax.tree.map(lambda x: put(x, sh_repl), tree)

    rs.tables = tuple(put(t, sh_users) for t in rs.tables)
    rs.app_sched = put(rs.app_sched, sh_cols)
    rs.app_choice = put(rs.app_choice, sh_cols)
    rs.scalars = repl_tree(rs.scalars)
    rs.pol_ops = repl_tree(rs.pol_ops)
    rs.agg_ops = repl_tree(rs.agg_ops)
    rs.dyn_ops = repl_tree(rs.dyn_ops)
    shardings = state_shardings(rs.state, mesh, n_arr)
    rs.state = jax.tree.map(lambda x, sh: put(x, sh),
                            rs.state, shardings)
    rs.repl_sharding = sh_repl
    return rs


def _run_jax(sim) -> SimResult:
    import jax
    import jax.numpy as jnp

    cfg = sim.cfg
    policy = sim.policy
    agg = sim.agg
    dynamics = sim.dynamics
    from .aggregation import aggregation_support
    from .dynamics import dynamics_support
    if not policy.supports_jax or \
            not dynamics_support(dynamics)["jax"] or \
            (cfg.collect_push_log and not aggregation_support(agg)["jax"]):
        return _NumpyEngine(sim).run()  # resolve_engine reroutes; be safe
    # sharded run: resolve the ("users",) mesh first — the auto-tuner and
    # the user-axis padding both need the LIVE device count. A 1-device
    # mesh degenerates to the plain path (identical graph, no constraint
    # ops to trace through).
    mesh = None
    n_arr = 0
    if cfg.n_devices:
        from ..launch.mesh import make_sim_mesh
        mesh = make_sim_mesh(cfg.n_devices)
        if mesh.devices.size == 1:
            mesh = None
    rs = _jax_run_setup(sim, jax, jnp,
                        n_devices=mesh.devices.size if mesh else 1)
    if mesh is not None:
        n_arr = pad_to_devices(rs.n, mesh.devices.size)
        rs = _mesh_ops_to_device(_pad_setup(rs, n_arr, sim), mesh, n_arr,
                                 jax, jnp)
    else:
        rs = _ops_to_device(rs, jax, jnp)
    n, T, chunk, collect, f, i = rs.n, rs.T, rs.chunk, rs.collect, rs.f, rs.i
    cap = rs.cap
    state = rs.state

    def fresh_events(c):
        ev = PushBuffer(jnp.zeros((c, 6), f), jnp.asarray(0, i))
        if mesh is not None:    # the buffer is a replicated carry leaf
            ev = PushBuffer(jax.device_put(ev.rows, rs.repl_sharding),
                            jax.device_put(ev.count, rs.repl_sharding))
        return ev

    if collect:
        state = state.replace(events=fresh_events(cap))

    log = PushLog()
    qs_parts, hs_parts, e_parts = [], [], []
    ci = 0
    while ci < rs.n_chunks:
        t0 = ci * chunk
        fn = _jax_chunk_fn(n, chunk, T, policy, rs.overhead, collect, cap,
                           rs.statics, agg, dynamics, mesh=mesh,
                           n_arr=n_arr)
        prev = state
        state, (qs, hs, esum) = fn(rs.tables, rs.app_sched, rs.app_choice,
                                   rs.scalars, rs.pol_ops, rs.agg_ops,
                                   rs.dyn_ops, jnp.asarray(t0, i), state)
        if collect:
            cnt = int(state.events.count)
            if cnt > cap:
                # buffer overflow: double and re-run this chunk from its
                # saved entry state (count is exact, rows past cap dropped)
                cap = _next_pow2(cnt)
                state = prev.replace(events=fresh_events(cap))
                continue
            if cnt:
                log.extend_rows(np.asarray(state.events.rows[:cnt]))
            cnt0 = jnp.asarray(0, i)
            if mesh is not None:
                cnt0 = jax.device_put(cnt0, rs.repl_sharding)
            state = state.replace(events=PushBuffer(state.events.rows,
                                                    cnt0))
        m = min(chunk, T - t0)          # live slots (tail chunk is padded)
        qs_parts.append(np.asarray(qs, dtype=float)[:m])
        hs_parts.append(np.asarray(hs, dtype=float)[:m])
        e_parts.append(np.asarray(esum, dtype=float)[:m])
        ci += 1

    # the run's final state, readable on the host like the other engines'
    host = _state_to_host(state, jax)
    if mesh is not None and n_arr != n:
        host = unpad_state_per_user(host, n)     # pad rows are all-zero
    sim.state = host
    if mesh is None:
        energy_total = float(jnp.sum(state.energy))
    else:
        # device reduction order differs across shards anyway; sum the
        # unpadded host rows (pads contribute exact 0.0 either way)
        energy_total = float(np.sum(host.energy))
    updates_total = int(np.sum(host.updates))
    sum_Q, sum_H = float(state.sum_Q), float(state.sum_H)
    corun_updates = int(state.corun_updates)
    idx = np.arange(0, T, cfg.trace_every)
    if qs_parts:
        qs = np.concatenate(qs_parts)
        hs = np.concatenate(hs_parts)
        es = np.concatenate(e_parts)
    else:
        qs = hs = es = np.zeros(0)
    return SimResult(
        energy_j=energy_total,
        updates=updates_total,
        trace_t=idx.copy(), trace_energy=es[idx],
        trace_Q=qs[idx], trace_H=hs[idx],
        push_log=log, accuracy=[],
        mean_Q=sum_Q / T if T else 0.0,
        mean_H=sum_H / T if T else 0.0,
        corun_fraction=corun_updates / max(updates_total, 1),
        drops=dynamics.total_drops(sim.state.dyn))


# ======================================================================
# Batched sweeps: one vmapped program advances B stacked scenarios
# ======================================================================
def sweep_bucket_key(sim):
    """Shared-executable bucket key for the batched sweep path, or None
    when this sim can't join a vmapped batch: real-ML hooks/backends, an
    explicit ``engine="loop"`` request, a policy or dynamics without jax
    + vmap support (the offline policy's host knapsack ``pure_callback``
    would fire for every config at every slot under vmapped ``cond``),
    or a push log wanted without a jax-capable aggregation rule. Sims
    with equal keys share ONE jitted program — the key mirrors
    ``_jax_chunk_fn``'s memo key, so everything per-config (V, L_b,
    ``scan_operands``, arrival draws, seeds) stays traced and batched."""
    from .aggregation import aggregation_support
    from .dynamics import dynamics_support
    cfg = sim.cfg
    policy, agg, dynamics = sim.policy, sim.agg, sim.dynamics
    if sim.ml or sim.ml_backend is not None or cfg.engine == "loop":
        return None
    if cfg.n_devices or cfg.jax_chunk == 0:
        # sharded sims run per-point — the mesh IS the parallelism, and
        # an auto-tuned chunk (jax_chunk=0) resolves against the live
        # device set at run time, not against a bucket
        return None
    if not (policy.supports_jax and getattr(policy, "supports_vmap", True)):
        return None
    if not (dynamics_support(dynamics)["jax"]
            and getattr(dynamics, "supports_vmap", True)):
        return None
    collect = cfg.collect_push_log
    if collect and not (aggregation_support(agg)["jax"]
                        and getattr(agg, "supports_vmap", True)):
        return None
    n = cfg.n_users
    T = n_slots(cfg)
    if not T:
        return None
    cap = _next_pow2(cfg.push_log_capacity or max(1024, 2 * n)) \
        if collect else 0
    return (n, min(cfg.jax_chunk, T), T, cfg.n_devices,
            policy.jax_cache_key(),
            cfg.include_scheduler_overhead, collect, cap,
            tuple(policy.scan_statics(cfg)),
            agg.jax_cache_key() if collect else None,
            dynamics.jax_cache_key() if dynamics.active else None)


def run_jax_sweep(sims) -> List[SimResult]:
    """Run constructed FederatedSims that share a ``sweep_bucket_key``
    as ONE vmapped jitted program: per-config operands and EngineStates
    stack along a leading config axis, the chunked scan advances all of
    them together, and each row decodes back to an unbatched
    ``SimResult`` (traces, push log, final host state) identical — bit
    for bit on discrete outputs, to float-sum reordering on energies —
    to its per-point ``_run_jax`` run. Push buffers are batched
    ``(B, cap, 6)``; if ANY config overflows a chunk, the chunk re-runs
    from its saved entry state with the buffer doubled for every row
    (per-config counts stay exact)."""
    import jax
    import jax.numpy as jnp

    sims = list(sims)
    if not sims:
        return []
    keys = {sweep_bucket_key(s) for s in sims}
    if None in keys or len(keys) != 1:
        raise ValueError(
            "run_jax_sweep needs sims sharing one sweep_bucket_key; got "
            f"{len(keys)} distinct keys (None = jax/vmap-ineligible). "
            "Use core.scenario.run_sweep for bucketing + fallback.")
    if len(sims) == 1:
        return [_run_jax(sims[0])]
    B = len(sims)
    policy, agg = sims[0].policy, sims[0].agg
    dynamics = sims[0].dynamics
    preps = [_jax_run_setup(s, jax, jnp) for s in sims]
    p0 = preps[0]
    n, T, chunk, collect, f, i = p0.n, p0.T, p0.chunk, p0.collect, \
        p0.f, p0.i

    # stack HOST-side (the setups are numpy), then device-put the whole
    # batch in one pass — one transfer per leaf, independent of B
    def stack(parts):
        return jax.tree.map(lambda *xs: np.stack(xs), *parts)

    rs = SimpleNamespace(
        tables=stack([p.tables for p in preps]),
        app_sched=np.stack([p.app_sched for p in preps]),
        app_choice=np.stack([p.app_choice for p in preps]),
        scalars=stack([p.scalars for p in preps]),
        pol_ops=stack([p.pol_ops for p in preps]),
        agg_ops=stack([p.agg_ops for p in preps]),
        dyn_ops=stack([p.dyn_ops for p in preps]),
        state=stack([p.state for p in preps]))
    rs = _ops_to_device(rs, jax, jnp)
    tables, app_sched, app_choice = rs.tables, rs.app_sched, rs.app_choice
    scalars, pol_ops, agg_ops, dyn_ops = \
        rs.scalars, rs.pol_ops, rs.agg_ops, rs.dyn_ops
    state = rs.state
    cap = p0.cap
    if collect:
        state = state.replace(events=PushBuffer(
            jnp.zeros((B, cap, 6), f), jnp.zeros((B,), i)))

    logs = [PushLog() for _ in range(B)]
    qs_parts, hs_parts, e_parts = [], [], []
    ci = 0
    while ci < p0.n_chunks:
        t0 = ci * chunk
        fn = _jax_chunk_fn(n, chunk, T, policy, p0.overhead, collect, cap,
                           p0.statics, agg, dynamics, batch=B)
        prev = state
        state, (qs, hs, esum) = fn(tables, app_sched, app_choice, scalars,
                                   pol_ops, agg_ops, dyn_ops,
                                   jnp.asarray(t0, i), state)
        if collect:
            counts = np.asarray(state.events.count)
            if int(counts.max()) > cap:
                # any config overflowing re-runs the whole chunk with
                # the buffer doubled for every row (counts stay exact)
                cap = _next_pow2(int(counts.max()))
                state = prev.replace(events=PushBuffer(
                    jnp.zeros((B, cap, 6), f), jnp.zeros((B,), i)))
                continue
            rows = np.asarray(state.events.rows)
            for b in range(B):
                if counts[b]:
                    logs[b].extend_rows(rows[b, :counts[b]])
            state = state.replace(events=PushBuffer(
                state.events.rows, jnp.zeros((B,), i)))
        m = min(chunk, T - t0)          # live slots (tail chunk is padded)
        qs_parts.append(np.asarray(qs, dtype=float)[:, :m])
        hs_parts.append(np.asarray(hs, dtype=float)[:, :m])
        e_parts.append(np.asarray(esum, dtype=float)[:, :m])
        ci += 1

    qs = np.concatenate(qs_parts, axis=1)
    hs = np.concatenate(hs_parts, axis=1)
    es = np.concatenate(e_parts, axis=1)
    # per-config energy reduced on device along the user axis, like the
    # per-point path's jnp.sum over (n,)
    energy_rows = np.asarray(jnp.sum(state.energy, axis=1), dtype=float)
    # one bulk device->host transfer for the whole batch, then numpy
    # slicing per row — per-row device slicing cost ~50x more here
    host_all = jax.tree.map(np.asarray, state.replace(events=None))
    results = []
    for b, sim in enumerate(sims):
        host = _state_to_host(jax.tree.map(lambda x: x[b], host_all), jax)
        sim.state = host
        sim._ran = True                 # Scenario.run() re-entrancy flag
        updates_total = int(host.updates.sum())
        idx = np.arange(0, T, sim.cfg.trace_every)
        results.append(SimResult(
            energy_j=float(energy_rows[b]),
            updates=updates_total,
            trace_t=idx.copy(), trace_energy=es[b, idx],
            trace_Q=qs[b, idx], trace_H=hs[b, idx],
            push_log=logs[b], accuracy=[],
            mean_Q=host.sum_Q / T, mean_H=host.sum_H / T,
            corun_fraction=host.corun_updates / max(updates_total, 1),
            drops=sim.dynamics.total_drops(host.dyn)))
    return results
