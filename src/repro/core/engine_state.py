"""Unified engine state: ONE explicit state container for all three engines.

Every simulator engine — the per-user loop oracle (``FederatedSim._run_loop``),
the struct-of-arrays numpy engine and the ``jax.lax.scan`` backend
(``core/vector_engine.py``) — threads the same ``EngineState``: the per-user
struct-of-arrays device state, the server/scheduler scalars (version,
in-flight count, the Eq. 15/16 queues Q and H and their running sums), an
RNG key for stochastic policies, the policy's declarative carry pytree
(``Policy.init_carry``), and — on the jax engine — the fixed-width push-event
buffer that streams the push log out of the scan.

``EngineState`` is a registered jax pytree, so the SAME object shape that the
numpy engine mutates in place is the ``lax.scan`` carry on the jax backend
(fields converted to device arrays by ``vector_engine``). ``FederatedSim``
builds one per run (``sim.state``); the loop oracle keeps its readable
per-user ``UserState`` objects as the working view and threads the scalar /
carry fields through this container.

The push log is no longer accumulated as per-push dicts: engines append
fixed-width blocks to a ``PushLog`` (six columns — slot, user, lag, gap,
corun, applied aggregation weight), and the ``SimResult.push_log`` dict
schema is decoded lazily on access, so fleet-scale runs never materialize
O(pushes) Python dicts unless the caller actually walks the log. Inside
the jax scan the same six columns live in a preallocated ``PushBuffer``
``(capacity, 6)`` array filled by scatter; ``vector_engine`` drains it
chunk-by-chunk over the horizon, so peak memory stays O(chunk), never
O(T * n).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import numpy as np

# Shared state encodings of all engines (re-exported by core/policies.py).
# MODE_OFF is the device-dynamics parking state (core/dynamics.py): a user
# whose device churned off; it draws no power and re-enters the arrival
# process through cooldown when it comes back up.
MODE_WAIT, MODE_TRAIN, MODE_COOL, MODE_OFF = 0, 1, 2, 3
PLAN_HOLD, PLAN_CORUN, PLAN_SEP = 0, 1, 2

# Column order of the fixed-width push-event records (PushBuffer rows and
# PushLog blocks). ``weight`` is the aggregation rule's applied mixing
# weight (core/aggregation.py) — 1.0 under the paper's replace rule.
EVENT_FIELDS = ("t", "user", "lag", "gap", "corun", "weight")


class PushBuffer(NamedTuple):
    """Fixed-width in-scan event buffer: ``rows`` is ``(capacity, 6)`` in
    ``EVENT_FIELDS`` order, ``count`` the number of pushes recorded so far
    (monotone within a chunk; entries past capacity are dropped by the
    scatter, which the driver detects as ``count > capacity`` and retries
    the chunk with a doubled buffer). NamedTuple => a native jax pytree."""

    rows: Any
    count: Any


@dataclasses.dataclass
class EngineState:
    """The one state pytree threaded through every engine.

    Per-user struct-of-arrays (``(n_users,)`` each): ``mode`` (wait / train /
    cool), ``cooldown`` slots left, current ``app`` id (-1 = none), remaining
    app / training seconds, ``corun`` flag of the current/last training run,
    the accumulated Eq. (12) ``idle_gap``, the global ``pulled_at`` version,
    per-user ``energy`` (J) and ``updates``, and the offline policy's
    ``plan`` code.

    Scheduler / server scalars: global model ``version``, ``in_flight``
    trainer count, the sync-round ``round_open`` flag, the Lyapunov queues
    ``Q`` / ``H`` (Eqs. 15/16) plus their horizon sums, and the co-run
    update counter.

    ``rng_key`` is a raw ``(2,)`` uint32 counter-key (the jax PRNGKey
    layout) derived from ``SimConfig.seed`` — engines thread it untouched;
    stochastic policies may split it inside their carry protocol hooks.

    ``carry`` is the policy's declarative carry pytree
    (``Policy.init_carry``) — e.g. greedy's per-user wait counters or the
    offline policy's next plan slot. ``agg_carry`` is the aggregation
    rule's carry pytree (``AggregationRule.init_carry``,
    core/aggregation.py) — e.g. hetero_aware's per-user device-class
    scales. ``events`` is the jax engine's ``PushBuffer`` (None
    elsewhere).
    """

    # ---- per-user struct-of-arrays -----------------------------------
    mode: Any
    cooldown: Any
    app: Any
    app_rem: Any
    train_rem: Any
    corun: Any
    idle_gap: Any
    pulled_at: Any
    energy: Any
    updates: Any
    plan: Any
    # ---- scheduler / server scalars ----------------------------------
    version: Any = 0
    in_flight: Any = 0
    round_open: Any = False
    Q: Any = 0.0
    H: Any = 0.0
    sum_Q: Any = 0.0
    sum_H: Any = 0.0
    corun_updates: Any = 0
    # ---- rng / policy carry / event stream ---------------------------
    rng_key: Any = None
    carry: Any = None
    agg_carry: Any = None
    dyn: Any = None
    events: Optional[PushBuffer] = None

    @classmethod
    def init(cls, n: int, cfg, policy, agg=None, fleet=None,
             dynamics=None) -> "EngineState":
        """Fresh host-side (numpy) state for an ``n``-user run: everyone
        cooling with zero cooldown (first slot moves the fleet to waiting,
        like the historical engines), no apps, v0 model, empty queues.
        ``agg``/``fleet`` (the run's aggregation rule and FleetSpec)
        initialize the rule carry; ``None`` leaves it empty. ``dynamics``
        (a resolved DeviceDynamics, core/dynamics.py) initializes the
        per-user churn state ``dyn``; ``None`` or an inactive dynamics
        leaves it empty. All per-user arrays are shape-checked against
        ``n`` (mis-shaped carries fail HERE, not deep inside the scan)."""
        state = cls(
            mode=np.full(n, MODE_COOL, dtype=np.int8),
            cooldown=np.zeros(n, dtype=np.int64),
            app=np.full(n, -1, dtype=np.int64),
            app_rem=np.zeros(n),
            train_rem=np.zeros(n),
            corun=np.zeros(n, dtype=bool),
            idle_gap=np.zeros(n),
            pulled_at=np.zeros(n, dtype=np.int64),
            energy=np.zeros(n),
            updates=np.zeros(n, dtype=np.int64),
            plan=np.full(n, PLAN_HOLD, dtype=np.int8),
            rng_key=np.array([0, cfg.seed & 0xFFFFFFFF], dtype=np.uint32),
            carry=policy.init_carry(n, cfg),
            agg_carry=None if agg is None
            else agg.init_carry(n, cfg, fleet),
            dyn=None if dynamics is None or not dynamics.active
            else dynamics.init_state(n, cfg, fleet),
        )
        _check_shapes(state, n)
        return state

    def replace(self, **kw) -> "EngineState":
        new = dataclasses.replace(self, **kw)
        if _PER_USER_FIELDS.intersection(kw) or "dyn" in kw:
            # n comes from the PRE-replace state: replacing mode itself
            # with a mis-sized array must fail too
            n = np.shape(self.mode)[0] if np.ndim(self.mode) else None
            if n is not None:
                _check_shapes(new, int(n), only=set(kw))
        return new


# Fields that must be (n,)-leading per-user arrays in every engine.
_PER_USER_FIELDS = frozenset(
    ("mode", "cooldown", "app", "app_rem", "train_rem", "corun",
     "idle_gap", "pulled_at", "energy", "updates", "plan"))


def _leaves(tree):
    """Pytree leaves without requiring jax (dyn carries are dict/array)."""
    if tree is None:
        return
    if isinstance(tree, dict):
        for v in tree.values():
            yield from _leaves(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _leaves(v)
    else:
        yield tree


def _check_shapes(state: "EngineState", n: int, only=None) -> None:
    """Every per-user array must be ``(n,)``-leading; every ``dyn`` leaf
    with a leading axis must share it. Shape-only (never reads values),
    so it is trace-safe and cheap; raises ValueError naming the offender
    at construction instead of a reshape error deep inside the scan."""
    for f in _PER_USER_FIELDS if only is None \
            else _PER_USER_FIELDS.intersection(only):
        v = getattr(state, f)
        shape = np.shape(v)
        if not shape or shape[0] != n:
            raise ValueError(
                f"EngineState.{f} must be an ({n},)-leading per-user "
                f"array, got shape {shape}")
    if only is None or "dyn" in only:
        for leaf in _leaves(state.dyn):
            shape = np.shape(leaf)
            if len(shape) >= 1 and shape[0] != n:
                raise ValueError(
                    f"EngineState.dyn leaf has leading dim {shape[0]}, "
                    f"expected the run's n_users={n} (shape {shape}); "
                    "dynamics init_state must return (n,)-leading arrays")


# ---------------------------------------------------------------------------
# Sharded-scan support (core/vector_engine.py, ``SimConfig.n_devices``): pad
# the user axis to a multiple of the mesh size with INERT rows and build the
# matching pytree of shardings for ``jax.device_put``. Padded users park in
# MODE_OFF with no app and a zeroed catalog row (the driver zero-pads the
# table gathers), so they draw no energy, never enter the waiting queue and
# never push — the scheduler scalars evolve exactly as at the live n.
# ---------------------------------------------------------------------------
def pad_to_devices(n: int, n_devices: int) -> int:
    """Smallest multiple of ``n_devices`` >= ``n`` (the padded user-axis
    length ``n_arr`` of a sharded run)."""
    d = max(int(n_devices), 1)
    return -(-int(n) // d) * d


def _map_tree(fn, tree):
    """Structure-preserving map without requiring jax (carries are
    dict/list/tuple/array pytrees; ``None`` passes through)."""
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {k: _map_tree(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_tree(fn, v) for v in tree)
    return fn(tree)


def _map_tree2(fn, tree, other):
    """Two-tree ``_map_tree`` (leaf-wise zip; structures must match)."""
    if tree is None:
        return None
    if isinstance(tree, dict):
        return {k: _map_tree2(fn, v, other[k]) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_tree2(fn, v, o)
                          for v, o in zip(tree, other))
    return fn(tree, other)


# inert fill values of the per-user fields; everything not named is 0/False
_PAD_FILLS = {"mode": MODE_OFF, "app": -1, "plan": PLAN_HOLD}


def pad_state_per_user(state: EngineState, n_arr: int,
                       dyn_rows=None) -> EngineState:
    """Host-side copy of ``state`` with every per-user leaf extended to
    ``n_arr`` rows of INERT users: MODE_OFF, no app, zero
    energy/updates/cooldown. Policy and aggregation carries zero-pad
    their ``(n,)``-leading leaves (the registry carries — greedy wait
    counters, hetero scales — initialize pad-equivalently at any n).
    ``dyn_rows`` is the dynamics' ``pad_state(k)`` pytree of inert rows
    (required when ``state.dyn`` is populated); its leaves are cast to
    the state leaf dtypes. Shape-checked at ``n_arr`` on the way out."""
    n = int(np.shape(state.mode)[0])
    k = int(n_arr) - n
    if k < 0:
        raise ValueError(f"n_arr={n_arr} is below the live n={n}")
    if k == 0:
        return state

    def pad(x, fill=0):
        x = np.asarray(x)
        return np.concatenate(
            [x, np.full((k,) + x.shape[1:], fill, dtype=x.dtype)])

    def pad_carry_leaf(x):
        a = np.asarray(x)
        if a.ndim >= 1 and a.shape[0] == n:
            return pad(a)
        return x

    kw = {f: pad(getattr(state, f), _PAD_FILLS.get(f, 0))
          for f in _PER_USER_FIELDS}
    kw["carry"] = _map_tree(pad_carry_leaf, state.carry)
    kw["agg_carry"] = _map_tree(pad_carry_leaf, state.agg_carry)
    if state.dyn is not None:
        if dyn_rows is None:
            raise ValueError(
                "pad_state_per_user needs the dynamics' pad_state(k) rows "
                "to pad a populated EngineState.dyn")
        kw["dyn"] = _map_tree2(
            lambda leaf, rows: np.concatenate(
                [np.asarray(leaf),
                 np.asarray(rows, np.asarray(leaf).dtype)])
            if np.ndim(leaf) >= 1 and np.shape(leaf)[0] == n else leaf,
            state.dyn, dyn_rows)
    new = dataclasses.replace(state, **kw)
    _check_shapes(new, int(n_arr))
    return new


def unpad_state_per_user(state: EngineState, n: int) -> EngineState:
    """Drop the pad rows again: every ``(n_arr,)``-leading per-user /
    carry / dyn leaf sliced back to the live ``n`` (numpy or device
    arrays — slicing works on both)."""
    n_arr = int(np.shape(state.mode)[0])
    if n_arr == n:
        return state

    def cut(x):
        if np.ndim(x) >= 1 and np.shape(x)[0] == n_arr:
            return x[:n]
        return x

    kw = {f: cut(getattr(state, f)) for f in _PER_USER_FIELDS}
    kw["carry"] = _map_tree(cut, state.carry)
    kw["agg_carry"] = _map_tree(cut, state.agg_carry)
    kw["dyn"] = _map_tree(cut, state.dyn)
    return dataclasses.replace(state, **kw)


def state_shardings(state: EngineState, mesh, n_arr: int) -> EngineState:
    """EngineState-shaped pytree of ``NamedSharding``s for
    ``jax.device_put``: per-user leaves (and any ``(n_arr,)``-leading
    carry/dyn leaf) partitioned over the mesh's ``users`` axis,
    scheduler scalars / rng key / scalar carry leaves replicated."""
    from jax.sharding import NamedSharding, PartitionSpec

    sh_u = NamedSharding(mesh, PartitionSpec("users"))
    sh_r = NamedSharding(mesh, PartitionSpec())

    def leaf_sharding(x):
        if np.ndim(x) >= 1 and np.shape(x)[0] == int(n_arr):
            return sh_u
        return sh_r

    kw = {}
    for f in _FIELDS:
        v = getattr(state, f)
        if f in _PER_USER_FIELDS:
            kw[f] = sh_u
        elif f in ("carry", "agg_carry", "dyn"):
            kw[f] = _map_tree(leaf_sharding, v)
        elif f == "events":
            kw[f] = None        # the driver builds the buffer separately
        else:
            kw[f] = sh_r
    return EngineState(**kw)


_FIELDS = tuple(f.name for f in dataclasses.fields(EngineState))


def _flatten(s: EngineState):
    return tuple(getattr(s, f) for f in _FIELDS), None


def _unflatten(_, children) -> EngineState:
    return EngineState(**dict(zip(_FIELDS, children)))


try:  # register as a jax pytree so EngineState IS the lax.scan carry
    from jax import tree_util as _jtu

    _jtu.register_pytree_node(EngineState, _flatten, _unflatten)
except ImportError:  # pragma: no cover - jax is a hard dep of repro.core
    pass


class PushLog:
    """Fixed-width push-log accumulator with the historical dict schema.

    Engines append columnar blocks (``extend``) or single events
    (``append``); the jax driver feeds decoded ``(k, 6)`` buffer slices
    (``extend_rows``). The sequence interface decodes per-event dicts
    ``{"t", "user", "lag", "gap", "corun", "weight"}`` lazily, so holding
    a fleet-scale log costs six flat arrays, not O(pushes) dicts;
    iteration and ``log == [...]`` behave exactly like the historical
    list of dicts.
    """

    __slots__ = ("_parts", "_n", "_cache")

    def __init__(self):
        self._parts = []   # (t, user, lag, gap, corun, weight) blocks
        self._n = 0
        self._cache = None

    # ------------------------------------------------------------- builders
    def append(self, t, user, lag, gap, corun, weight=1.0) -> None:
        """One event (the loop oracle's per-push path)."""
        self._parts.append((np.asarray([t], np.int64),
                            np.asarray([user], np.int64),
                            np.asarray([lag], np.int64),
                            np.asarray([gap], np.float64),
                            np.asarray([corun], bool),
                            np.asarray([weight], np.float64)))
        self._n += 1
        self._cache = None

    def extend(self, t, users, lags, gaps, corun, weights=None) -> None:
        """One slot's finisher cohort (the numpy engine's path); ``t`` is
        the scalar slot, the rest ``(k,)`` arrays in user order.
        ``weights=None`` means full-weight (replace) pushes."""
        users = np.asarray(users, np.int64)
        k = len(users)
        if not k:
            return
        self._parts.append((np.full(k, t, np.int64), users,
                            np.asarray(lags, np.int64),
                            np.asarray(gaps, np.float64),
                            np.asarray(corun, bool),
                            np.ones(k, np.float64) if weights is None
                            else np.asarray(weights, np.float64)))
        self._n += k
        self._cache = None

    def extend_rows(self, rows) -> None:
        """Decode a drained ``PushBuffer`` slice: ``rows`` is ``(k, 6)``
        float in ``EVENT_FIELDS`` order (the jax engine's path)."""
        rows = np.asarray(rows)
        if not len(rows):
            return
        self._parts.append((rows[:, 0].astype(np.int64),
                            rows[:, 1].astype(np.int64),
                            rows[:, 2].astype(np.int64),
                            rows[:, 3].astype(np.float64),
                            rows[:, 4] != 0,
                            rows[:, 5].astype(np.float64)))
        self._n += len(rows)
        self._cache = None

    # ------------------------------------------------------------- readers
    def arrays(self):
        """The six concatenated columns, ``EVENT_FIELDS`` order."""
        if self._cache is None:
            if self._parts:
                cols = tuple(np.concatenate([p[j] for p in self._parts])
                             for j in range(6))
            else:
                cols = (np.zeros(0, np.int64), np.zeros(0, np.int64),
                        np.zeros(0, np.int64), np.zeros(0, np.float64),
                        np.zeros(0, bool), np.zeros(0, np.float64))
            self._cache = cols
        return self._cache

    def field(self, name: str) -> np.ndarray:
        return self.arrays()[EVENT_FIELDS.index(name)]

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def _event(self, i: int) -> dict:
        t, u, l, g, c, w = self.arrays()
        # python scalars on purpose: digests/reprs must match the
        # historical dict-of-python-scalars schema byte for byte
        return {"t": int(t[i]), "user": int(u[i]), "lag": int(l[i]),
                "gap": float(g[i]), "corun": bool(c[i]),
                "weight": float(w[i])}

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._event(j) for j in range(*i.indices(self._n))]
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError(i)
        return self._event(i)

    def __iter__(self):
        for i in range(self._n):
            yield self._event(i)

    def __eq__(self, other):
        if isinstance(other, PushLog):
            return list(self) == list(other)
        if isinstance(other, (list, tuple)):
            return list(self) == list(other)
        return NotImplemented

    def __repr__(self):
        return f"PushLog(n={self._n})"
