"""Pluggable app-arrival processes.

The paper's evaluation fixes one arrival model — i.i.d. Bernoulli per slot
at p = 0.001 (~1 app per 1000 s, Sec. VII.B). This module makes the
process a composable object: every ``ArrivalProcess`` pre-samples the full
``(T, n_users)`` arrival mask and app-choice schedule up front (the offline
policy needs oracle lookahead, and pre-sampling is what keeps all three
engines draw-for-draw identical), so any process drops into any engine.

Ships: ``bernoulli`` (paper-exact — the default consumes the rng stream in
the same order as the pre-registry simulator, keeping seeded runs
bit-for-bit reproducible), ``diurnal`` (sinusoidal time-of-day intensity),
``bursty`` (per-user two-state Markov-modulated on/off bursts), and
``trace`` (replay a recorded schedule).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Type

import numpy as np


class ArrivalProcess:
    """Base arrival process. ``sample`` returns the slot-indexed
    ``(sched, choice)`` pair the engines consume: ``sched[t, i]`` — does an
    app arrive for user i at slot t (ignored while one is running);
    ``choice[t, i]`` — which app (row of ``energy.APPS``) it would be."""

    name: str = ""

    def sample(self, rng: np.random.Generator, T: int, n_users: int,
               n_apps: int, t_d: float = 1.0
               ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[ArrivalProcess]] = {}


def register_arrival(cls: Type[ArrivalProcess]) -> Type[ArrivalProcess]:
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    _REGISTRY[cls.name] = cls
    return cls


def registered_arrivals() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_arrival(arrivals) -> ArrivalProcess:
    """String -> default-constructed registered process; instance -> itself."""
    if isinstance(arrivals, ArrivalProcess):
        return arrivals
    if isinstance(arrivals, str):
        if arrivals not in _REGISTRY:
            raise ValueError(f"unknown arrival process {arrivals!r}; "
                             f"expected one of {registered_arrivals()} "
                             "or an ArrivalProcess instance")
        try:
            return _REGISTRY[arrivals]()
        except TypeError as e:
            raise ValueError(
                f"arrival process {arrivals!r} needs constructor arguments; "
                f"pass an instance instead ({e})") from None
    raise ValueError(f"arrivals must be a name or ArrivalProcess instance, "
                     f"got {type(arrivals).__name__}")


def resolve_arrival_or_default(arrivals, app_arrival_p) -> "ArrivalProcess":
    """The simulator-facing resolution rule, in ONE place: ``None`` or the
    name ``"bernoulli"`` mean the paper's process at the *configured*
    ``app_arrival_p`` — scalar or per-user ``(n_users,)`` rate vector
    (never bernoulli's stock 0.001); anything else resolves normally."""
    if arrivals is None or arrivals == "bernoulli":
        return BernoulliArrivals(app_arrival_p)
    return resolve_arrival(arrivals)


@register_arrival
class BernoulliArrivals(ArrivalProcess):
    """Paper-exact i.i.d. Bernoulli arrivals (Sec. VII.B, p = 0.001).

    ``p`` is a scalar rate or an ``(n_users,)`` vector giving every user
    its own rate (heterogeneous usage intensity — the AutoFL-style device
    heterogeneity axis). The same ``(T, n)`` uniform block feeds both
    forms, so a vector of identical entries is draw-for-draw the scalar
    process.

    Draw order is pinned: one ``(T, n)`` uniform block for the mask, then
    one ``(T, n)`` integer block for the choices — byte-identical to the
    pre-registry ``FederatedSim.__init__`` sampling, so existing seeded
    results reproduce exactly."""

    name = "bernoulli"

    def __init__(self, p=0.001):
        arr = np.asarray(p, dtype=float)
        if arr.ndim > 1:
            raise ValueError(
                f"arrival probability must be a scalar or an (n_users,) "
                f"vector, got shape {arr.shape}")
        if arr.size and not np.all((arr >= 0.0) & (arr <= 1.0)):
            # the conjunctive form also rejects NaN entries
            raise ValueError(f"arrival probability must be in [0, 1], got {p}")
        self.p = float(arr) if arr.ndim == 0 else arr

    def sample(self, rng, T, n_users, n_apps, t_d=1.0):
        p = self.p
        if np.ndim(p) == 1 and len(p) != n_users:
            raise ValueError(
                f"per-user arrival rates cover {len(p)} users, run has "
                f"{n_users}")
        # scalar p compares elementwise exactly as the historical code
        # did; a (n,) vector broadcasts across the same uniform block
        sched = rng.random((T, n_users)) < p
        choice = rng.integers(0, n_apps, (T, n_users))
        return sched, choice


@register_arrival
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidal time-of-day intensity: p(t) = p_mean * (1 + depth *
    sin(2*pi*(t / period + phase))). Mobile app usage peaks in the evening
    and bottoms out overnight; ``period_s`` defaults to 24 h. ``phase`` in
    [0, 1) shifts where in the cycle the horizon starts."""

    name = "diurnal"

    def __init__(self, p_mean: float = 0.001, depth: float = 0.8,
                 period_s: float = 86400.0, phase: float = 0.0):
        if not 0.0 <= depth <= 1.0:
            raise ValueError(f"depth must be in [0, 1], got {depth}")
        if period_s <= 0:
            raise ValueError(f"period_s must be positive, got {period_s}")
        if not 0.0 <= p_mean <= 1.0:
            raise ValueError(f"p_mean must be in [0, 1], got {p_mean}")
        self.p_mean = float(p_mean)
        self.depth = float(depth)
        self.period_s = float(period_s)
        self.phase = float(phase)

    def rate(self, T: int, t_d: float = 1.0) -> np.ndarray:
        """The per-slot arrival probability profile (exposed for tests)."""
        t = np.arange(T) * t_d
        p = self.p_mean * (1.0 + self.depth *
                           np.sin(2.0 * np.pi * (t / self.period_s
                                                 + self.phase)))
        return np.clip(p, 0.0, 1.0)

    def sample(self, rng, T, n_users, n_apps, t_d=1.0):
        p_t = self.rate(T, t_d)[:, None]
        sched = rng.random((T, n_users)) < p_t
        choice = rng.integers(0, n_apps, (T, n_users))
        return sched, choice


@register_arrival
class MarkovModulatedArrivals(ArrivalProcess):
    """Per-user two-state Markov-modulated Bernoulli (bursty sessions).

    Each user independently alternates calm/burst phases: in a calm slot an
    app arrives w.p. ``p_calm`` and the user enters a burst w.p.
    ``burst_start``; bursts arrive at ``p_burst`` and end w.p.
    ``burst_stop`` per slot (mean burst length 1/burst_stop slots). Models
    the clumped app-usage sessions that i.i.d. Bernoulli cannot."""

    name = "bursty"

    def __init__(self, p_calm: float = 2e-4, p_burst: float = 5e-2,
                 burst_start: float = 1e-3, burst_stop: float = 1e-2):
        for nm, v in (("p_calm", p_calm), ("p_burst", p_burst),
                      ("burst_start", burst_start),
                      ("burst_stop", burst_stop)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {v}")
        self.p_calm = float(p_calm)
        self.p_burst = float(p_burst)
        self.burst_start = float(burst_start)
        self.burst_stop = float(burst_stop)

    def sample(self, rng, T, n_users, n_apps, t_d=1.0):
        arr_u = rng.random((T, n_users))
        flip_u = rng.random((T, n_users))
        burst = np.zeros(n_users, dtype=bool)
        sched = np.zeros((T, n_users), dtype=bool)
        for t in range(T):
            # state transition first, then the arrival draw in that state
            burst = np.where(burst, flip_u[t] >= self.burst_stop,
                             flip_u[t] < self.burst_start)
            sched[t] = arr_u[t] < np.where(burst, self.p_burst, self.p_calm)
        choice = rng.integers(0, n_apps, (T, n_users))
        return sched, choice


@register_arrival
class TraceArrivals(ArrivalProcess):
    """Replay a recorded ``(T', n_users)`` schedule.

    Shorter traces wrap around the horizon; the user axis must match the
    fleet exactly (silently recycling users would misattribute per-device
    behaviour). ``choice=None`` draws app choices from the run's rng."""

    name = "trace"

    def __init__(self, sched, choice=None):
        self.sched = np.asarray(sched).astype(bool)
        if self.sched.ndim != 2:
            raise ValueError(
                f"trace schedule must be (T, n_users), got shape "
                f"{self.sched.shape}")
        self.choice = None if choice is None \
            else np.asarray(choice, dtype=np.int64)
        if self.choice is not None and self.choice.shape != self.sched.shape:
            raise ValueError(
                f"choice shape {self.choice.shape} != schedule shape "
                f"{self.sched.shape}")

    @classmethod
    def from_sim(cls, sim) -> "TraceArrivals":
        """Snapshot a constructed FederatedSim's sampled schedule."""
        return cls(sim.app_sched.copy(), sim.app_choice.copy())

    def sample(self, rng, T, n_users, n_apps, t_d=1.0):
        Tr, nr = self.sched.shape
        if nr != n_users:
            raise ValueError(f"trace covers {nr} users, run has {n_users}")
        reps = -(-T // Tr) if Tr else 0          # ceil
        if Tr == 0 or reps == 0:
            raise ValueError("trace schedule has zero slots")
        sched = np.tile(self.sched, (reps, 1))[:T]
        if self.choice is not None:
            choice = np.tile(self.choice, (reps, 1))[:T]
            if np.any(choice >= n_apps) or np.any(choice < 0):
                raise ValueError(
                    f"trace app choices must be in [0, {n_apps})")
        else:
            choice = rng.integers(0, n_apps, (T, n_users))
        return sched, choice
