"""Client-side local training: momentum SGD per Eq. (1).

    v_t = beta * v_{t-1} + (1 - beta) * s_t
    theta_t = theta_{t-1} - eta * v_t

One ``local_train`` call = one local epoch over the client's shard (the unit
the paper schedules; ~210 s of wall-clock on the testbed devices).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("loss_fn", "batch_size", "eta", "beta"))
def _epoch(params, images, labels, key, loss_fn, batch_size: int,
           eta: float, beta: float):
    n = images.shape[0]
    steps = n // batch_size
    perm = jax.random.permutation(key, n)[: steps * batch_size]
    batches_x = images[perm].reshape(steps, batch_size, *images.shape[1:])
    batches_y = labels[perm].reshape(steps, batch_size)
    v0 = jax.tree.map(jnp.zeros_like, params)

    def step(carry, xy):
        p, v = carry
        x, y = xy
        grads, metrics = jax.grad(
            lambda q: loss_fn(q, {"images": x, "labels": y}), has_aux=True)(p)
        v = jax.tree.map(lambda vv, g: beta * vv + (1 - beta) * g, v, grads)
        p = jax.tree.map(lambda pp, vv: pp - eta * vv, p, v)
        return (p, v), metrics["loss"]

    (params, v), losses = jax.lax.scan(step, (params, v0), (batches_x, batches_y))
    return params, v, losses.mean()


class Client:
    """A federated participant holding one data shard."""

    def __init__(self, client_id, images, labels, loss_fn: Callable,
                 batch_size: int = 20, eta: float = 0.01, beta: float = 0.9):
        self.client_id = client_id
        self.images = images
        self.labels = labels
        self.loss_fn = loss_fn
        self.batch_size = batch_size
        self.eta = eta
        self.beta = beta
        self._key = jax.random.PRNGKey(hash(client_id) % (2 ** 31))

    def local_train(self, params: Any):
        """One local epoch; returns (new_params, local_momentum, mean_loss)."""
        self._key, sub = jax.random.split(self._key)
        new_params, v, loss = _epoch(params, self.images, self.labels, sub,
                                     self.loss_fn, self.batch_size,
                                     self.eta, self.beta)
        return new_params, v, float(loss)
