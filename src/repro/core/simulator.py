"""Slotted-time federated simulator (Sec. VII.B methodology).

Replays the paper's evaluation: n users each owning a testbed device
(Table II catalog), Bernoulli app arrivals per slot, four scheduling
policies — "sync" (FedAvg lock-step), "immediate" (ASync, schedule ASAP),
"offline" (knapsack with look-ahead window), "online" (Lyapunov) — with
per-slot energy accounting per Eq. (10) and queue dynamics per Eqs. (15-16).

ml_mode="trace" tracks updates/staleness without real gradients (fast —
Fig. 4/6 energy results); ml_mode="real" couples the schedule to actual JAX
training of the paper's LeNet-5 (Fig. 5 convergence results).

Engines (SimConfig.engine): this class's per-user object loop is the
reference oracle ("loop"); "vectorized" runs the same semantics on
struct-of-arrays batched state (core/vector_engine.py), "jax" compiles the
horizon into one lax.scan, and "auto" (default) picks the vectorized
engine for pure trace-mode runs. Seeded equivalence across engines is
pinned by tests/test_sim_engines.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .energy import APPS, DEVICE_NAMES, TESTBED, DeviceProfile
from .lyapunov import OnlineScheduler, UserSlotState
from .offline import knapsack_schedule, lemma1_lag_bounds
from .staleness import gradient_gap


POLICIES = ("sync", "immediate", "offline", "online")
ENGINES = ("auto", "loop", "vectorized", "jax")


@dataclasses.dataclass
class SimConfig:
    n_users: int = 25
    horizon_s: int = 10800          # paper: 3 hours
    t_d: float = 1.0                # slot length (s)
    app_arrival_p: float = 0.001    # paper: ~1 app per 1000 s
    policy: str = "online"          # sync | immediate | offline | online
    V: float = 4000.0
    L_b: float = 1000.0
    epsilon: float = 0.05
    eta: float = 0.01
    beta: float = 0.9
    offline_window: float = 500.0   # paper: 500 s look-ahead
    offline_resolution: float = 0.01
    seed: int = 0
    ml_mode: str = "trace"          # trace | real
    ready_delay: int = 5            # slots between push and re-arrival
    trace_every: int = 30           # slots between trace samples
    include_scheduler_overhead: bool = False
    v_norm0: float = 1.0            # trace-mode momentum-norm model scale
    engine: str = "auto"            # auto | loop | vectorized | jax
    collect_push_log: bool = True   # per-push dicts; disable at fleet scale

    def __post_init__(self):
        # Fail at construction, not mid-run (a bad policy string used to
        # surface only once the first slot hit the decision branch).
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"expected one of {POLICIES}")
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"expected one of {ENGINES}")
        if self.ml_mode not in ("trace", "real"):
            raise ValueError(f"unknown ml_mode {self.ml_mode!r}")
        if self.n_users <= 0:
            raise ValueError(f"n_users must be positive, got {self.n_users}")
        if self.t_d <= 0:
            raise ValueError(f"t_d must be positive, got {self.t_d}")
        if self.horizon_s <= 0:
            raise ValueError(
                f"horizon_s must be positive, got {self.horizon_s}")
        if not 0.0 <= self.app_arrival_p <= 1.0:
            raise ValueError(
                f"app_arrival_p must be in [0, 1], got {self.app_arrival_p}")
        if not 0.0 <= self.beta < 1.0:
            raise ValueError(f"beta must be in [0, 1), got {self.beta}")
        if self.V < 0 or self.L_b < 0 or self.epsilon < 0:
            raise ValueError("V, L_b and epsilon must be non-negative")
        if self.eta < 0 or self.v_norm0 < 0:
            # negative eta/v_norm would invert Eq. 4's gap monotonicity,
            # which the batched online argmin relies on
            raise ValueError("eta and v_norm0 must be non-negative")
        if self.offline_window <= 0 or self.offline_resolution <= 0:
            raise ValueError(
                "offline_window and offline_resolution must be positive")
        if self.ready_delay < 0:
            raise ValueError(
                f"ready_delay must be non-negative, got {self.ready_delay}")
        if self.trace_every <= 0:
            raise ValueError(
                f"trace_every must be positive, got {self.trace_every}")


@dataclasses.dataclass
class UserState:
    device: DeviceProfile
    mode: str = "cooldown"          # waiting | training | cooldown
    cooldown: int = 0
    app: Optional[str] = None
    app_remaining: float = 0.0
    train_remaining: float = 0.0
    corun: bool = False
    idle_gap: float = 0.0
    pulled_at: int = 0              # global version at pull
    started_at: int = 0
    energy_j: float = 0.0
    updates: int = 0
    plan: str = "none"              # offline policy: corun | separate | hold


@dataclasses.dataclass
class SimResult:
    energy_j: float
    updates: int
    trace_t: np.ndarray
    trace_energy: np.ndarray
    trace_Q: np.ndarray
    trace_H: np.ndarray
    push_log: List[dict]            # per push: t, user, lag, gap, corun
    accuracy: List[tuple]           # (sim_t, test_acc) if ml_mode == real
    mean_Q: float
    mean_H: float
    corun_fraction: float


def n_slots(cfg: SimConfig) -> int:
    """Slots in the horizon. round() before int: 48 s / 1.6 s is
    29.999999999999996 in floats and plain int() would drop a slot."""
    return int(round(cfg.horizon_s / cfg.t_d))


def trace_v_norm(v_norm0: float, version) -> float:
    """Trace-mode momentum-norm model: ||v|| decays with global progress.
    Shared by the loop oracle and the vectorized engines (version may be an
    array of per-finisher versions)."""
    return v_norm0 / np.sqrt(1.0 + 0.05 * version)


class FederatedSim:
    def __init__(self, cfg: SimConfig, ml_hooks: Optional[dict] = None):
        """ml_hooks (real mode): {"pull": fn()->params_version, "push":
        fn(uid, params)->PushResult, "local_train": fn(uid, params)->params,
        "evaluate": fn()->acc, "sync_submit", "sync_aggregate", "v_norm": fn()->float}
        """
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.ml = ml_hooks or {}
        names = [DEVICE_NAMES[i % len(DEVICE_NAMES)] for i in range(cfg.n_users)]
        self.rng.shuffle(names)
        self.users = [UserState(device=TESTBED[n]) for n in names]
        self.sched = OnlineScheduler(cfg.V, cfg.L_b, cfg.eta, cfg.beta,
                                     cfg.epsilon, cfg.t_d)
        self.version = 0
        self.in_flight = 0
        # Pre-sample the app arrival schedule (offline policy needs
        # lookahead), one row per SLOT — t_d < 1 means more slots than
        # seconds. (For t_d == 1 this matches the historical horizon_s
        # sizing draw-for-draw, keeping seeded runs reproducible.)
        T = n_slots(cfg)
        self.app_sched = self.rng.random((T, cfg.n_users)) < cfg.app_arrival_p
        self.app_choice = self.rng.integers(0, len(APPS), (T, cfg.n_users))

    # ------------------------------------------------------------------ utils
    def _v_norm(self) -> float:
        if "v_norm" in self.ml:
            return self.ml["v_norm"]()
        return trace_v_norm(self.cfg.v_norm0, self.version)

    def _begin_training(self, u: UserState, t: int, corun: bool):
        u.mode = "training"
        u.corun = corun and u.app is not None
        u.train_remaining = u.device.duration(u.corun, u.app)
        u.pulled_at = self.version
        u.started_at = t
        self.in_flight += 1
        if self.ml.get("pull"):
            u._params = self.ml["pull"](u._uid)

    def _finish_training(self, u: UserState, t: int, log: list):
        lag = self.version - u.pulled_at
        gap = gradient_gap(self._v_norm(), lag, self.cfg.eta, self.cfg.beta)
        if self.cfg.policy == "sync":
            if self.ml.get("sync_submit"):
                trained = self.ml["local_train"](u._uid, u._params)
                self.ml["sync_submit"](trained)
        else:
            self.version += 1
            if self.ml.get("push"):
                trained = self.ml["local_train"](u._uid, u._params)
                self.ml["push"](u._uid, trained)
        u.updates += 1
        u.mode = "cooldown"
        u.cooldown = self.cfg.ready_delay
        u.idle_gap = 0.0
        self.in_flight -= 1
        if self.cfg.collect_push_log:
            log.append({"t": t, "user": u._uid, "lag": lag, "gap": gap,
                        "corun": u.corun})

    # ------------------------------------------------------------------ main
    def resolve_engine(self) -> str:
        """Pick the engine to run: ``auto`` selects the vectorized SoA
        engine whenever the run is pure trace mode (real-ML hooks other than
        the slot-constant ``v_norm`` need the per-user object loop). The jax
        backend covers hook-free trace runs of sync/immediate/online only —
        with an offline policy (knapsack DP cannot live inside lax.scan) or
        a ``v_norm`` hook (a Python callback cannot run under the scan) it
        degrades to the numpy engine, which honors both."""
        cfg = self.cfg
        vec_ok = cfg.ml_mode == "trace" and set(self.ml) <= {"v_norm"}
        engine = cfg.engine
        if engine == "auto":
            return "vectorized" if vec_ok else "loop"
        if engine in ("vectorized", "jax") and not vec_ok:
            raise ValueError(
                f"engine={engine!r} supports only trace-mode runs without "
                "per-user ML hooks; use engine='loop' (or 'auto') for "
                "ml_mode='real'")
        if engine == "jax" and (cfg.policy == "offline" or self.ml):
            return "vectorized"
        return engine

    def run(self) -> SimResult:
        engine = self.resolve_engine()
        if engine == "loop":
            return self._run_loop()
        from .vector_engine import run_vectorized
        return run_vectorized(self, backend=engine)

    def _run_loop(self) -> SimResult:
        cfg = self.cfg
        for i, u in enumerate(self.users):
            u._uid = i
            u._params = None
        T = n_slots(cfg)
        trace_t, trace_E, trace_Q, trace_H = [], [], [], []
        push_log: List[dict] = []
        accuracy: List[tuple] = []
        sum_Q = sum_H = 0.0
        corun_updates = 0
        sync_round_open = False
        next_offline_plan = 0.0

        for t in range(T):
            arrivals = served = 0
            gap_sum = 0.0

            # --- app arrivals / progression -------------------------------
            for i, u in enumerate(self.users):
                if u.app is None and self.app_sched[t, i]:
                    u.app = APPS[self.app_choice[t, i]]
                    u.app_remaining = u.device.apps[u.app].t_corun
                elif u.app is not None:
                    u.app_remaining -= cfg.t_d
                    if u.app_remaining <= 0:
                        u.app, u.app_remaining = None, 0.0

            # --- cooldown -> waiting (queue arrival) ------------------------
            for u in self.users:
                if u.mode == "cooldown":
                    u.cooldown -= 1
                    if u.cooldown <= 0:
                        u.mode = "waiting"
                        u.plan = "hold"   # offline: wait for next plan window
                        arrivals += 1

            # --- policy decisions for waiting users -------------------------
            waiting = [u for u in self.users if u.mode == "waiting"]
            if cfg.policy == "sync":
                # lock-step rounds: start everyone when the whole cohort waits
                if not sync_round_open and len(waiting) == cfg.n_users:
                    for u in waiting:
                        self._begin_training(u, t, corun=u.app is not None)
                        served += 1
                    sync_round_open = True
            elif cfg.policy == "immediate":
                for u in waiting:
                    self._begin_training(u, t, corun=u.app is not None)
                    served += 1
            elif cfg.policy == "online":
                vn = self._v_norm()
                for u in waiting:
                    a = u.app is not None
                    ap = u.device.apps[u.app] if a else None
                    st = UserSlotState(
                        p_corun=ap.p_corun if a else 0.0,
                        p_app=ap.p_app if a else 0.0,
                        p_train=u.device.p_train, p_idle=u.device.p_idle,
                        app_running=a,
                        lag_estimate=self.in_flight,
                        idle_gap=u.idle_gap)
                    d = self.sched.decide(st, vn)
                    gap_sum += d.gap
                    if d.schedule:
                        self._begin_training(u, t, corun=a)
                        served += 1
                    else:
                        u.idle_gap += cfg.epsilon
            elif cfg.policy == "offline":
                if t >= next_offline_plan:
                    next_offline_plan = t + cfg.offline_window
                    self._plan_offline(t, waiting)
                for u in waiting:
                    if u.plan == "corun":
                        if u.app is not None:
                            self._begin_training(u, t, corun=True)
                            served += 1
                    elif u.plan == "separate":
                        self._begin_training(u, t, corun=u.app is not None)
                        served += 1
                    # plan == "hold"/"none": idle until the next window
            else:
                raise ValueError(cfg.policy)

            # --- training progression ---------------------------------------
            for u in self.users:
                if u.mode == "training":
                    u.train_remaining -= cfg.t_d
                    if u.train_remaining <= 0:
                        self._finish_training(u, t, push_log)
                        if u.corun:
                            corun_updates += 1
            if cfg.policy == "sync" and sync_round_open and \
                    all(u.mode != "training" for u in self.users):
                sync_round_open = False
                self.version += 1
                if self.ml.get("sync_aggregate"):
                    self.ml["sync_aggregate"]()

            # --- energy accounting (Eq. 10) ---------------------------------
            for u in self.users:
                p = u.device.power(u.mode == "training", u.app is not None, u.app)
                if cfg.include_scheduler_overhead and u.mode == "waiting" \
                        and cfg.policy == "online":
                    p += u.device.p_sched - u.device.p_idle
                u.energy_j += p * cfg.t_d

            # --- queues ------------------------------------------------------
            self.sched.update_queues(arrivals, served, gap_sum)
            sum_Q += self.sched.Q
            sum_H += self.sched.H

            if t % cfg.trace_every == 0:
                trace_t.append(t)
                trace_E.append(sum(u.energy_j for u in self.users))
                trace_Q.append(self.sched.Q)
                trace_H.append(self.sched.H)
            if self.ml.get("evaluate") and t % self.ml.get("eval_every", 600) == 0 \
                    and t > 0:
                accuracy.append((t, self.ml["evaluate"]()))

        if self.ml.get("evaluate"):
            accuracy.append((T, self.ml["evaluate"]()))
        updates = sum(u.updates for u in self.users)
        return SimResult(
            energy_j=sum(u.energy_j for u in self.users),
            updates=updates,
            trace_t=np.array(trace_t), trace_energy=np.array(trace_E),
            trace_Q=np.array(trace_Q), trace_H=np.array(trace_H),
            push_log=push_log, accuracy=accuracy,
            mean_Q=sum_Q / T if T else 0.0,
            mean_H=sum_H / T if T else 0.0,
            corun_fraction=corun_updates / max(updates, 1))

    # ------------------------------------------------------------- offline plan
    def _plan_offline(self, t: int, waiting: List[UserState]):
        """Knapsack over the look-ahead window (Alg. 1).

        Users whose app arrival falls inside the window are knapsack
        candidates: selected -> wait for the arrival and co-run (x_i = 1);
        rejected -> train immediately, separate execution (x_i = 0). Users
        without an in-window arrival hold (idle) until the next window —
        with the paper's relaxed L_b = 1000 this reduces to the "greedy
        always waiting for co-running opportunities" behaviour of Fig. 4a.
        """
        cfg = self.cfg
        W = int(cfg.offline_window)
        cands, t_app, t_now, durs, savings = [], [], [], [], []
        for u in waiting:
            # next app arrival within the window (oracle lookahead)
            i = u._uid
            horizon = min(t + W, self.app_sched.shape[0])
            arr = np.nonzero(self.app_sched[t:horizon, i])[0]
            if u.app is not None:
                ta, app = t, u.app
            elif len(arr):
                ta = t + int(arr[0])
                app = APPS[self.app_choice[ta, i]]
            else:
                u.plan = "hold"
                continue
            cands.append(u)
            t_now.append(t)
            t_app.append(ta)
            durs.append(u.device.apps[app].t_corun)
            savings.append(u.device.energy_saving_rate(app) * u.device.apps[app].t_corun)
        if not cands:
            return
        lags = lemma1_lag_bounds(np.array(t_now), np.array(t_app), np.array(durs))
        vn = self._v_norm()
        gaps = np.array([gradient_gap(vn, int(l), cfg.eta, cfg.beta) for l in lags])
        x, _ = knapsack_schedule(np.array(savings), gaps, cfg.L_b,
                                 resolution=cfg.offline_resolution)
        for u, chosen in zip(cands, x):
            u.plan = "corun" if chosen else "separate"
