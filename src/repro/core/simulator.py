"""Slotted-time federated simulator (Sec. VII.B methodology).

Replays the paper's evaluation: n users each owning a testbed device
(Table II catalog), Bernoulli app arrivals per slot, four scheduling
policies — "sync" (FedAvg lock-step), "immediate" (ASync, schedule ASAP),
"offline" (knapsack with look-ahead window), "online" (Lyapunov) — with
per-slot energy accounting per Eq. (10) and queue dynamics per Eqs. (15-16).

Policies, arrival processes, and device fleets are composable objects with
registries (core/policies.py, core/arrivals.py, core/fleet.py); the paper's
setup is just the default composition. ``SimConfig.policy`` accepts either
a registry name or a ``Policy`` instance; ``FederatedSim`` additionally
takes ``arrivals=``/``fleet=`` objects. See core/scenario.py for the
experiment-facing ``Scenario``/``run_experiment`` entrypoint.

ml_mode="trace" tracks updates/staleness without real gradients (fast —
Fig. 4/6 energy results); ml_mode="real" couples the schedule to actual JAX
training of the paper's LeNet-5 (Fig. 5 convergence results).

Engines (SimConfig.engine): this class's per-user object loop is the
reference oracle ("loop"); "vectorized" runs the same semantics on
struct-of-arrays batched state (core/vector_engine.py), "jax" compiles the
horizon into chunked lax.scans, and "auto" (default) picks the vectorized
engine for pure trace-mode runs AND for real-mode runs driven by a
batched ml_backend (core/realml.py — vmap'd cohort training). All three
engines thread ONE state container — ``core.engine_state.EngineState``
(``sim.state``): per-user struct-of-arrays, scheduler scalars, RNG key and
the policy's carry pytree — and stream push events through
``core.engine_state.PushLog``. Seeded equivalence across engines is pinned
by tests/test_sim_engines.py, tests/test_engine_matrix.py and
tests/test_real_mode.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np

from ..kernels.fused_update import KERNEL_MODES
from .aggregation import (AggregationRule, aggregation_support,
                          resolve_aggregation)
from .arrivals import ArrivalProcess, resolve_arrival_or_default
from .dynamics import (DROPOUT_RULES, DeviceDynamics, dynamics_support,
                       resolve_dynamics)
from .energy import APPS, DeviceProfile
from .engine_state import (MODE_COOL, MODE_OFF, MODE_TRAIN, MODE_WAIT,
                           EngineState, PushLog)
from .fleet import Fleet, resolve_fleet
from .lyapunov import OnlineScheduler
from .policies import Policy, engine_support, resolve_policy
from .staleness import gradient_gap


# The paper's four schedulers (Sec. VII.B). The full registry — these plus
# any registered extras — is policies.registered_policies().
POLICIES = ("sync", "immediate", "offline", "online")
ENGINES = ("auto", "loop", "vectorized", "jax")


@dataclasses.dataclass
class SimConfig:
    n_users: int = 25
    horizon_s: int = 10800          # paper: 3 hours
    t_d: float = 1.0                # slot length (s)
    # scalar = the paper's i.i.d. rate; an (n_users,) vector gives every
    # user its own Bernoulli rate (heterogeneous fleets)
    app_arrival_p: Any = 0.001      # paper: ~1 app per 1000 s
    policy: Union[str, Policy] = "online"   # registry name or Policy object
    V: float = 4000.0
    L_b: float = 1000.0
    epsilon: float = 0.05
    eta: float = 0.01
    beta: float = 0.9
    offline_window: float = 500.0   # paper: 500 s look-ahead
    offline_resolution: float = 0.01
    seed: int = 0
    ml_mode: str = "trace"          # trace | real
    # how the server APPLIES pushes (core/aggregation.py): registry name
    # or AggregationRule instance; "replace" is the paper's Sec. VI rule.
    # Every engine logs the applied weight per push (push_log "weight"
    # column); in real mode the weight actually mixes the global model.
    aggregation: Union[str, AggregationRule] = "replace"
    # how the apply is COMPUTED (kernels/fused_update): "pallas" fuses
    # mix + momentum + Eq. 4 norm into one HBM pass, "reference" keeps
    # the multi-dispatch jnp path (bit-stable with the goldens), "auto"
    # picks Pallas on TPU and reference elsewhere. Only real-ML mode
    # touches parameter pytrees, so the knob is a no-op in trace mode.
    kernel: str = "auto"
    ready_delay: int = 5            # slots between push and re-arrival
    trace_every: int = 30           # slots between trace samples
    include_scheduler_overhead: bool = False
    v_norm0: float = 1.0            # trace-mode momentum-norm model scale
    engine: str = "auto"            # auto | loop | vectorized | jax
    collect_push_log: bool = True   # push events; streamed on every engine
    jax_chunk: int = 1024           # slots per compiled scan chunk (jax);
    #                                 0 = auto-tune from per-device memory
    #                                 (core/autotune.py)
    push_log_capacity: int = 0      # initial per-chunk event buffer slots
    #                                 for the jax engine (0 = auto-sized;
    #                                 doubled + chunk retried on overflow)
    # Shard the user axis (jax engine): partition every per-user
    # EngineState leaf over a 1-D ("users",) mesh of
    # min(n_devices, available) devices (launch/mesh.py make_sim_mesh),
    # scheduler scalars replicated — Alg. 2 decisions stay bit-identical
    # to the single-device scan (core/vector_engine.py). 0 = unsharded.
    n_devices: int = 0
    # Device dynamics (core/dynamics.py): availability / battery / network
    # churn as per-user state machines. Registry name or DeviceDynamics
    # instance; "none" (the paper's always-on fleet) is bit-identical to
    # the pre-dynamics engines.
    dynamics: Union[str, DeviceDynamics] = "none"

    def __post_init__(self):
        # Fail at construction, not mid-run (a bad policy string used to
        # surface only once the first slot hit the decision branch).
        pol = resolve_policy(self.policy)   # raises ValueError on unknowns
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"expected one of {ENGINES}")
        # Engine-capability validation: a policy whose support flags claim
        # an engine must actually implement its carry-protocol hook — a
        # flag/hook mismatch is a property of the policy, so it is
        # rejected for EVERY engine (auto included: auto dispatches on the
        # flags). An explicitly requested vectorized engine additionally
        # needs the vectorized hook. Catching this here replaces the
        # historical NotImplementedError raised mid-run from the
        # base-class hook stubs.
        sup = engine_support(pol)
        if pol.supports_vectorized and not sup["vectorized"]:
            raise ValueError(
                f"policy {pol.name!r} sets supports_vectorized but "
                "implements no decide_vectorized hook; implement "
                "decide_vectorized(eng, t, carry) or clear the flag")
        if pol.supports_jax and not sup["jax"]:
            raise ValueError(
                f"policy {pol.name!r} sets supports_jax but implements no "
                "scan_step carry hook; implement scan_step(carry, sv) or "
                "clear the flag to degrade to the vectorized engine")
        if self.engine == "vectorized" and not sup["vectorized"]:
            raise ValueError(
                f"policy {pol.name!r} implements no vectorized "
                "(decide_vectorized) hook; use engine='loop' (or 'auto', "
                "which falls back to the loop oracle)")
        if self.ml_mode not in ("trace", "real"):
            raise ValueError(f"unknown ml_mode {self.ml_mode!r}")
        if self.kernel not in KERNEL_MODES:
            raise ValueError(f"unknown kernel {self.kernel!r}; "
                             f"expected one of {KERNEL_MODES}")
        # Aggregation-rule validation mirrors the policy validation: the
        # name must resolve, and a rule whose supports_jax flag claims a
        # traced path must actually implement scan_weight (rules without
        # one degrade the jax engine to the numpy path, see
        # resolve_engine).
        agg = resolve_aggregation(self.aggregation)  # raises on unknowns
        asup = aggregation_support(agg)
        if not asup["host"]:
            raise ValueError(
                f"aggregation rule {agg.name!r} implements no weight() "
                "host path; every rule needs one (the loop oracle and "
                "the numpy engine run on it)")
        if agg.supports_jax and not asup["jax"]:
            raise ValueError(
                f"aggregation rule {agg.name!r} sets supports_jax but "
                "implements no scan_weight hook; implement "
                "scan_weight(carry, pv) or clear the flag to degrade to "
                "the numpy engines")
        # Dynamics validation, same shape: the name must resolve, an
        # active dynamics needs the shared host transition (the loop
        # oracle and the numpy engine both run on it), a supports_jax
        # flag without the traced hook is a lie, and the dropout rule
        # must be one the engines know how to apply structurally.
        dyn = resolve_dynamics(self.dynamics)    # raises on unknowns
        dsup = dynamics_support(dyn)
        if not dsup["host"]:
            raise ValueError(
                f"dynamics {dyn.name!r} implements no host_step() path; "
                "every active dynamics needs one (the loop oracle and "
                "the numpy engine run on it)")
        if dyn.active and dyn.supports_jax and not dsup["jax"]:
            raise ValueError(
                f"dynamics {dyn.name!r} sets supports_jax but implements "
                "no scan_step hook; implement scan_step(dyn, dv) or "
                "clear the flag to degrade to the numpy engines")
        if dyn.active and dyn.dropout not in DROPOUT_RULES:
            raise ValueError(
                f"dynamics {dyn.name!r} has unknown dropout rule "
                f"{dyn.dropout!r}; engines apply one of {DROPOUT_RULES}")
        if self.n_users <= 0:
            raise ValueError(f"n_users must be positive, got {self.n_users}")
        if self.t_d <= 0:
            raise ValueError(f"t_d must be positive, got {self.t_d}")
        if self.horizon_s <= 0:
            raise ValueError(
                f"horizon_s must be positive, got {self.horizon_s}")
        p = np.asarray(self.app_arrival_p, dtype=float)
        if p.ndim > 1:
            raise ValueError(
                f"app_arrival_p must be a scalar or an (n_users,) vector, "
                f"got shape {p.shape}")
        if p.ndim == 1 and p.shape[0] != self.n_users:
            raise ValueError(
                f"app_arrival_p vector has {p.shape[0]} entries for "
                f"n_users={self.n_users}")
        if p.size and not np.all((p >= 0.0) & (p <= 1.0)):
            # the conjunctive form also rejects NaN entries
            raise ValueError(
                f"app_arrival_p must be in [0, 1], got {self.app_arrival_p}")
        if p.ndim == 1:
            # normalize rate vectors to a plain tuple: keeps the
            # dataclass-generated __eq__/repr working (an ndarray field
            # would make config comparison raise) and the value hashable
            self.app_arrival_p = tuple(float(x) for x in p)
        if not 0.0 <= self.beta < 1.0:
            raise ValueError(f"beta must be in [0, 1), got {self.beta}")
        if self.V < 0 or self.L_b < 0 or self.epsilon < 0:
            raise ValueError("V, L_b and epsilon must be non-negative")
        if self.eta < 0 or self.v_norm0 < 0:
            # negative eta/v_norm would invert Eq. 4's gap monotonicity,
            # which the batched online argmin relies on
            raise ValueError("eta and v_norm0 must be non-negative")
        if self.offline_window <= 0 or self.offline_resolution <= 0:
            raise ValueError(
                "offline_window and offline_resolution must be positive")
        if self.ready_delay < 0:
            raise ValueError(
                f"ready_delay must be non-negative, got {self.ready_delay}")
        if self.trace_every <= 0:
            raise ValueError(
                f"trace_every must be positive, got {self.trace_every}")
        if self.jax_chunk < 0:
            raise ValueError(
                f"jax_chunk must be positive (or 0 = auto-tune from "
                f"device memory), got {self.jax_chunk}")
        if self.push_log_capacity < 0:
            raise ValueError(
                f"push_log_capacity must be non-negative, got "
                f"{self.push_log_capacity}")
        if self.n_devices < 0:
            raise ValueError(
                f"n_devices must be >= 0 (0 = unsharded), got "
                f"{self.n_devices}")
        if self.n_devices:
            # The sharded scan only exists on the jax engine and has no
            # silent degrade path (falling back to one device would make
            # the knob lie about what ran) — reject ineligible configs
            # here with the reason, not mid-run.
            if self.engine in ("loop", "vectorized"):
                raise ValueError(
                    f"n_devices={self.n_devices} shards the jax chunked "
                    f"scan; it cannot run under engine={self.engine!r} — "
                    "use engine='jax' or 'auto'")
            for what, obj in (("policy", pol), ("dynamics", dyn)):
                if (what == "dynamics" and not dyn.active):
                    continue
                if not getattr(obj, "supports_jax", False):
                    raise ValueError(
                        f"n_devices={self.n_devices} needs a jax-capable "
                        f"{what}; {obj.name!r} has supports_jax=False")
                if not getattr(obj, "supports_shard", True):
                    raise ValueError(
                        f"{what} {obj.name!r} does not support the "
                        "sharded scan (supports_shard=False, e.g. host "
                        "callbacks inside the step); run with n_devices=0")
            if self.collect_push_log:
                if not asup["jax"]:
                    raise ValueError(
                        f"n_devices={self.n_devices} with a push log "
                        f"needs a jax-capable aggregation rule; "
                        f"{agg.name!r} implements no scan_weight hook")
                if not getattr(agg, "supports_shard", True):
                    raise ValueError(
                        f"aggregation rule {agg.name!r} does not support "
                        "the sharded scan (supports_shard=False); run "
                        "with n_devices=0")


@dataclasses.dataclass
class UserState:
    device: DeviceProfile
    mode: str = "cooldown"          # waiting | training | cooldown | off
    cooldown: int = 0
    app: Optional[str] = None
    app_remaining: float = 0.0
    train_remaining: float = 0.0
    corun: bool = False
    idle_gap: float = 0.0
    pulled_at: int = 0              # global version at pull
    started_at: int = 0
    energy_j: float = 0.0
    updates: int = 0
    plan: str = "none"              # offline policy: corun | separate | hold


@dataclasses.dataclass
class SimResult:
    energy_j: float
    updates: int
    trace_t: np.ndarray
    trace_energy: np.ndarray
    trace_Q: np.ndarray
    trace_H: np.ndarray
    push_log: Any                   # PushLog (list-of-dicts view): per push
    #                                 t, user, lag, gap, corun
    accuracy: List[tuple]           # (sim_t, test_acc) if ml_mode == real
    mean_Q: float
    mean_H: float
    corun_fraction: float
    drops: int = 0                  # mid-training dropouts (device churn;
    #                                 0 with dynamics="none")


# UserState.mode string <-> shared engine code (engine_state constants);
# the loop oracle builds the dynamics layer's mode view through this map.
_MODE_CODE = {"waiting": MODE_WAIT, "training": MODE_TRAIN,
              "cooldown": MODE_COOL, "off": MODE_OFF}


def n_slots(cfg: SimConfig) -> int:
    """Slots in the horizon. round() before int: 48 s / 1.6 s is
    29.999999999999996 in floats and plain int() would drop a slot."""
    return int(round(cfg.horizon_s / cfg.t_d))


def trace_v_norm(v_norm0: float, version) -> float:
    """Trace-mode momentum-norm model: ||v|| decays with global progress.
    Shared by the loop oracle and the vectorized engines (version may be an
    array of per-finisher versions)."""
    return v_norm0 / np.sqrt(1.0 + 0.05 * version)


class FederatedSim:
    def __init__(self, cfg: SimConfig, ml_hooks: Optional[dict] = None, *,
                 ml_backend=None,
                 arrivals: Union[str, ArrivalProcess, None] = None,
                 fleet: Union[str, Fleet, None] = None):
        """ml_hooks (real mode): {"pull": fn()->params_version, "push":
        fn(uid, params)->PushResult, "local_train": fn(uid, params)->params,
        "evaluate": fn()->acc, "sync_submit", "sync_aggregate", "v_norm": fn()->float}

        ``ml_backend`` (real mode): a ``core.realml.BatchedMLBackend`` —
        the batched alternative to ``ml_hooks`` that the vectorized engine
        can drive cohort-at-a-time (the loop engine drives the same backend
        through its ``hooks()`` adapter). Pass one or the other, not both.

        ``arrivals``/``fleet`` plug in non-paper arrival processes and
        device fleets (core/arrivals.py, core/fleet.py); the defaults —
        Bernoulli(cfg.app_arrival_p) on the Table II round-robin fleet —
        consume the seeded rng stream draw-for-draw like the historical
        hard-coded setup, so existing seeded runs reproduce bit-for-bit.

        ``self.state`` is the run's ``EngineState`` — the one state pytree
        every engine threads. The loop oracle keeps its per-user
        ``UserState`` objects as the readable working view and routes the
        scalar fields (version, in_flight, round_open) plus the policy
        carry through the container; the batched engines consume it whole.
        """
        self.cfg = cfg
        self.policy = resolve_policy(cfg.policy)
        self.agg = resolve_aggregation(cfg.aggregation)
        self.dynamics = resolve_dynamics(cfg.dynamics)
        self.rng = np.random.default_rng(cfg.seed)
        self.ml_backend = ml_backend
        if ml_backend is not None:
            if ml_hooks is not None:
                raise ValueError(
                    "pass either ml_hooks or ml_backend, not both")
            if cfg.ml_mode != "real":
                raise ValueError(
                    "ml_backend requires ml_mode='real' (a backend couples "
                    "the schedule to actual training)")
            if getattr(ml_backend, "n_users", cfg.n_users) != cfg.n_users:
                raise ValueError(
                    f"ml_backend was built for {ml_backend.n_users} users; "
                    f"config has n_users={cfg.n_users}")
            self.ml = ml_backend.hooks()
        else:
            self.ml = ml_hooks or {}
        self.fleet = resolve_fleet(fleet if fleet is not None else "paper")
        self.fleet_spec = self.fleet.build(self.rng, cfg.n_users)
        self.users = [UserState(device=d) for d in self.fleet_spec.devices]
        self.sched = OnlineScheduler(cfg.V, cfg.L_b, cfg.eta, cfg.beta,
                                     cfg.epsilon, cfg.t_d)
        self.state = EngineState.init(cfg.n_users, cfg, self.policy,
                                      agg=self.agg, fleet=self.fleet_spec,
                                      dynamics=self.dynamics)
        if ml_backend is not None:
            # fleet-conditioned aggregation (hetero_aware) needs the
            # run's FleetSpec; the backend forwards it to its server,
            # gathers the rule carry for the fused push scan, and keeps
            # the config for the rule's scan_operands
            ml_backend.bind_fleet(self.fleet_spec, cfg)
            brule = getattr(getattr(ml_backend, "server", None), "rule",
                            None)

            def _knobs(r):   # public instance attrs = the rule's knobs
                return {k: v for k, v in vars(r).items()
                        if not k.startswith("_")}

            def _same_knobs(a, b):
                # per-value np.array_equal: dict != would raise the
                # ambiguous-truth ValueError on array-valued knobs
                return a.keys() == b.keys() and \
                    all(np.array_equal(a[k], b[k]) for k in a)

            if brule is not None and brule is not self.agg and \
                    (brule.name != self.agg.name or
                     not _same_knobs(_knobs(brule), _knobs(self.agg))):
                # name AND knobs must match: same-class rules with
                # different alpha/a/gap_ref would silently attribute the
                # run to the wrong hyperparameters
                raise ValueError(
                    f"ml_backend was built with aggregation rule "
                    f"{brule.name!r} ({_knobs(brule) or 'no knobs'}) "
                    f"but the config says {self.agg.name!r} "
                    f"({_knobs(self.agg) or 'no knobs'}); in real mode "
                    "the backend's server applies the pushes, so the "
                    "two must agree (Scenario threads cfg.aggregation "
                    "automatically)")
        # Pre-sample the app arrival schedule (offline policy needs
        # lookahead), one row per SLOT — t_d < 1 means more slots than
        # seconds. (For t_d == 1 this matches the historical horizon_s
        # sizing draw-for-draw, keeping seeded runs reproducible.)
        self.arrivals: ArrivalProcess = resolve_arrival_or_default(
            arrivals, cfg.app_arrival_p)
        T = n_slots(cfg)
        self.app_sched, self.app_choice = self.arrivals.sample(
            self.rng, T, cfg.n_users, len(APPS), cfg.t_d)
        self.app_sched = np.asarray(self.app_sched, dtype=bool)
        self.app_choice = np.asarray(self.app_choice, dtype=np.int64)
        if self.app_sched.shape != (T, cfg.n_users) or \
                self.app_choice.shape != (T, cfg.n_users):
            raise ValueError(
                f"arrival process {self.arrivals.name!r} produced shapes "
                f"{self.app_sched.shape}/{self.app_choice.shape}; "
                f"expected {(T, cfg.n_users)}")
        if T and (self.app_choice.min() < 0 or
                  self.app_choice.max() >= len(APPS)):
            # out-of-range choices would index catalog tables from the
            # end (numpy) or clamp (jax gather) — silently wrong energy
            raise ValueError(
                f"arrival process {self.arrivals.name!r} produced app "
                f"choices outside [0, {len(APPS)})")

    # ------------------------------------------------------------ state views
    # Scalar server state lives in self.state (the shared EngineState);
    # these properties keep the historical sim.version / sim.in_flight /
    # sim._round_open spelling for policy hooks and ML backends.
    @property
    def version(self) -> int:
        return self.state.version

    @version.setter
    def version(self, v: int):
        self.state.version = v

    @property
    def in_flight(self) -> int:
        return self.state.in_flight

    @in_flight.setter
    def in_flight(self, v: int):
        self.state.in_flight = v

    @property
    def _round_open(self) -> bool:
        return self.state.round_open

    @_round_open.setter
    def _round_open(self, v: bool):
        self.state.round_open = v

    # ------------------------------------------------------------------ utils
    def _v_norm(self) -> float:
        if "v_norm" in self.ml:
            return self.ml["v_norm"]()
        return trace_v_norm(self.cfg.v_norm0, self.version)

    def begin_training(self, u: UserState, t: int, corun: bool):
        """Start user ``u`` training this slot (public: the loop-engine
        twin of _NumpyEngine.begin_training, called from Policy.decide_loop
        hooks)."""
        u.mode = "training"
        u.corun = corun and u.app is not None
        u.train_remaining = u.device.duration(u.corun, u.app)
        u.pulled_at = self.version
        u.started_at = t
        self.in_flight += 1
        if self.ml.get("pull"):
            u._params = self.ml["pull"](u._uid)

    def _finish_training(self, u: UserState, t: int, log: PushLog,
                         extra_delay: int = 0):
        """``extra_delay`` is the device-dynamics network penalty (slots):
        a finisher in the bad network state re-arrives late, so its next
        pull is staler — the churn layer's feed into the lag model."""
        lag = self.version - u.pulled_at
        vn = self._v_norm()
        gap = gradient_gap(vn, lag, self.cfg.eta, self.cfg.beta)
        res = None
        if self.policy.sync_rounds:
            if self.ml.get("sync_submit"):
                trained = self.ml["local_train"](u._uid, u._params)
                self.ml["sync_submit"](trained)
        else:
            self.version += 1
            if self.ml.get("push"):
                trained = self.ml["local_train"](u._uid, u._params)
                res = self.ml["push"](u._uid, trained)
        u.updates += 1
        u.mode = "cooldown"
        u.cooldown = self.cfg.ready_delay + extra_delay
        u.idle_gap = 0.0
        self.in_flight -= 1
        if self.cfg.collect_push_log:
            # applied aggregation weight, only materialized for the log:
            # what the server DID (real mode), the rule's value (trace),
            # or 1.0 for FedAvg rounds (no per-push weight)
            if self.policy.sync_rounds:
                weight = 1.0
            elif res is not None and \
                    getattr(res, "applied_weight", None) is not None:
                weight = float(res.applied_weight)
            else:
                weight = float(self.agg.weight(lag, gap, vn,
                                               fleet=self.fleet_spec,
                                               users=u._uid))
            log.append(t, u._uid, lag, gap, u.corun, weight)

    # ------------------------------------------------------------------ main
    def resolve_engine(self) -> str:
        """Pick the engine to run. The vectorized SoA engine covers two
        regimes: pure trace mode (real-ML *hooks* other than the
        slot-constant ``v_norm`` need the per-user object loop) and real
        mode driven by a batched ``ml_backend`` (core/realml.py), whose
        cohort-level entry points the engine dispatches once per slot.
        ``auto`` selects it whenever the policy implements the vectorized
        hook; real mode with per-user hooks (or no backend) stays on the
        loop oracle. The jax backend covers hook-free trace runs of
        policies with the ``scan_step`` carry hook — all registry policies
        qualify, including offline (its knapsack plan runs through a host
        callback) and greedy (wait counters in the carry); push-log
        collection streams out of the scan and is NOT a jax blocker. With
        a ``v_norm`` hook or an ml_backend (Python callbacks cannot run
        under the scan per slot) it degrades to the numpy engine, which
        honors both; policies without scan_step degrade the same way."""
        cfg = self.cfg
        pol = self.policy
        vec_ok = (cfg.ml_mode == "trace" and set(self.ml) <= {"v_norm"}) \
            or (cfg.ml_mode == "real" and self.ml_backend is not None)
        engine = cfg.engine
        if cfg.n_devices:
            # the sharded scan (SimConfig validated policy/agg/dynamics
            # shard support at construction) runs only on the jax engine
            # and never degrades silently — remaining blockers are the
            # per-slot host callbacks the scan cannot shard
            if self.ml or self.ml_backend is not None:
                raise ValueError(
                    f"n_devices={cfg.n_devices} shards the jax chunked "
                    "scan, which cannot run per-user ML hooks or a "
                    "real-ML backend; set n_devices=0 for those runs")
            return "jax"
        if engine == "auto":
            return "vectorized" if (vec_ok and pol.supports_vectorized) \
                else "loop"
        if engine in ("vectorized", "jax") and not vec_ok:
            raise ValueError(
                f"engine={engine!r} supports trace-mode runs without "
                "per-user ML hooks, or ml_mode='real' with a batched "
                "ml_backend; use engine='loop' (or 'auto') for "
                "hook-based real-ML runs")
        if engine == "vectorized" and not pol.supports_vectorized:
            raise ValueError(
                f"policy {pol.name!r} implements no vectorized hook; "
                "use engine='loop' (or 'auto')")
        if engine == "jax":
            # a push log under a rule without a traced scan_weight cannot
            # fill the weight column in-scan: degrade like a policy
            # without scan_step (weight-free runs are unaffected)
            agg_jax = aggregation_support(self.agg)["jax"] or \
                not cfg.collect_push_log
            # an active dynamics without a traced scan_step degrades the
            # same way (the numpy engine runs its host transition)
            dyn_jax = dynamics_support(self.dynamics)["jax"]
            if pol.supports_jax and agg_jax and dyn_jax and \
                    not self.ml and self.ml_backend is None:
                return "jax"
            # degrade in capability order: numpy SoA if the policy has the
            # hook (any policy under a v_norm callback, or any real-mode
            # backend run), else the loop oracle, which runs everything
            return "vectorized" if pol.supports_vectorized else "loop"
        return engine

    def run(self) -> SimResult:
        if getattr(self, "_ran", False):
            # a run consumes the mutable EngineState / UserState objects;
            # reallocate them so repeated run() calls (warmup-then-timed
            # patterns) start fresh instead of continuing silently from
            # the previous run's state. Real-ML backends/hook closures are
            # single-run by contract and are NOT reset here.
            self.state = EngineState.init(self.cfg.n_users, self.cfg,
                                          self.policy, agg=self.agg,
                                          fleet=self.fleet_spec,
                                          dynamics=self.dynamics)
            self.users = [UserState(device=d)
                          for d in self.fleet_spec.devices]
            self.sched.Q = 0.0
            self.sched.H = 0.0
        self._ran = True
        engine = self.resolve_engine()
        if engine == "loop":
            return self._run_loop()
        from .vector_engine import run_vectorized
        return run_vectorized(self, backend=engine)

    def _run_loop(self) -> SimResult:
        cfg = self.cfg
        policy = self.policy
        es = self.state                   # scalar/carry state container
        dynamics = self.dynamics
        dyn_active = dynamics.active
        up = net_extra = None
        for i, u in enumerate(self.users):
            u._uid = i
            u._params = None
        T = n_slots(cfg)
        trace_t, trace_E, trace_Q, trace_H = [], [], [], []
        push_log = PushLog()
        accuracy: List[tuple] = []
        carry = es.carry

        for t in range(T):
            arrivals = 0
            departures = 0

            # --- device dynamics (churn) ------------------------------------
            # Runs FIRST in the slot on every engine: the shared host
            # transition decides who went up/down, then the effects are
            # applied in the loop idiom — a waiting user that churns off
            # leaves the request queue (departure), a training user drops
            # per the dynamics' rule ("lose": in-flight work discarded;
            # "resume": paused, pays the penalty), a recovered user
            # re-enters the arrival process through cooldown with the
            # network state's extra delay.
            if dyn_active:
                mode_arr = np.array([_MODE_CODE[u.mode] for u in self.users],
                                    dtype=np.int8)
                corun_arr = np.array([u.corun for u in self.users],
                                     dtype=bool)
                es.dyn, es.rng_key, eff = dynamics.host_step(
                    es.dyn, es.rng_key, mode_arr, corun_arr, cfg.t_d)
                up = np.asarray(eff.up)
                net_extra = np.asarray(eff.net_extra)
                for i, u in enumerate(self.users):
                    if eff.went_down[i]:
                        if u.mode == "waiting":
                            u.mode = "off"
                            departures += 1
                        elif u.mode == "training":
                            if dynamics.dropout == "lose":
                                u.mode = "off"
                                u.train_remaining = 0.0
                                self.in_flight -= 1
                            else:       # resume: paused, extra seconds
                                u.train_remaining += float(
                                    eff.resume_penalty)
                        elif u.mode == "cooldown":
                            u.mode = "off"
                    elif eff.went_up[i] and u.mode == "off":
                        u.mode = "cooldown"
                        u.cooldown = cfg.ready_delay + int(net_extra[i])

            # --- app arrivals / progression -------------------------------
            for i, u in enumerate(self.users):
                if u.app is None and self.app_sched[t, i]:
                    u.app = APPS[self.app_choice[t, i]]
                    u.app_remaining = u.device.apps[u.app].t_corun
                elif u.app is not None:
                    u.app_remaining -= cfg.t_d
                    if u.app_remaining <= 0:
                        u.app, u.app_remaining = None, 0.0

            # --- cooldown -> waiting (queue arrival) ------------------------
            for u in self.users:
                if u.mode == "cooldown":
                    u.cooldown -= 1
                    if u.cooldown <= 0:
                        u.mode = "waiting"
                        u.plan = "hold"   # offline: wait for next plan window
                        arrivals += 1

            # --- policy decisions for waiting users -------------------------
            waiting = [u for u in self.users if u.mode == "waiting"]
            served, gap_sum = policy.decide_loop(self, t, waiting, carry)

            # --- training progression ---------------------------------------
            # Under churn a down trainer makes no progress (a "resume"
            # dropout is paused, not working), and a finisher's cooldown
            # carries the current network state's extra delay.
            for u in self.users:
                if u.mode == "training" and (not dyn_active or up[u._uid]):
                    u.train_remaining -= cfg.t_d
                    if u.train_remaining <= 0:
                        self._finish_training(
                            u, t, push_log,
                            extra_delay=int(net_extra[u._uid])
                            if dyn_active else 0)
                        if u.corun:
                            es.corun_updates += 1
            if policy.sync_rounds and self._round_open and \
                    all(u.mode != "training" for u in self.users):
                self._round_open = False
                self.version += 1
                if self.ml.get("sync_aggregate"):
                    self.ml["sync_aggregate"]()

            # --- energy accounting (Eq. 10) ---------------------------------
            # A down device draws nothing (off) — a paused "resume"
            # trainer included.
            for u in self.users:
                p = u.device.power(u.mode == "training", u.app is not None, u.app)
                if cfg.include_scheduler_overhead and u.mode == "waiting" \
                        and policy.uses_online_queue:
                    p += u.device.p_sched - u.device.p_idle
                if dyn_active and not up[u._uid]:
                    p = 0.0
                u.energy_j += p * cfg.t_d

            # --- queues ------------------------------------------------------
            self.sched.update_queues(arrivals, served, gap_sum, departures)
            es.Q, es.H = self.sched.Q, self.sched.H
            es.sum_Q += es.Q
            es.sum_H += es.H

            if t % cfg.trace_every == 0:
                trace_t.append(t)
                trace_E.append(sum(u.energy_j for u in self.users))
                trace_Q.append(es.Q)
                trace_H.append(es.H)
            eval_every = self.ml.get("eval_every", 600)
            if self.ml.get("evaluate") and eval_every and \
                    t % eval_every == 0 and t > 0:
                accuracy.append((t, self.ml["evaluate"]()))

        if self.ml.get("evaluate"):
            accuracy.append((T, self.ml["evaluate"]()))
        updates = sum(u.updates for u in self.users)
        return SimResult(
            energy_j=sum(u.energy_j for u in self.users),
            updates=updates,
            trace_t=np.array(trace_t), trace_energy=np.array(trace_E),
            trace_Q=np.array(trace_Q), trace_H=np.array(trace_H),
            push_log=push_log, accuracy=accuracy,
            mean_Q=es.sum_Q / T if T else 0.0,
            mean_H=es.sum_H / T if T else 0.0,
            corun_fraction=es.corun_updates / max(updates, 1),
            drops=dynamics.total_drops(es.dyn))
