"""First-class aggregation rules: how the server APPLIES a push.

The paper's Sec. VI server uses the plain "replace" rule, but the
gradient-gap machinery (Eqs. 2-4) exists precisely because stale pushes
should not count at full weight. This module turns the application rule
into a registry object mirroring the ``Policy`` carry protocol
(core/policies.py), so staleness-aware aggregation is visible to EVERY
layer of the push path — the loop oracle's ``AsyncParameterServer``, the
vectorized engine's in-slot push replay, the jax engine's ``lax.scan``
push scatter, and the fused train+push scan of
``realml.BatchedMLBackend`` — instead of living as an if/elif ladder
inside the server.

An ``AggregationRule`` exposes three paths:

``weight(lag, gap, v_norm, fleet=None, users=None)``
    The host (numpy) path: the applied mixing weight in ``[0, 1]`` for a
    push (or a whole finisher cohort — ``lag``/``gap`` broadcast as
    arrays). ``fleet`` is the run's ``FleetSpec`` and ``users`` the
    pushing user id(s); fleet-conditioned rules read device classes from
    them. The server applies ``theta <- w * theta_push + (1-w) * theta``
    (``w == 1`` is the paper's replace rule).
``init_carry(n, cfg, fleet=None)``
    One pytree of per-run rule state threaded by every engine
    (``EngineState.agg_carry``) — e.g. ``hetero_aware``'s per-user
    device-class scale vector, gathered once at run start. ``None`` for
    stateless rules. The carry is RUN-CONSTANT lookup state, not an
    evolving accumulator: the host ``weight()`` path never sees it
    (fleet-derived values must be recomputable from ``fleet``/``users``)
    and the fused real-ML push scan reads one snapshot per cohort, so a
    carry that ``scan_weight`` mutated per push would diverge across
    engines — return it unchanged.
``scan_weight(carry, pv)``
    The traced twin, called inside the jax engines' scans: ``pv`` is a
    push view (``jnp``, ``lag``, ``gap``, ``v_norm``, ``users``,
    ``consts`` from ``scan_operands``, ``float_dtype``; arrays over the
    fleet in the trace scan, per-push scalars in the fused real-ML
    scan — write rules to broadcast). Must return ``(carry, weight)``
    with the carry unchanged (see ``init_carry``). Instance knobs must
    flow through ``scan_operands`` (traced), never be closed over —
    compiled scans are cached per ``jax_cache_key()``.

Equivalence contract: for a given push the three paths must produce the
same weight — tests/test_engine_matrix.py pins loop/vectorized/jax weight
parity for every registered rule, and tests/test_aggregation.py holds the
property ``0 <= weight <= 1`` plus ``fedasync_poly``'s monotone
non-increase in lag.

Ships: ``replace`` (the paper, weight 1), ``fedasync_poly`` (Xie et al.
[30]: ``alpha * (1+lag)^-a``), ``gap_aware`` (dampen by the Eq. 4 gap
estimate: ``1 / (1 + gap/gap_ref)``), and ``hetero_aware`` (AutoFL-style
fleet conditioning: the staleness polynomial scaled per device class by
relative training speed, so slow — stale-prone — classes contribute
less). Strings resolve through the registry (``resolve_aggregation``);
instances carry custom knobs.
"""
from __future__ import annotations

from typing import Dict, Tuple, Type

import numpy as np

__all__ = ["AggregationRule", "ReplaceRule", "FedAsyncPolyRule",
           "GapAwareRule", "HeteroAwareRule", "register_aggregation",
           "registered_aggregations", "resolve_aggregation",
           "configure_aggregation", "aggregation_support", "hetero_scales"]


class AggregationRule:
    """Base aggregation rule. Subclass, set ``name``, implement the
    paths, and decorate with ``@register_aggregation``.

    Class attributes engines dispatch on:

    - ``needs_gap``: the weight reads the Eq. (4) gap / momentum norm, so
      the fused real-ML push scan must materialize the per-push norm even
      when no push log is collected.
    - ``supports_jax``: a traced ``scan_weight`` exists, so the rule can
      run inside the jax engine's scan and the fused real-ML push scan.
      ``SimConfig`` validates the flag against the actual hook at
      construction; rules without it degrade the jax engine to the numpy
      path (and the fused real-ML finish to per-push server calls).
    """

    name: str = ""
    needs_gap: bool = False
    supports_jax: bool = True

    # ------------------------------------------------------------ host path
    def weight(self, lag, gap, v_norm, fleet=None, users=None):
        """Applied mixing weight(s) in ``[0, 1]``; ``lag``/``gap``
        broadcast (scalars from the loop server, arrays from the
        vectorized engine's finisher cohorts)."""
        raise NotImplementedError(
            f"aggregation rule {self.name!r} implements no weight()")

    # ------------------------------------------------------------ carry
    def init_carry(self, n: int, cfg=None, fleet=None):
        """Per-run rule state as ONE pytree (``EngineState.agg_carry``);
        ``None`` for stateless rules."""
        return None

    def scan_operands(self, cfg) -> tuple:
        """Instance knobs the traced hook needs, as a flat scalar tuple
        (traced operands — ``pv.consts`` — so knob sweeps share one
        compiled scan). ``cfg`` is the run's SimConfig when an engine
        calls this, but may be ``None`` outside a run (a backend that
        was never bound to a sim) — keep knobs on the instance rather
        than reading cfg where possible."""
        return ()

    def jax_cache_key(self):
        """Hashable token identifying this rule's ``scan_weight``
        behavior (same contract as ``Policy.jax_cache_key``): class-keyed
        when provably safe, else instance-keyed."""
        if not vars(self) or \
                type(self).scan_operands is not AggregationRule.scan_operands:
            return type(self)
        return self


    # ------------------------------------------------------------ traced path
    def scan_weight(self, carry, pv):
        """Traced weight inside a scan step. ``pv`` carries ``jnp``,
        ``lag``, ``gap``, ``v_norm``, ``users``, ``consts``,
        ``float_dtype``; return ``(carry, weight)`` with ``weight``
        broadcastable against ``pv.lag``. Only called when
        ``supports_jax``."""
        raise TypeError(
            f"aggregation rule {self.name!r} sets supports_jax but "
            "inherits the base scan_weight; implement the hook or clear "
            "the flag to degrade to the numpy engines")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[AggregationRule]] = {}
_INSTANCES: Dict[str, AggregationRule] = {}     # singletons for strings


def register_aggregation(cls: Type[AggregationRule]) -> Type[AggregationRule]:
    """Class decorator: make ``cls`` resolvable as ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    _REGISTRY[cls.name] = cls
    _INSTANCES.pop(cls.name, None)              # re-registration wins
    return cls


def registered_aggregations() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_aggregation(rule) -> AggregationRule:
    """String -> registered singleton; AggregationRule instance -> itself."""
    if isinstance(rule, AggregationRule):
        return rule
    if isinstance(rule, str):
        if rule not in _REGISTRY:
            raise ValueError(
                f"unknown aggregation {rule!r}; expected one of "
                f"{registered_aggregations()} or an AggregationRule "
                "instance")
        if rule not in _INSTANCES:
            _INSTANCES[rule] = _REGISTRY[rule]()
        return _INSTANCES[rule]
    raise ValueError(f"aggregation must be a name or AggregationRule "
                     f"instance, got {type(rule).__name__}")


def configure_aggregation(rule, *, fedasync_alpha: float = 0.6,
                          fedasync_a: float = 0.5,
                          gap_ref: float = 1.0) -> AggregationRule:
    """``resolve_aggregation`` plus the legacy knob kwargs both servers
    accept: a registry NAME given with non-default knob values constructs
    the matching configured rule instead of the shared singleton. Rule
    instances pass through untouched (their own knobs win). One home for
    the ladder so ``AsyncParameterServer`` and the sharded serving tier
    cannot drift."""
    if isinstance(rule, str) and rule == "fedasync_poly" \
            and (fedasync_alpha != 0.6 or fedasync_a != 0.5):
        return FedAsyncPolyRule(fedasync_alpha, fedasync_a)
    if isinstance(rule, str) and rule == "gap_aware" and gap_ref != 1.0:
        return GapAwareRule(gap_ref)
    return resolve_aggregation(rule)


def aggregation_support(rule: AggregationRule) -> Dict[str, bool]:
    """Which paths ``rule`` GENUINELY implements (flag set AND the base
    stub overridden) — the SimConfig-validation twin of
    ``policies.engine_support``."""
    cls = type(rule)
    return {
        "host": cls.weight is not AggregationRule.weight,
        "jax": (rule.supports_jax and
                cls.scan_weight is not AggregationRule.scan_weight),
    }


# ---------------------------------------------------------------------------
# Shipped rules
# ---------------------------------------------------------------------------
@register_aggregation
class ReplaceRule(AggregationRule):
    """The paper's Sec. VI rule: every push lands at full weight."""

    name = "replace"

    def weight(self, lag, gap, v_norm, fleet=None, users=None):
        lag = np.asarray(lag)
        return np.ones(lag.shape) if lag.ndim else 1.0

    def scan_weight(self, carry, pv):
        jnp = pv.jnp
        return carry, jnp.ones(jnp.shape(pv.lag), pv.float_dtype)


@register_aggregation
class FedAsyncPolyRule(AggregationRule):
    """FedAsync polynomial staleness weighting (Xie et al. [30]):
    ``w = alpha * (1 + lag)^-a`` — monotone non-increasing in lag,
    bounded by ``alpha <= 1``."""

    name = "fedasync_poly"

    def __init__(self, alpha: float = 0.6, a: float = 0.5):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0, 1], got {alpha}")
        if a < 0.0:
            raise ValueError(f"a must be non-negative, got {a}")
        self.alpha = float(alpha)
        self.a = float(a)

    def scan_operands(self, cfg):
        return (self.alpha, self.a)

    def weight(self, lag, gap, v_norm, fleet=None, users=None):
        # np.power on the scalar path too: python ** disagrees with the
        # np.power ufunc by an ulp for some bases, and the loop server
        # (scalar) must produce the vectorized engine's (array) bits —
        # same trick as staleness.momentum_scale
        return self.alpha * np.power(1.0 + lag, -self.a)

    def scan_weight(self, carry, pv):
        alpha, a = pv.consts
        return carry, alpha * (1.0 + pv.lag) ** (-a)


@register_aggregation
class GapAwareRule(AggregationRule):
    """Dampen by the Eq. (4) gradient-gap estimate: a push predicted to
    land ``gap`` away from the current model mixes at
    ``w = 1 / (1 + gap / gap_ref)`` — fresh pushes (gap 0) at full
    weight, weight halved at ``gap == gap_ref``."""

    name = "gap_aware"
    needs_gap = True

    def __init__(self, gap_ref: float = 1.0):
        if gap_ref <= 0.0:
            raise ValueError(f"gap_ref must be positive, got {gap_ref}")
        self.gap_ref = float(gap_ref)

    def scan_operands(self, cfg):
        return (self.gap_ref,)

    def weight(self, lag, gap, v_norm, fleet=None, users=None):
        # no clamp: the constructor guarantees gap_ref > 0, and a host
        # clamp the traced path lacks would break three-path equivalence
        return 1.0 / (1.0 + gap / self.gap_ref)

    def scan_weight(self, carry, pv):
        (gap_ref,) = pv.consts
        return carry, 1.0 / (1.0 + pv.gap / gap_ref)


_SCALE_CACHE: dict = {}      # id(fleet) -> (fleet strong ref, scales)
_SCALE_CACHE_MAX = 8


def hetero_scales(fleet) -> np.ndarray:
    """Per-catalog-row device-class scale in ``(0, 1]``: relative
    training speed ``min(t_train) / t_train`` — the fastest class scores
    1.0, a class twice as slow 0.5. Slow classes hold the global model
    longest (Lemma 1 couples lag to training duration), so AutoFL-style
    conditioning downweights exactly the stale-prone contributions.

    Fleet-constant, but called per push on the loop-server path — a
    small keep-alive cache (the strong ref pins the id) makes repeat
    lookups O(1) instead of O(catalog) per push."""
    hit = _SCALE_CACHE.pop(id(fleet), None)    # pop+reinsert = LRU order
    if hit is not None and hit[0] is fleet:
        _SCALE_CACHE[id(fleet)] = hit
        return hit[1]
    tt = np.asarray(fleet.tables.t_train, dtype=np.float64)
    scales = tt.min() / tt
    if len(_SCALE_CACHE) >= _SCALE_CACHE_MAX:
        _SCALE_CACHE.pop(next(iter(_SCALE_CACHE)))  # evict LRU
    _SCALE_CACHE[id(fleet)] = (fleet, scales)
    return scales


@register_aggregation
class HeteroAwareRule(AggregationRule):
    """Fleet-conditioned staleness weighting (AutoFL-style: Kim & Wu
    '21 motivate conditioning on device-class heterogeneity, DEAL (Zou
    et al. '21) energy-aware client weighting): the FedAsync polynomial
    scaled per device class by ``hetero_scales`` —
    ``w = scale(class(u)) * (1 + lag)^-a``.

    The per-user scale vector is the rule's carry
    (``init_carry(fleet=...)`` gathers it once from ``FleetSpec``); the
    host path reads it from the ``FleetSpec`` directly, so a bound fleet
    is REQUIRED — the rule refuses to silently ignore heterogeneity."""

    name = "hetero_aware"

    def __init__(self, a: float = 0.5):
        if a < 0.0:
            raise ValueError(f"a must be non-negative, got {a}")
        self.a = float(a)

    def scan_operands(self, cfg):
        return (self.a,)

    def init_carry(self, n, cfg=None, fleet=None):
        if fleet is None:
            raise ValueError(
                "hetero_aware needs the run's FleetSpec to derive "
                "device-class scales; engines pass it automatically")
        return {"scale": hetero_scales(fleet)[fleet.device_ids]}

    def weight(self, lag, gap, v_norm, fleet=None, users=None):
        if fleet is None or users is None:
            raise ValueError(
                "hetero_aware weights are fleet-conditioned: pass the "
                "run's FleetSpec and the pushing user id(s) (bind the "
                "server to a fleet, or run through an engine)")
        scale = hetero_scales(fleet)[fleet.device_ids[users]]
        # np.power for scalar-vs-array bit identity (see FedAsyncPolyRule)
        return scale * np.power(1.0 + lag, -self.a)

    def scan_weight(self, carry, pv):
        (a,) = pv.consts
        scale = carry["scale"][pv.users]
        return carry, scale * (1.0 + pv.lag) ** (-a)
