"""Pluggable scheduling policies: one registry, one carry protocol,
three engine hooks.

The paper evaluates four fixed schedulers (Sec. VII.B); this module turns
them into registered ``Policy`` objects so alternative schedulers — e.g.
the energy-minimal scheduling families of Pilla '22 or AutoFL-style
heterogeneity-aware schedulers — plug into the simulator without touching
any engine file.

Policy state is declarative: ``init_carry(n, cfg)`` returns ONE pytree of
per-run policy state (e.g. greedy's per-user wait counters, offline's next
plan slot) that every engine threads for the policy — the loop oracle and
the numpy engine mutate it in place, the jax backend carries it through
``lax.scan`` inside ``EngineState.carry``. A policy implements up to three
hooks, one per engine:

``decide_loop(sim, t, waiting, carry)``
    Reference semantics on the per-user object loop (the oracle). Required.
``decide_vectorized(eng, t, carry)``
    Same decisions on the struct-of-arrays numpy engine
    (``core/vector_engine.py``); the batched state is ``eng.s`` (an
    ``EngineState``). Set ``supports_vectorized = True``.
``scan_step(carry, sv) -> (carry, (start_mask, gap_sum))``
    Traced decision step inside the ``jax.lax.scan`` backend; set
    ``supports_jax = True``. ``sv`` is the mutable slot view the engine
    builds per step (masks, table gathers, queue scalars, the full-horizon
    arrival arrays for oracle lookahead). The hook must be functional in
    ``carry`` and may reach back to the host with ``sv.jax.pure_callback``
    for decision logic that cannot be traced (the offline knapsack does).
    Instance knobs must flow through ``scan_operands`` (traced operands),
    NOT be closed over — compiled scans are cached per ``jax_cache_key()``,
    which defaults to the policy class. Policies without the hook
    transparently degrade to the vectorized engine.

Equivalence contract: for a given seed the three hooks must produce the
same decision sequence — tests/test_sim_engines.py, tests/test_scenario.py
and tests/test_engine_matrix.py pin loop/vectorized/jax schedule parity
(bit-for-bit under ``jax_enable_x64``) for every registered policy.

Strings keep working everywhere: ``SimConfig(policy="online")`` resolves
through the registry (``resolve_policy``), and string lookups hand out a
per-name singleton. New code should pass ``Policy`` instances (see
``core/scenario.py``).
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple, Type

import numpy as np

# jax's pure_callback round-trips its operands through jax.device_put onto
# the CPU device before invoking the host function; forcing them back to
# numpy inside the callback then waits on a device whose only execution
# thread is parked inside the custom call waiting for the callback to
# return. On one-core hosts that is a hard deadlock (observed on the
# offline policy's plan_window callback from n_users~100 up). A second
# host-platform device gives the operand transfer its own thread.
# Best-effort: the flag only takes effect if jax has not yet created its
# CPU client when this module is first imported.
if os.cpu_count() == 1 and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=2"
                               ).strip()

from .energy import APPS
from .engine_state import (MODE_COOL, MODE_TRAIN, MODE_WAIT, PLAN_CORUN,
                           PLAN_HOLD, PLAN_SEP)
from .lyapunov import UserSlotState
from .offline import knapsack_schedule, lemma1_lag_bounds
from .staleness import gradient_gap

__all__ = ["Policy", "register_policy", "registered_policies",
           "resolve_policy", "plan_window",
           "SyncPolicy", "ImmediatePolicy", "OnlinePolicy", "OfflinePolicy",
           "GreedyThresholdPolicy", "EpsGreedyPolicy",
           "MODE_WAIT", "MODE_TRAIN", "MODE_COOL",
           "PLAN_HOLD", "PLAN_CORUN", "PLAN_SEP"]


class Policy:
    """Base scheduling policy. Subclass, set ``name``, implement hooks,
    and decorate with ``@register_policy`` to make the name resolvable.

    Class attributes describe engine semantics the engines must honor:

    - ``sync_rounds``: lock-step rounds — the global version bumps once per
      round close (all trainers finished), not per push.
    - ``uses_online_queue``: the per-slot Lyapunov decision runs on-device,
      so ``include_scheduler_overhead`` adds Table III's scheduler power
      while waiting.
    - ``supports_vectorized`` / ``supports_jax``: which engine hooks exist.
      ``SimConfig`` validates the flags against the actual hook methods at
      construction, so a mismatch fails fast with a clear message instead
      of erroring mid-run.
    - ``supports_vmap``: whether ``scan_step`` may run under ``jax.vmap``
      over a leading config axis (the batched sweep path,
      ``core.scenario.run_sweep``). True for pure traced hooks; set False
      for hooks with host side effects — under vmap ``lax.cond`` evaluates
      both branches per config, so e.g. a ``pure_callback`` guarded by a
      plan-slot cond would fire for every config at every slot.
    - ``supports_shard``: whether ``scan_step`` may run with the user axis
      sharded over a device mesh (``SimConfig.n_devices``,
      core/vector_engine.py). The engine hands the hook REPLICATED
      per-user inputs (so cross-user reductions like Eq. 16's gap sum
      keep the single-device float order) plus padding helpers:
      ``sv.n`` is always the LIVE user count, ``sv.n_arr`` the padded
      array length (== ``sv.n`` unsharded), and hooks drawing per-user
      randomness must draw at ``sv.n`` and extend via
      ``sv.pad_users(x, fill)`` — threefry draws are shape-dependent, so
      drawing at ``n_arr`` would fork the stream from the unsharded
      engines. Set False for hooks with host callbacks in the step.
    """

    name: str = ""
    sync_rounds: bool = False
    uses_online_queue: bool = False
    supports_vectorized: bool = False
    supports_jax: bool = False
    supports_vmap: bool = True
    supports_shard: bool = True

    # ------------------------------------------------------------ carry
    def init_carry(self, n: int, cfg):
        """Per-run policy state as ONE pytree shared by every engine:
        numpy arrays / scalars that the loop and numpy engines mutate in
        place and the jax backend converts to device arrays and threads
        through the scan (``EngineState.carry``). Return ``None`` for
        stateless policies."""
        return None

    def scan_operands(self, cfg) -> tuple:
        """Instance knobs the jax hook needs, as a flat tuple of scalars.
        They are passed as TRACED operands (``sv.consts``), so runs with
        different knob values share one compiled scan; reading instance
        attributes directly from ``scan_step`` instead would bake the
        first run's values into the class-keyed executable cache."""
        return ()

    def scan_statics(self, cfg) -> tuple:
        """Values the jax hook needs as STATIC Python constants (e.g.
        shapes of intermediate slices), as a flat hashable tuple. Unlike
        ``scan_operands`` these are baked into the trace (``sv.statics``)
        and included in the jit cache key, so each distinct tuple compiles
        its own scan — keep them to genuinely shape-like knobs."""
        return ()

    def jax_cache_key(self):
        """Hashable token identifying this policy's ``scan_step``
        behavior: two policies with equal keys share one compiled scan.

        The default keys by CLASS when that is provably safe — the
        instance carries no attributes, or it declares its knobs through
        ``scan_operands`` (traced) — so fresh instances of registry
        policies reuse one executable per shape. Any other instance is
        keyed by itself: a ``scan_step`` that reads ad-hoc instance state
        directly then at worst recompiles per instance, never silently
        reuses another instance's baked-in values. Policies that override
        ``scan_operands`` must route ALL hook-read knobs through it (or
        ``scan_statics``)."""
        if not vars(self) or \
                type(self).scan_operands is not Policy.scan_operands:
            return type(self)
        return self

    # ------------------------------------------------------------- loop hook
    def decide_loop(self, sim, t: int, waiting: list, carry
                    ) -> Tuple[int, float]:
        """Schedule waiting users for slot ``t`` via ``sim.begin_training``.
        Returns (served, gap_sum) feeding Eqs. (15)/(16)."""
        raise NotImplementedError(
            f"policy {self.name!r} implements no loop hook")

    # ------------------------------------------------- vectorized (numpy) hook
    def decide_vectorized(self, eng, t: int, carry) -> Tuple[int, float]:
        """Same decisions on the batched engine ``eng`` (state:
        ``eng.s``, an EngineState; per-slot masks: ``eng.waiting`` /
        ``eng.has_app``). Returns (served, gap_sum). Only called when
        ``supports_vectorized``; SimConfig validates the flag against the
        hook at construction."""
        raise TypeError(
            f"policy {self.name!r} sets supports_vectorized but inherits "
            "the base decide_vectorized; implement the hook or clear the "
            "flag")

    # ----------------------------------------------------------- jax scan hook
    def scan_step(self, carry, sv):
        """Traced decision inside the lax.scan step. Read the slot view
        ``sv`` (``waiting``, ``has_app``, per-user power gathers, queue
        scalars, ``sv.consts`` from ``scan_operands``); write ``sv.idle_gap``
        / ``sv.round_open`` / ``sv.plan`` if the policy owns them. Return
        ``(carry, (start_mask, gap_sum))``. Only called when
        ``supports_jax``; SimConfig validates the flag against the hook at
        construction."""
        raise TypeError(
            f"policy {self.name!r} sets supports_jax but inherits the base "
            "scan_step; implement the hook or clear the flag")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[Policy]] = {}
_INSTANCES: Dict[str, Policy] = {}       # singletons for string lookups


def register_policy(cls: Type[Policy]) -> Type[Policy]:
    """Class decorator: make ``cls`` resolvable as ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    _REGISTRY[cls.name] = cls
    _INSTANCES.pop(cls.name, None)       # re-registration wins
    return cls


def registered_policies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_policy(policy) -> Policy:
    """String -> registered singleton; Policy instance -> itself."""
    if isinstance(policy, Policy):
        return policy
    if isinstance(policy, str):
        if policy not in _REGISTRY:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {registered_policies()} "
                             "or a Policy instance")
        if policy not in _INSTANCES:
            _INSTANCES[policy] = _REGISTRY[policy]()
        return _INSTANCES[policy]
    raise ValueError(f"policy must be a name or Policy instance, "
                     f"got {type(policy).__name__}")


def engine_support(policy: Policy) -> Dict[str, bool]:
    """Which engine hooks ``policy`` GENUINELY implements (flag set AND
    the base stub overridden). SimConfig uses this to reject
    flag-vs-implementation mismatches at construction instead of letting
    the base stubs raise mid-run."""
    cls = type(policy)
    return {
        "loop": cls.decide_loop is not Policy.decide_loop,
        "vectorized": (policy.supports_vectorized and
                       cls.decide_vectorized is not Policy.decide_vectorized),
        "jax": (policy.supports_jax and
                cls.scan_step is not Policy.scan_step),
    }


# ---------------------------------------------------------------------------
# jnp twins of the shared numpy formulas (np ufuncs don't dispatch on jax
# tracers on this JAX version). Any change to the originals MUST land here
# too — the jax-vs-loop parity suite is the tripwire.
# ---------------------------------------------------------------------------
def _jax_trace_v_norm(v_norm0, version, jnp, zero=0.0):
    """Mirror of simulator.trace_v_norm.

    ``zero`` must be a TRACED 0.0 when called inside jit: XLA's CPU
    codegen is free to contract ``1.0 + 0.05 * version`` into a single
    fma, which skips the product's rounding step and drifts an ulp from
    the numpy original (optimization_barrier does not survive fusion).
    Adding a runtime-opaque zero to the product forces the rounding:
    even if the inner add contracts, ``fma(0.05, version, 0.0)`` IS the
    correctly-rounded product, and the outer add has no fmul operand
    left to contract with."""
    return v_norm0 / jnp.sqrt(1.0 + (0.05 * version + zero))


def _jax_gradient_gap(v_norm, lag, eta, beta):
    """Mirror of staleness.gradient_gap/momentum_scale (Eq. 4). beta is a
    traced scalar, so no beta==0 branch: 0**0==1 makes the closed form
    agree at lag=0."""
    return eta * (1.0 - beta ** lag) / (1.0 - beta) * v_norm


# ---------------------------------------------------------------------------
# Offline window planning (Alg. 1) over array state — ONE implementation
# shared by the numpy engine's decide hook and the jax engine's
# pure_callback, so the knapsack decisions are bit-identical by
# construction on both batched engines.
# ---------------------------------------------------------------------------
def plan_window(plan, t, widx, app, app_sched, app_choice, T_COR, SRATE,
                window, v_norm, L_b, resolution, eta, beta, row0=0):
    """One Alg. 1 plan over the look-ahead window, mutating and returning
    ``plan`` (the per-user PLAN_* codes in ``EngineState.plan``).

    Candidates are waiting users (``widx``) with an app running now or an
    (oracle lookahead) arrival inside the window; the knapsack picks which
    of them wait to co-run, the rest train immediately. Users without an
    in-window arrival hold until the next plan.

    ``app_sched``/``app_choice`` may be the full horizon (``row0 = 0``,
    the numpy engine) or just a slice whose row i is absolute slot
    ``row0 + i`` (the jax callback ships only the window to the host)."""
    if not len(widx):
        return plan
    W = int(window)
    horizon = min(t + W, row0 + app_sched.shape[0])
    sub = app_sched[t - row0:horizon - row0][:, widx]  # (window, n_waiting)
    if sub.shape[0]:
        has_arr = sub.any(axis=0)
        first = sub.argmax(axis=0)                   # first arrival offset
    else:
        # sub-slot window (int(window) == 0) or horizon tail: no lookahead
        # rows — only users with an app running now are candidates (the
        # loop oracle's semantics; bare argmax would crash on the empty
        # axis, which the historical _plan_vec did)
        has_arr = np.zeros(len(widx), dtype=bool)
        first = np.zeros(len(widx), dtype=np.int64)
    ha = app[widx] >= 0
    cand = ha | has_arr
    plan[widx[~cand]] = PLAN_HOLD
    cidx = widx[cand]
    if not len(cidx):
        return plan
    ta = np.where(ha[cand], t, t + first[cand])      # absolute slots
    # np.where evaluates both branches: app-running candidates (ha) take
    # app[cidx], but their discarded app_choice gather still needs an
    # in-bounds row — clamp covers them (non-ha rows are in-window by
    # construction, so the clamp never alters a selected lane)
    if app_choice.shape[0]:
        pick = app_choice[np.minimum(ta - row0, app_choice.shape[0] - 1),
                          cidx]
    else:
        pick = np.zeros(len(cidx), dtype=np.int64)   # all-ha candidates
    aid = np.where(ha[cand], app[cidx], pick)
    durs = T_COR[cidx, aid]
    savings = SRATE[cidx, aid] * durs
    lags = lemma1_lag_bounds(np.full(len(cidx), t), ta, durs)
    gaps = np.asarray(gradient_gap(v_norm, lags, eta, beta), dtype=float)
    x, _ = knapsack_schedule(savings, gaps, L_b, resolution=resolution)
    plan[cidx] = np.where(x, PLAN_CORUN, PLAN_SEP)
    return plan


def _offline_plan_host(t, waiting, plan, app, version, sched_w, choice_w,
                       row0, T_COR, SRATE, window, L_b, resolution, eta,
                       beta, v_norm0):
    """pure_callback target for OfflinePolicy.scan_step: the same
    ``plan_window`` the numpy engine runs, on host numpy, fed entirely by
    traced operands (nothing closed over — the compiled scan is shared
    across runs). ``sched_w``/``choice_w`` are just the look-ahead window
    rows, sliced on device (row i = absolute slot ``row0 + i``), so the
    host transfer is O(window * n), not the full horizon. Returns the new
    plan array."""
    from .simulator import trace_v_norm

    t = int(t)
    plan = np.array(plan)                           # functional: copy
    widx = np.nonzero(np.asarray(waiting))[0]
    vn = trace_v_norm(float(v_norm0), int(version))
    out = plan_window(plan, t, widx, np.asarray(app),
                      np.asarray(sched_w), np.asarray(choice_w),
                      np.asarray(T_COR), np.asarray(SRATE),
                      float(window), vn, float(L_b), float(resolution),
                      float(eta), float(beta), row0=int(row0))
    return out.astype(plan.dtype, copy=False)


# ---------------------------------------------------------------------------
# The four paper policies (Sec. VII.B)
# ---------------------------------------------------------------------------
@register_policy
class SyncPolicy(Policy):
    """FedAvg lock-step: a round starts only when the whole cohort waits."""

    name = "sync"
    sync_rounds = True
    supports_vectorized = True
    supports_jax = True

    def decide_loop(self, sim, t, waiting, carry):
        served = 0
        if not sim._round_open and len(waiting) == sim.cfg.n_users:
            for u in waiting:
                sim.begin_training(u, t, corun=u.app is not None)
                served += 1
            sim._round_open = True
        return served, 0.0

    def decide_vectorized(self, eng, t, carry):
        s = eng.s
        if not s.round_open and \
                int(np.count_nonzero(eng.waiting)) == eng.n:
            eng.begin_training(eng.ar)
            s.round_open = True
            return eng.n, 0.0
        return 0, 0.0

    def scan_step(self, carry, sv):
        jnp = sv.jnp
        open_now = (~sv.round_open) & (jnp.sum(sv.waiting) == sv.n)
        start = sv.waiting & open_now
        sv.round_open = sv.round_open | open_now
        return carry, (start, jnp.asarray(0.0, sv.float_dtype))


@register_policy
class ImmediatePolicy(Policy):
    """ASync baseline: schedule every waiting user ASAP (energy ceiling)."""

    name = "immediate"
    supports_vectorized = True
    supports_jax = True

    def decide_loop(self, sim, t, waiting, carry):
        for u in waiting:
            sim.begin_training(u, t, corun=u.app is not None)
        return len(waiting), 0.0

    def decide_vectorized(self, eng, t, carry):
        if eng.waiting.any():
            widx = np.nonzero(eng.waiting)[0]
            eng.begin_training(widx)
            return len(widx), 0.0
        return 0, 0.0

    def scan_step(self, carry, sv):
        return carry, (sv.waiting, sv.jnp.asarray(0.0, sv.float_dtype))


@register_policy
class OnlinePolicy(Policy):
    """Lyapunov drift-plus-penalty controller (Alg. 2, Eqs. 21-23)."""

    name = "online"
    uses_online_queue = True
    supports_vectorized = True
    supports_jax = True

    def decide_loop(self, sim, t, waiting, carry):
        cfg = sim.cfg
        vn = sim._v_norm()
        served = 0
        gap_sum = 0.0
        for u in waiting:
            a = u.app is not None
            ap = u.device.apps[u.app] if a else None
            st = UserSlotState(
                p_corun=ap.p_corun if a else 0.0,
                p_app=ap.p_app if a else 0.0,
                p_train=u.device.p_train, p_idle=u.device.p_idle,
                app_running=a,
                lag_estimate=sim.in_flight,
                idle_gap=u.idle_gap)
            d = sim.sched.decide(st, vn)
            gap_sum += d.gap
            if d.schedule:
                sim.begin_training(u, t, corun=a)
                served += 1
            else:
                u.idle_gap += cfg.epsilon
        return served, gap_sum

    def decide_vectorized(self, eng, t, carry):
        if not eng.waiting.any():
            return 0, 0.0
        s = eng.s
        widx = np.nonzero(eng.waiting)[0]
        vn = eng.v_norm(s.version)
        d = eng.sched.decide_batch(eng.p_if_train[widx], eng.p_if_idle[widx],
                                   s.idle_gap[widx], s.in_flight, vn)
        if d.n_served:
            eng.begin_training(widx[d.schedule])
        if d.n_served != len(widx):
            s.idle_gap[widx[~d.schedule]] += eng.cfg.epsilon
        return d.n_served, d.gap_sum

    def scan_step(self, carry, sv):
        jnp, lax = sv.jnp, sv.lax
        f, i = sv.float_dtype, sv.int_dtype
        waiting, has_app = sv.waiting, sv.has_app
        H = sv.H
        vn = _jax_trace_v_norm(sv.v_norm0, sv.version, jnp, sv.fp_zero)
        p_s = jnp.where(has_app, sv.pcor_g, sv.PT)
        p_i = jnp.where(has_app, sv.papp_g, sv.PI)
        # fp_zero blocks fma contraction of the products (see
        # _jax_trace_v_norm): the host rounds V*P*t_d before subtracting
        base = (sv.V * p_s * sv.t_d + sv.fp_zero) - sv.Q
        rhs = sv.V * p_i * sv.t_d
        gap_idle_v = sv.idle_gap + sv.epsilon
        lag_idx = sv.in_flight + jnp.arange(sv.n + 1)
        gap_vec = _jax_gradient_gap(vn, lag_idx, sv.eta, sv.beta)

        def fast(_):
            # H == 0: the gap term adds exactly 0 to both branches.
            # sv.repl pins `sched` replicated: it has a sharded consumer
            # in the engine (begin-training), and without the pin GSPMD
            # propagates that layout back through cumsum/gather/where and
            # turns the gap_sum below into reassociated shard-local
            # partials + AllReduce (the reduce(all-gather) -> all-reduce
            # rewrite), flipping low bits of the Eq. 16 H update
            sched = sv.repl(waiting & (base <= rhs))
            before = jnp.cumsum(sched) - sched
            gaps = jnp.where(sched, gap_vec[before], gap_idle_v)
            # sum the LIVE lanes only ([:sv.n] folds to a no-op when the
            # sharded scan hasn't padded the user axis): pad lanes never
            # wait, and excluding their zeros keeps the reduction tree —
            # hence the Eq. 16 H update — bit-identical to unsharded
            return sched, jnp.sum(jnp.where(waiting, gaps, 0.0)[:sv.n])

        def slow(_):
            # sequential in-slot lag coupling, user-index order
            def body(c, xs_i):
                j, gs = c
                w_i, b_i, r_i, gi_i = xs_i
                do = w_i & (b_i + (H * gap_vec[j] + sv.fp_zero)
                            <= r_i + (H * gi_i + sv.fp_zero))
                gap_i = jnp.where(do, gap_vec[j], gi_i)
                gs = gs + jnp.where(w_i, gap_i, 0.0)
                return (j + do.astype(i), gs), do
            (j, gs), sched = lax.scan(
                body, (jnp.asarray(0, i), jnp.asarray(0.0, f)),
                (waiting, base, rhs, gap_idle_v))
            return sched, gs

        start, gap_sum = lax.cond(H > 0.0, slow, fast, None)
        sv.idle_gap = jnp.where(waiting & ~start,
                                sv.idle_gap + sv.epsilon, sv.idle_gap)
        return carry, (start, gap_sum)


@register_policy
class OfflinePolicy(Policy):
    """Oracle knapsack with look-ahead window (Alg. 1).

    Carry: the next plan slot. The window plan itself writes the per-user
    ``plan`` codes in ``EngineState.plan`` (engine state: the engines reset
    a user's plan to HOLD when it re-enters the waiting queue). Under the
    jax engine the knapsack DP — host numpy, pseudo-polynomial in
    ``L_b / resolution`` — runs through ``jax.pure_callback`` inside a
    ``lax.cond``, so the host is consulted only at plan slots (every
    ``offline_window`` seconds) and the decisions are bit-identical to the
    numpy engine's, which calls the same ``plan_window``."""

    name = "offline"
    supports_vectorized = True
    supports_jax = True
    # host knapsack via pure_callback: under vmap the plan-slot cond
    # runs both branches per config, consulting the host every slot for
    # every config — keep this policy on the per-point scan path
    supports_vmap = False
    # ... and the callback cannot run inside a GSPMD-partitioned step
    # either: keep it off the sharded scan (SimConfig.n_devices)
    supports_shard = False

    def init_carry(self, n, cfg):
        return {"next_plan": 0.0}

    def decide_loop(self, sim, t, waiting, carry):
        cfg = sim.cfg
        if t >= carry["next_plan"]:
            carry["next_plan"] = t + cfg.offline_window
            self._plan_loop(sim, t, waiting)
        served = 0
        for u in waiting:
            if u.plan == "corun":
                if u.app is not None:
                    sim.begin_training(u, t, corun=True)
                    served += 1
            elif u.plan == "separate":
                sim.begin_training(u, t, corun=u.app is not None)
                served += 1
            # plan == "hold"/"none": idle until the next window
        return served, 0.0

    def _plan_loop(self, sim, t: int, waiting: List):
        """Knapsack over the look-ahead window (Alg. 1), object form (the
        readable oracle; ``plan_window`` is its array twin).

        Users whose app arrival falls inside the window are knapsack
        candidates: selected -> wait for the arrival and co-run (x_i = 1);
        rejected -> train immediately, separate execution (x_i = 0). Users
        without an in-window arrival hold (idle) until the next window —
        with the paper's relaxed L_b = 1000 this reduces to the "greedy
        always waiting for co-running opportunities" behaviour of Fig. 4a.
        """
        cfg = sim.cfg
        W = int(cfg.offline_window)
        cands, t_app, t_now, durs, savings = [], [], [], [], []
        for u in waiting:
            # next app arrival within the window (oracle lookahead)
            i = u._uid
            horizon = min(t + W, sim.app_sched.shape[0])
            arr = np.nonzero(sim.app_sched[t:horizon, i])[0]
            if u.app is not None:
                ta, app = t, u.app
            elif len(arr):
                ta = t + int(arr[0])
                app = APPS[sim.app_choice[ta, i]]
            else:
                u.plan = "hold"
                continue
            cands.append(u)
            t_now.append(t)
            t_app.append(ta)
            durs.append(u.device.apps[app].t_corun)
            savings.append(u.device.energy_saving_rate(app)
                           * u.device.apps[app].t_corun)
        if not cands:
            return
        lags = lemma1_lag_bounds(np.array(t_now), np.array(t_app),
                                 np.array(durs))
        vn = sim._v_norm()
        gaps = np.array([gradient_gap(vn, int(l), cfg.eta, cfg.beta)
                         for l in lags])
        x, _ = knapsack_schedule(np.array(savings), gaps, cfg.L_b,
                                 resolution=cfg.offline_resolution)
        for u, chosen in zip(cands, x):
            u.plan = "corun" if chosen else "separate"

    def decide_vectorized(self, eng, t, carry):
        cfg = eng.cfg
        s = eng.s
        if t >= carry["next_plan"]:
            carry["next_plan"] = t + cfg.offline_window
            plan_window(s.plan, t, np.nonzero(eng.waiting)[0], s.app,
                        eng.app_sched, eng.app_choice, eng.T_COR, eng.SRATE,
                        cfg.offline_window, eng.v_norm(s.version),
                        cfg.L_b, cfg.offline_resolution, cfg.eta, cfg.beta)
        start = eng.waiting & (((s.plan == PLAN_CORUN) & eng.has_app) |
                               (s.plan == PLAN_SEP))
        if start.any():
            sidx = np.nonzero(start)[0]
            eng.begin_training(sidx)
            return len(sidx), 0.0
        return 0, 0.0

    def scan_statics(self, cfg) -> tuple:
        # the look-ahead slice shipped to the host callback needs a
        # static row count; baked into the trace + jit cache key
        return (int(cfg.offline_window),)

    def scan_step(self, carry, sv):
        jnp, lax, jax = sv.jnp, sv.lax, sv.jax
        nxt = carry["next_plan"]
        do_plan = sv.t >= nxt
        n, T, plan_dtype = sv.n, sv.T, sv.plan.dtype
        (W,) = sv.statics
        Wc = min(max(W, 0), T)          # static window rows

        def plan_now(args):
            t, waiting, plan, app, version = args
            # slice just the look-ahead window for the host (inside the
            # taken cond branch: the gather + device->host copy happen at
            # plan slots only, and cost O(window * n), never O(T * n));
            # the start clamps at the horizon tail, row0 re-anchors it
            row0 = jnp.minimum(t, T - Wc)
            sched_w = lax.dynamic_slice(sv.app_sched, (row0, 0), (Wc, n))
            choice_w = lax.dynamic_slice(sv.app_choice, (row0, 0), (Wc, n))
            return jax.pure_callback(
                _offline_plan_host,
                jax.ShapeDtypeStruct((n,), plan_dtype),
                t, waiting, plan, app, version, sched_w, choice_w, row0,
                sv.T_COR, sv.SRATE, sv.offline_window, sv.L_b,
                sv.offline_resolution, sv.eta, sv.beta, sv.v_norm0)

        args = (sv.t, sv.waiting, sv.plan, sv.app, sv.version)
        sv.plan = lax.cond(do_plan, plan_now, lambda a: a[2], args)
        nxt = jnp.where(do_plan, sv.t + sv.offline_window, nxt)
        start = sv.waiting & (((sv.plan == PLAN_CORUN) & sv.has_app) |
                              (sv.plan == PLAN_SEP))
        return {"next_plan": nxt}, \
            (start, jnp.asarray(0.0, sv.float_dtype))


# ---------------------------------------------------------------------------
# A genuinely new registered policy: proof the registry extends beyond the
# paper's four schedulers.
# ---------------------------------------------------------------------------
@register_policy
class GreedyThresholdPolicy(Policy):
    """Greedy energy-threshold baseline (not in the paper).

    Schedules a waiting user as soon as the *marginal* power of training is
    cheap — below ``theta`` watts over what the device would burn anyway:
    P^{a'} - P^a while an app runs (the co-run discount), P^b - P^d when
    idle. Users that never see a cheap slot are force-scheduled after
    ``patience`` waiting slots, so progress is guaranteed without any queue
    machinery. A natural midpoint between "immediate" (theta = inf) and
    "wait for co-runs" (theta small, patience large).

    Carry: the per-user wait counters — the canonical stateful-policy
    example of the carry protocol (one ``(n,)`` array threaded identically
    through the loop, numpy and lax.scan engines). ``theta``/``patience``
    reach the traced hook as ``scan_operands``, so a parameter sweep
    reuses one compiled scan.
    """

    name = "greedy"
    supports_vectorized = True
    supports_jax = True

    def __init__(self, theta: float = 0.3, patience: int = 240):
        if patience < 0:
            raise ValueError(f"patience must be >= 0, got {patience}")
        self.theta = float(theta)
        self.patience = int(patience)

    def init_carry(self, n, cfg):
        return {"waited": np.zeros(n, dtype=np.int64)}

    def scan_operands(self, cfg):
        return (self.theta, self.patience)

    def decide_loop(self, sim, t, waiting, carry):
        waited = carry["waited"]
        served = 0
        for u in waiting:
            a = u.app is not None
            if a:
                ap = u.device.apps[u.app]
                delta = ap.p_corun - ap.p_app
            else:
                delta = u.device.p_train - u.device.p_idle
            i = u._uid
            if delta <= self.theta or waited[i] >= self.patience:
                sim.begin_training(u, t, corun=a)
                waited[i] = 0
                served += 1
            else:
                waited[i] += 1
        return served, 0.0

    def decide_vectorized(self, eng, t, carry):
        w = eng.waiting
        if not w.any():
            return 0, 0.0
        # p_if_train/p_if_idle are exactly (P^{a'}, P^a) with an app and
        # (P^b, P^d) without — the same operands the loop hook compares
        delta = eng.p_if_train - eng.p_if_idle
        waited = carry["waited"]
        go = w & ((delta <= self.theta) | (waited >= self.patience))
        if go.any():
            eng.begin_training(np.nonzero(go)[0])
        waited[go] = 0
        waited[w & ~go] += 1
        return int(np.count_nonzero(go)), 0.0

    def scan_step(self, carry, sv):
        jnp = sv.jnp
        theta, patience = sv.consts
        waited = carry["waited"]
        delta = jnp.where(sv.has_app, sv.pcor_g - sv.papp_g, sv.PT - sv.PI)
        go = sv.waiting & ((delta <= theta) | (waited >= patience))
        waited = jnp.where(go, 0,
                           jnp.where(sv.waiting & ~go, waited + 1, waited))
        return {"waited": waited}, \
            (go, jnp.asarray(0.0, sv.float_dtype))


# ---------------------------------------------------------------------------
# A stochastic registry policy: draws ride the run's EngineState.rng_key
# through the carry protocol, so the SAME threefry stream drives the loop
# oracle, the numpy engine and the lax.scan backend bit-identically.
# ---------------------------------------------------------------------------
def _eps_draw(rng_key, n):
    """One slot's exploration draws on the host: split the run key, draw
    ``(n,)`` f32 uniforms. jax's counter-based threefry PRNG produces the
    SAME bits eagerly (here) and traced (inside ``scan_step``), which is
    what makes the three engine hooks decision-identical."""
    import jax
    import jax.numpy as jnp

    k2, sub = jax.random.split(jnp.asarray(rng_key))
    u = jax.random.uniform(sub, (n,), jnp.float32)
    return np.asarray(k2, dtype=np.uint32), np.asarray(u)


@register_policy
class EpsGreedyPolicy(Policy):
    """Epsilon-greedy exploration over the greedy marginal-power rule.

    Exploit: schedule a waiting user when training is marginally cheap
    (the ``GreedyThresholdPolicy`` comparison, ``delta <= theta``).
    Explore: with probability ``eps`` per user per slot, schedule anyway
    — a stochastic escape hatch that guarantees progress without wait
    counters and trades energy for staleness at a tunable rate.

    The randomness is drawn from ``EngineState.rng_key`` — the seeded
    ``(2,)`` uint32 counter key every engine threads — via one
    ``jax.random.split`` + ``(n,)`` uniform per slot, consumed
    UNCONDITIONALLY (even with nobody waiting) so the key chain advances
    identically on every engine: the loop and numpy hooks draw eagerly
    and write the split key back into the state, the jax hook draws
    traced inside the scan and threads it through ``sv.rng_key``.
    threefry is counter-based and jit-invariant, so the decisions are
    bit-identical across all three engines (pinned by the engine
    matrix). ``eps``/``theta`` reach the traced hook as
    ``scan_operands``, so a parameter sweep reuses one compiled scan.
    """

    name = "eps_greedy"
    supports_vectorized = True
    supports_jax = True

    def __init__(self, eps: float = 0.05, theta: float = 0.3):
        if not 0.0 <= eps <= 1.0:
            raise ValueError(f"eps must be in [0, 1], got {eps}")
        self.eps = float(eps)
        self.theta = float(theta)

    def scan_operands(self, cfg):
        return (self.eps, self.theta)

    def decide_loop(self, sim, t, waiting, carry):
        s = sim.state
        s.rng_key, u = _eps_draw(s.rng_key, sim.cfg.n_users)
        served = 0
        for usr in waiting:
            a = usr.app is not None
            if a:
                ap = usr.device.apps[usr.app]
                delta = ap.p_corun - ap.p_app
            else:
                delta = usr.device.p_train - usr.device.p_idle
            if u[usr._uid] < self.eps or delta <= self.theta:
                sim.begin_training(usr, t, corun=a)
                served += 1
        return served, 0.0

    def decide_vectorized(self, eng, t, carry):
        s = eng.s
        s.rng_key, u = _eps_draw(s.rng_key, eng.n)
        w = eng.waiting
        if not w.any():
            return 0, 0.0
        delta = eng.p_if_train - eng.p_if_idle
        go = w & ((u < self.eps) | (delta <= self.theta))
        if go.any():
            eng.begin_training(np.nonzero(go)[0])
        return int(np.count_nonzero(go)), 0.0

    def scan_step(self, carry, sv):
        jnp, jax = sv.jnp, sv.jax
        eps, theta = sv.consts
        k2, sub = jax.random.split(sv.rng_key)
        u = jax.random.uniform(sub, (sv.n,), jnp.float32)
        # live-n draw + fill-1.0 pad: keeps the threefry stream identical
        # to the unsharded engines when the sharded scan pads the user
        # axis (1.0 is never < eps, so pad lanes never explore)
        u = sv.pad_users(u, 1.0)
        sv.rng_key = k2
        delta = jnp.where(sv.has_app, sv.pcor_g - sv.papp_g, sv.PT - sv.PI)
        go = sv.waiting & ((u < eps) | (delta <= theta))
        return carry, (go, jnp.asarray(0.0, sv.float_dtype))
