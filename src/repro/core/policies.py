"""Pluggable scheduling policies: one registry, three engine hooks.

The paper evaluates four fixed schedulers (Sec. VII.B); this module turns
them into registered ``Policy`` objects so alternative schedulers — e.g.
the energy-minimal scheduling families of Pilla '22 or AutoFL-style
heterogeneity-aware schedulers — plug into the simulator without touching
any engine file. A policy implements up to three hooks, one per engine:

``decide_loop(sim, t, waiting, state)``
    Reference semantics on the per-user object loop (the oracle). Required.
``decide_vectorized(eng, t, state)``
    Same decisions on the struct-of-arrays numpy engine
    (``core/vector_engine.py``); set ``supports_vectorized = True``.
``jax_decide(sv)``
    Traced decision step inside the ``jax.lax.scan`` backend; set
    ``supports_jax = True``. Policies without it transparently degrade to
    the vectorized engine (the way the paper's offline knapsack always has).

Equivalence contract: for a given seed the three hooks must produce the
same decision sequence — tests/test_sim_engines.py and
tests/test_scenario.py pin loop/vectorized/jax parity and bit-for-bit
reproduction of the pre-registry results for the four paper policies.

Strings keep working everywhere: ``SimConfig(policy="online")`` resolves
through the registry (``resolve_policy``), and string lookups hand out a
per-name singleton so the jax backend's jit cache is shared across runs.
New code should pass ``Policy`` instances (see ``core/scenario.py``).
"""
from __future__ import annotations

from typing import Dict, List, Tuple, Type

import numpy as np

from .energy import APPS
from .lyapunov import UserSlotState
from .offline import knapsack_schedule, lemma1_lag_bounds
from .staleness import gradient_gap

# Shared state encodings of the struct-of-arrays engines (numpy + jax).
MODE_WAIT, MODE_TRAIN, MODE_COOL = 0, 1, 2
PLAN_HOLD, PLAN_CORUN, PLAN_SEP = 0, 1, 2


class Policy:
    """Base scheduling policy. Subclass, set ``name``, implement hooks,
    and decorate with ``@register_policy`` to make the name resolvable.

    Class attributes describe engine semantics the engines must honor:

    - ``sync_rounds``: lock-step rounds — the global version bumps once per
      round close (all trainers finished), not per push.
    - ``uses_online_queue``: the per-slot Lyapunov decision runs on-device,
      so ``include_scheduler_overhead`` adds Table III's scheduler power
      while waiting.
    - ``supports_vectorized`` / ``supports_jax``: which engine hooks exist.
    """

    name: str = ""
    sync_rounds: bool = False
    uses_online_queue: bool = False
    supports_vectorized: bool = False
    supports_jax: bool = False

    # ------------------------------------------------------------- loop hook
    def loop_init(self, sim) -> dict:
        """Per-run mutable policy state for the loop engine (policies are
        stateless singletons; runs must not share state)."""
        return {}

    def decide_loop(self, sim, t: int, waiting: list, state: dict
                    ) -> Tuple[int, float]:
        """Schedule waiting users for slot ``t`` via ``sim.begin_training``.
        Returns (served, gap_sum) feeding Eqs. (15)/(16)."""
        raise NotImplementedError(
            f"policy {self.name!r} implements no loop hook")

    # ------------------------------------------------- vectorized (numpy) hook
    def vec_init(self, eng) -> dict:
        return {}

    def decide_vectorized(self, eng, t: int, state: dict
                          ) -> Tuple[int, float]:
        """Same decisions on the batched engine state ``eng``
        (see vector_engine._NumpyEngine). Returns (served, gap_sum)."""
        raise NotImplementedError(
            f"policy {self.name!r} implements no vectorized hook; "
            "run it with engine='loop'")

    # ----------------------------------------------------------- jax scan hook
    def jax_decide(self, sv):
        """Traced decision inside the lax.scan step. ``sv`` is a mutable
        slot view (vector_engine builds it): read ``waiting``, ``has_app``,
        per-user power gathers and queue scalars; write ``idle_gap`` /
        ``round_open`` if the policy owns them. Returns (start_mask,
        gap_sum)."""
        raise NotImplementedError(
            f"policy {self.name!r} implements no jax hook")

    def jax_cache_key(self):
        """Hashable token identifying this policy's ``jax_decide``
        behavior: two policies with equal keys may share one compiled
        scan. Default is the instance itself (always safe). Policies
        whose jax hook reads no instance state should return
        ``type(self)`` so fresh instances — the object-passing style —
        reuse the jit cache instead of recompiling per run."""
        return self


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[Policy]] = {}
_INSTANCES: Dict[str, Policy] = {}       # singletons for string lookups


def register_policy(cls: Type[Policy]) -> Type[Policy]:
    """Class decorator: make ``cls`` resolvable as ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    _REGISTRY[cls.name] = cls
    _INSTANCES.pop(cls.name, None)       # re-registration wins
    return cls


def registered_policies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_policy(policy) -> Policy:
    """String -> registered singleton; Policy instance -> itself.

    Singletons matter for the jax backend: its jit cache is keyed on the
    policy object, so every ``SimConfig(policy="online")`` run shares one
    compiled executable per shape."""
    if isinstance(policy, Policy):
        return policy
    if isinstance(policy, str):
        if policy not in _REGISTRY:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {registered_policies()} "
                             "or a Policy instance")
        if policy not in _INSTANCES:
            _INSTANCES[policy] = _REGISTRY[policy]()
        return _INSTANCES[policy]
    raise ValueError(f"policy must be a name or Policy instance, "
                     f"got {type(policy).__name__}")


# ---------------------------------------------------------------------------
# jnp twins of the shared numpy formulas (np ufuncs don't dispatch on jax
# tracers on this JAX version). Any change to the originals MUST land here
# too — the jax-vs-loop parity suite is the tripwire.
# ---------------------------------------------------------------------------
def _jax_trace_v_norm(v_norm0, version, jnp):
    """Mirror of simulator.trace_v_norm."""
    return v_norm0 / jnp.sqrt(1.0 + 0.05 * version)


def _jax_gradient_gap(v_norm, lag, eta, beta):
    """Mirror of staleness.gradient_gap/momentum_scale (Eq. 4). beta is a
    traced scalar, so no beta==0 branch: 0**0==1 makes the closed form
    agree at lag=0."""
    return eta * (1.0 - beta ** lag) / (1.0 - beta) * v_norm


# ---------------------------------------------------------------------------
# The four paper policies (Sec. VII.B)
# ---------------------------------------------------------------------------
@register_policy
class SyncPolicy(Policy):
    """FedAvg lock-step: a round starts only when the whole cohort waits."""

    name = "sync"
    sync_rounds = True
    supports_vectorized = True
    supports_jax = True

    def decide_loop(self, sim, t, waiting, state):
        served = 0
        if not sim._round_open and len(waiting) == sim.cfg.n_users:
            for u in waiting:
                sim.begin_training(u, t, corun=u.app is not None)
                served += 1
            sim._round_open = True
        return served, 0.0

    def decide_vectorized(self, eng, t, state):
        if not eng.round_open and \
                int(np.count_nonzero(eng.waiting)) == eng.n:
            eng.begin_training(eng.ar)
            eng.round_open = True
            return eng.n, 0.0
        return 0, 0.0

    def jax_cache_key(self):
        return type(self)   # hook reads no instance state

    def jax_decide(self, sv):
        jnp = sv.jnp
        open_now = (~sv.round_open) & (jnp.sum(sv.waiting) == sv.n)
        start = sv.waiting & open_now
        sv.round_open = sv.round_open | open_now
        return start, jnp.asarray(0.0, sv.float_dtype)


@register_policy
class ImmediatePolicy(Policy):
    """ASync baseline: schedule every waiting user ASAP (energy ceiling)."""

    name = "immediate"
    supports_vectorized = True
    supports_jax = True

    def decide_loop(self, sim, t, waiting, state):
        for u in waiting:
            sim.begin_training(u, t, corun=u.app is not None)
        return len(waiting), 0.0

    def decide_vectorized(self, eng, t, state):
        if eng.waiting.any():
            widx = np.nonzero(eng.waiting)[0]
            eng.begin_training(widx)
            return len(widx), 0.0
        return 0, 0.0

    def jax_cache_key(self):
        return type(self)   # hook reads no instance state

    def jax_decide(self, sv):
        return sv.waiting, sv.jnp.asarray(0.0, sv.float_dtype)


@register_policy
class OnlinePolicy(Policy):
    """Lyapunov drift-plus-penalty controller (Alg. 2, Eqs. 21-23)."""

    name = "online"
    uses_online_queue = True
    supports_vectorized = True
    supports_jax = True

    def decide_loop(self, sim, t, waiting, state):
        cfg = sim.cfg
        vn = sim._v_norm()
        served = 0
        gap_sum = 0.0
        for u in waiting:
            a = u.app is not None
            ap = u.device.apps[u.app] if a else None
            st = UserSlotState(
                p_corun=ap.p_corun if a else 0.0,
                p_app=ap.p_app if a else 0.0,
                p_train=u.device.p_train, p_idle=u.device.p_idle,
                app_running=a,
                lag_estimate=sim.in_flight,
                idle_gap=u.idle_gap)
            d = sim.sched.decide(st, vn)
            gap_sum += d.gap
            if d.schedule:
                sim.begin_training(u, t, corun=a)
                served += 1
            else:
                u.idle_gap += cfg.epsilon
        return served, gap_sum

    def decide_vectorized(self, eng, t, state):
        if not eng.waiting.any():
            return 0, 0.0
        widx = np.nonzero(eng.waiting)[0]
        vn = eng.v_norm(eng.version)
        d = eng.sched.decide_batch(eng.p_if_train[widx], eng.p_if_idle[widx],
                                   eng.idle_gap[widx], eng.in_flight, vn)
        if d.n_served:
            eng.begin_training(widx[d.schedule])
        if d.n_served != len(widx):
            eng.idle_gap[widx[~d.schedule]] += eng.cfg.epsilon
        return d.n_served, d.gap_sum

    def jax_cache_key(self):
        return type(self)   # hook reads no instance state

    def jax_decide(self, sv):
        jnp, lax = sv.jnp, sv.lax
        f, i = sv.float_dtype, sv.int_dtype
        waiting, has_app = sv.waiting, sv.has_app
        H = sv.H
        vn = _jax_trace_v_norm(sv.v_norm0, sv.version, jnp)
        p_s = jnp.where(has_app, sv.pcor_g, sv.PT)
        p_i = jnp.where(has_app, sv.papp_g, sv.PI)
        base = sv.V * p_s * sv.t_d - sv.Q
        rhs = sv.V * p_i * sv.t_d
        gap_idle_v = sv.idle_gap + sv.epsilon
        lag_idx = sv.in_flight + jnp.arange(sv.n + 1)
        gap_vec = _jax_gradient_gap(vn, lag_idx, sv.eta, sv.beta)

        def fast(_):
            # H == 0: the gap term adds exactly 0 to both branches
            sched = waiting & (base <= rhs)
            before = jnp.cumsum(sched) - sched
            gaps = jnp.where(sched, gap_vec[before], gap_idle_v)
            return sched, jnp.sum(jnp.where(waiting, gaps, 0.0))

        def slow(_):
            # sequential in-slot lag coupling, user-index order
            def body(c, xs_i):
                j, gs = c
                w_i, b_i, r_i, gi_i = xs_i
                do = w_i & (b_i + H * gap_vec[j] <= r_i + H * gi_i)
                gap_i = jnp.where(do, gap_vec[j], gi_i)
                gs = gs + jnp.where(w_i, gap_i, 0.0)
                return (j + do.astype(i), gs), do
            (j, gs), sched = lax.scan(
                body, (jnp.asarray(0, i), jnp.asarray(0.0, f)),
                (waiting, base, rhs, gap_idle_v))
            return sched, gs

        start, gap_sum = lax.cond(H > 0.0, slow, fast, None)
        sv.idle_gap = jnp.where(waiting & ~start,
                                sv.idle_gap + sv.epsilon, sv.idle_gap)
        return start, gap_sum


@register_policy
class OfflinePolicy(Policy):
    """Oracle knapsack with look-ahead window (Alg. 1)."""

    name = "offline"
    supports_vectorized = True
    # no jax hook: the knapsack DP cannot live inside lax.scan

    def loop_init(self, sim):
        return {"next_plan": 0.0}

    def decide_loop(self, sim, t, waiting, state):
        cfg = sim.cfg
        if t >= state["next_plan"]:
            state["next_plan"] = t + cfg.offline_window
            self._plan_loop(sim, t, waiting)
        served = 0
        for u in waiting:
            if u.plan == "corun":
                if u.app is not None:
                    sim.begin_training(u, t, corun=True)
                    served += 1
            elif u.plan == "separate":
                sim.begin_training(u, t, corun=u.app is not None)
                served += 1
            # plan == "hold"/"none": idle until the next window
        return served, 0.0

    def _plan_loop(self, sim, t: int, waiting: List):
        """Knapsack over the look-ahead window (Alg. 1).

        Users whose app arrival falls inside the window are knapsack
        candidates: selected -> wait for the arrival and co-run (x_i = 1);
        rejected -> train immediately, separate execution (x_i = 0). Users
        without an in-window arrival hold (idle) until the next window —
        with the paper's relaxed L_b = 1000 this reduces to the "greedy
        always waiting for co-running opportunities" behaviour of Fig. 4a.
        """
        cfg = sim.cfg
        W = int(cfg.offline_window)
        cands, t_app, t_now, durs, savings = [], [], [], [], []
        for u in waiting:
            # next app arrival within the window (oracle lookahead)
            i = u._uid
            horizon = min(t + W, sim.app_sched.shape[0])
            arr = np.nonzero(sim.app_sched[t:horizon, i])[0]
            if u.app is not None:
                ta, app = t, u.app
            elif len(arr):
                ta = t + int(arr[0])
                app = APPS[sim.app_choice[ta, i]]
            else:
                u.plan = "hold"
                continue
            cands.append(u)
            t_now.append(t)
            t_app.append(ta)
            durs.append(u.device.apps[app].t_corun)
            savings.append(u.device.energy_saving_rate(app)
                           * u.device.apps[app].t_corun)
        if not cands:
            return
        lags = lemma1_lag_bounds(np.array(t_now), np.array(t_app),
                                 np.array(durs))
        vn = sim._v_norm()
        gaps = np.array([gradient_gap(vn, int(l), cfg.eta, cfg.beta)
                         for l in lags])
        x, _ = knapsack_schedule(np.array(savings), gaps, cfg.L_b,
                                 resolution=cfg.offline_resolution)
        for u, chosen in zip(cands, x):
            u.plan = "corun" if chosen else "separate"

    def vec_init(self, eng):
        return {"next_plan": 0.0}

    def decide_vectorized(self, eng, t, state):
        cfg = eng.cfg
        if t >= state["next_plan"]:
            state["next_plan"] = t + cfg.offline_window
            self._plan_vec(eng, t, np.nonzero(eng.waiting)[0])
        start = eng.waiting & (((eng.plan == PLAN_CORUN) & eng.has_app) |
                               (eng.plan == PLAN_SEP))
        if start.any():
            sidx = np.nonzero(start)[0]
            eng.begin_training(sidx)
            return len(sidx), 0.0
        return 0, 0.0

    def _plan_vec(self, eng, t, widx):
        """Vectorized Alg. 1 window plan (mirrors ``_plan_loop``).

        Candidates are waiting users with an app running now or an (oracle
        lookahead) arrival inside the window; the knapsack picks which of
        them wait to co-run, the rest train immediately. Users without an
        in-window arrival hold until the next plan."""
        if not len(widx):
            return
        cfg = eng.cfg
        app, plan = eng.app, eng.plan
        W = int(cfg.offline_window)
        horizon = min(t + W, eng.app_sched.shape[0])
        sub = eng.app_sched[t:horizon][:, widx]          # (window, n_waiting)
        has_arr = sub.any(axis=0)
        first = sub.argmax(axis=0)                       # first arrival offset
        ha = app[widx] >= 0
        cand = ha | has_arr
        plan[widx[~cand]] = PLAN_HOLD
        cidx = widx[cand]
        if not len(cidx):
            return
        ta = np.where(ha[cand], t, t + first[cand])
        aid = np.where(ha[cand], app[cidx], eng.app_choice[ta, cidx])
        durs = eng.T_COR[cidx, aid]
        savings = eng.SRATE[cidx, aid] * durs
        lags = lemma1_lag_bounds(np.full(len(cidx), t), ta, durs)
        vn = eng.v_norm(eng.version)
        gaps = np.asarray(gradient_gap(vn, lags, cfg.eta, cfg.beta),
                          dtype=float)
        x, _ = knapsack_schedule(savings, gaps, cfg.L_b,
                                 resolution=cfg.offline_resolution)
        plan[cidx] = np.where(x, PLAN_CORUN, PLAN_SEP)


# ---------------------------------------------------------------------------
# A genuinely new registered policy: proof the registry extends beyond the
# paper's four schedulers.
# ---------------------------------------------------------------------------
@register_policy
class GreedyThresholdPolicy(Policy):
    """Greedy energy-threshold baseline (not in the paper).

    Schedules a waiting user as soon as the *marginal* power of training is
    cheap — below ``theta`` watts over what the device would burn anyway:
    P^{a'} - P^a while an app runs (the co-run discount), P^b - P^d when
    idle. Users that never see a cheap slot are force-scheduled after
    ``patience`` waiting slots, so progress is guaranteed without any queue
    machinery. A natural midpoint between "immediate" (theta = inf) and
    "wait for co-runs" (theta small, patience large).
    """

    name = "greedy"
    supports_vectorized = True
    # no jax hook on purpose: exercises the documented jax -> vectorized
    # degradation path for registry policies

    def __init__(self, theta: float = 0.3, patience: int = 240):
        if patience < 0:
            raise ValueError(f"patience must be >= 0, got {patience}")
        self.theta = float(theta)
        self.patience = int(patience)

    def loop_init(self, sim):
        return {"waited": {}}

    def decide_loop(self, sim, t, waiting, state):
        waited = state["waited"]
        served = 0
        for u in waiting:
            a = u.app is not None
            if a:
                ap = u.device.apps[u.app]
                delta = ap.p_corun - ap.p_app
            else:
                delta = u.device.p_train - u.device.p_idle
            w = waited.get(u._uid, 0)
            if delta <= self.theta or w >= self.patience:
                sim.begin_training(u, t, corun=a)
                waited[u._uid] = 0
                served += 1
            else:
                waited[u._uid] = w + 1
        return served, 0.0

    def vec_init(self, eng):
        return {"waited": np.zeros(eng.n, dtype=np.int64)}

    def decide_vectorized(self, eng, t, state):
        w = eng.waiting
        if not w.any():
            return 0, 0.0
        # p_if_train/p_if_idle are exactly (P^{a'}, P^a) with an app and
        # (P^b, P^d) without — the same operands the loop hook compares
        delta = eng.p_if_train - eng.p_if_idle
        waited = state["waited"]
        go = w & ((delta <= self.theta) | (waited >= self.patience))
        if go.any():
            eng.begin_training(np.nonzero(go)[0])
        waited[go] = 0
        waited[w & ~go] += 1
        return int(np.count_nonzero(go)), 0.0
