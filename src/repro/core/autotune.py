"""Memory-aware auto-tuning of the jax chunked scan's shape knobs.

The scan (core/vector_engine.py) has two knobs that used to be global
heuristics and stop scaling past ~1M users:

- ``SimConfig.jax_chunk`` — slots per compiled ``lax.scan`` chunk. The
  chunk-proportional device cost is the ``(chunk, n/D)`` arrival slice
  (plus the stacked per-slot trace outputs); too big a chunk at a 10M-row
  shard blows device memory, too small a chunk pays dispatch overhead per
  chunk. ``jax_chunk=0`` resolves here against the per-device budget.
- push-buffer capacity — the legacy ``max(1024, 2 * n_users)`` guess
  allocates a ~960 MB replicated buffer at n=10M. The training pipeline
  bounds pushes per chunk by ``n * chunk / cycle_slots`` (a user must
  train ``min t_train`` seconds and sit out ``ready_delay`` + 1 slots
  between pushes), which is orders of magnitude tighter at fleet scale.
  Under-estimates stay safe: the driver detects buffer overflow by count
  and re-runs the chunk doubled.

Budgets come from the accelerator's ``memory_stats()`` when the backend
reports one (GPU/TPU ``bytes_limit``), else system RAM split over the
(possibly forced-host) device count — so the same tuner sizes a CPU
smoke test and a TPU pod run.
"""
from __future__ import annotations

import os
from types import SimpleNamespace

import numpy as np

from .simulator import n_slots

__all__ = ["autotune_scan_params", "device_memory_budget",
           "estimate_device_bytes"]

# Modeled per-user resident bytes of one sharded scan row (x64): 11
# EngineState SoA leaves + 8 catalog gathers + up to 7 dynamics leaves at
# 8 B each — bools/int8 leaves round UP toward safety.
_STATE_BYTES_PER_USER = 26 * 8
# Arrival operands are resident for the whole horizon: 1 B bool schedule
# + 4 B int32 app choice per user per slot.
_ARRIVAL_BYTES_PER_SLOT = 5
_PUSH_ROW_BYTES = 6 * 8           # (t, user, lag, gap, corun, weight) f64


def _next_pow2(k: int) -> int:
    c = 1
    while c < k:
        c <<= 1
    return c


def _prev_pow2(k: int) -> int:
    return _next_pow2(max(int(k), 1) + 1) >> 1 if k >= 1 else 1


def device_memory_budget(n_devices: int = 1, fraction: float = 0.25) -> int:
    """Usable bytes per device for the scan's operands: the device's
    reported ``bytes_limit`` when the backend exposes ``memory_stats()``
    (GPU/TPU), else system RAM split over the ``n_devices`` host devices.
    ``fraction`` leaves headroom for XLA temporaries, the replicated
    scalars and the rest of the process."""
    import jax

    limit = None
    try:
        stats = jax.local_devices()[0].memory_stats() or {}
        limit = stats.get("bytes_limit")
    except Exception:           # CPU backends raise / return nothing
        limit = None
    if not limit:
        try:
            limit = (os.sysconf("SC_PAGE_SIZE")
                     * os.sysconf("SC_PHYS_PAGES")) // max(int(n_devices), 1)
        except (ValueError, OSError, AttributeError):
            limit = 4 << 30     # no sysconf (non-POSIX): assume 4 GiB
    return int(limit * fraction)


def estimate_device_bytes(n: int, T: int, chunk: int, capacity: int,
                          n_devices: int = 1, dyn_active: bool = False,
                          collect: bool = False) -> int:
    """Modeled peak per-device bytes of a sharded run: resident state
    rows + whole-horizon arrival columns for this device's shard, the
    in-flight ``(chunk, rows)`` arrival slice, and the replicated push
    buffer. Reported as ``mem_per_device_mb`` in ``bench_sim_scale`` so
    CPU-host numbers transfer to accelerator meshes by arithmetic."""
    rows = -(-int(n) // max(int(n_devices), 1))
    per_user = _STATE_BYTES_PER_USER if dyn_active else 19 * 8
    per_slot = rows * _ARRIVAL_BYTES_PER_SLOT
    return int(rows * per_user + T * per_slot + chunk * per_slot
               + (capacity * _PUSH_ROW_BYTES if collect else 0))


def autotune_scan_params(sim, n_devices: int = 1, mem_bytes=None):
    """Pick ``(jax_chunk, push_capacity)`` for a built ``FederatedSim``
    from the per-device memory budget (``mem_bytes`` overrides the probed
    budget — tests pin it). Returns a namespace with the chosen knobs,
    the budget, and the modeled per-device footprint at those knobs."""
    cfg = sim.cfg
    n, T = cfg.n_users, n_slots(cfg)
    D = max(int(n_devices), 1)
    rows = -(-n // D)
    budget = device_memory_budget(D) if mem_bytes is None else int(mem_bytes)
    # chunk: cap the in-flight (chunk, rows) arrival slice at 1/8 of the
    # budget; floor 64 slots (dispatch amortization), ceiling 16384 (trace
    # time and program size grow with the unrolled chunk graph), never
    # past the horizon
    per_slot = max(rows * _ARRIVAL_BYTES_PER_SLOT, 1)
    chunk = max(64, budget // (8 * per_slot))
    chunk = _prev_pow2(min(chunk, 16384))
    if T:
        chunk = min(chunk, T)
    # push capacity: pushes per chunk are bounded by the training cycle —
    # min t_train slots of training + ready_delay cooldown + 1 waiting
    # slot between consecutive pushes of one user; 2x safety, pow2.
    # An overflowing chunk is re-run doubled, so a tight guess costs a
    # (rare) recompile, never correctness.
    tt = np.asarray(sim.fleet_spec.tables.t_train, dtype=float)
    cycle = max(float(tt.min()) / cfg.t_d + cfg.ready_delay + 1.0, 1.0) \
        if tt.size else 1.0
    per_chunk = n * min(chunk, T or chunk) / cycle
    cap = _next_pow2(max(int(2.0 * per_chunk) + 64, 1024))
    # never let the buffer itself dominate the budget
    cap = min(cap, _next_pow2(max(budget // (2 * _PUSH_ROW_BYTES), 1024)))
    est = estimate_device_bytes(
        n, T, chunk, cap if cfg.collect_push_log else 0, D,
        dyn_active=sim.dynamics.active, collect=cfg.collect_push_log)
    return SimpleNamespace(jax_chunk=int(chunk), push_capacity=int(cap),
                           device_budget=int(budget),
                           est_bytes_per_device=int(est))
