"""Offline scheduling (Sec. IV, Algorithm 1).

With all app arrivals known, choosing which users co-run is the 0/1 knapsack

    max sum_i s_i x_i   s.t.  sum_i g_i x_i <= L_b,  x_i in {0,1}   (P1)

solved by pseudo-polynomial DP (Eq. 8) after bounding each user's lag with
the interval-overlap count of Lemma 1 (Eq. 9) — the lag depends on other
users' decisions, and the lemma removes that circularity with a decision-free
upper bound.
"""
from __future__ import annotations

import numpy as np


def lemma1_lag_bounds(t_start, t_app, duration, *, block: int = 1024):
    """Lemma 1: decision-independent upper bound on each user's lag.

    For user i, count users j != i whose training could END inside either of
    i's candidate execution windows [t_i, t_i+d_i] or [t_i^a, t_i^a+d_i],
    considering both of j's candidate end times t_j+d_j and t_j^a+d_j.

    Fully broadcast over (i, j) pairs — no per-i Python loop — processed in
    row blocks of ``block`` users to bound peak memory at O(block * n).
    """
    t = np.asarray(t_start, float)
    ta = np.asarray(t_app, float)
    d = np.asarray(duration, float)
    n = len(t)
    ends = np.stack([t + d, ta + d], axis=1)                 # (n, 2) candidate ends
    lo = np.stack([t, ta], axis=1)                           # (n, 2) window starts
    hi = lo + d[:, None]
    bounds = np.empty(n, dtype=np.int64)
    for s in range(0, n, block):
        e = min(s + block, n)
        # (rows, n, ends(2), windows(2)): does end (j, e) land in window (i, w)?
        in_window = ((ends[None, :, :, None] >= lo[s:e, None, None, :]) &
                     (ends[None, :, :, None] <= hi[s:e, None, None, :]))
        overlaps = in_window.any(axis=(2, 3))                # (rows, n)
        overlaps[np.arange(e - s), np.arange(s, e)] = False  # exclude self
        bounds[s:e] = overlaps.sum(axis=1)
    return bounds


def lemma1_lag_bounds_loop(t_start, t_app, duration):
    """Reference per-i loop form of Lemma 1 (oracle for the broadcast one)."""
    t = np.asarray(t_start, float)
    ta = np.asarray(t_app, float)
    d = np.asarray(duration, float)
    n = len(t)
    ends = np.stack([t + d, ta + d], axis=1)
    lo = np.stack([t, ta], axis=1)
    hi = lo + d[:, None]
    bounds = np.zeros(n, dtype=np.int64)
    for i in range(n):
        in_window = ((ends[:, :, None] >= lo[i][None, None, :]) &
                     (ends[:, :, None] <= hi[i][None, None, :]))   # (n,2,2)
        overlaps = in_window.any(axis=(1, 2))
        overlaps[i] = False
        bounds[i] = int(overlaps.sum())
    return bounds


def knapsack_schedule(savings, gaps, L_b: float, resolution: float = 1.0):
    """Algorithm 1: DP over the staleness budget.

    savings: (n,) energy saving s_i of co-running user i (>0 entries useful).
    gaps:    (n,) gradient-gap weight g_i (>= 0).
    Returns (x: (n,) bool decisions, total_saving: float).

    Weights are discretized at `resolution` (ceil -> the budget is never
    exceeded); complexity O(n * L_b / resolution).
    """
    s = np.asarray(savings, float)
    g = np.asarray(gaps, float)
    n = len(s)
    W = int(np.floor(L_b / resolution))
    if W < 0:
        raise ValueError("L_b must be >= 0")
    w = np.ceil(g / resolution).astype(np.int64)

    # items with non-positive saving are never worth co-running
    # items with zero weight and positive saving are always taken
    dp = np.zeros(W + 1)
    keep = np.zeros((n, W + 1), dtype=bool)
    for i in range(n):
        if s[i] <= 0 or w[i] > W:
            continue
        if w[i] == 0:
            dp += s[i]
            keep[i, :] = True
            continue
        cand = np.concatenate([dp[: w[i]], dp[: W + 1 - w[i]] + s[i]])
        take = cand > dp
        take[: w[i]] = False
        keep[i] = take
        dp = np.maximum(dp, cand)

    # reconstruct
    x = np.zeros(n, dtype=bool)
    y = W
    for i in range(n - 1, -1, -1):
        if keep[i, y]:
            x[i] = True
            if w[i] > 0:
                y -= w[i]
    return x, float(np.sum(s[x]))


def offline_schedule(t_start, t_app, duration, savings, L_b: float,
                     eta: float, beta: float, v_norm: float,
                     resolution: float = 1.0):
    """Full Algorithm 1: Lemma-1 lag bounds -> Eq. 4 gaps -> knapsack DP."""
    from .staleness import gradient_gap

    lags = lemma1_lag_bounds(t_start, t_app, duration)
    gaps = np.asarray(gradient_gap(v_norm, lags, eta, beta), dtype=float)
    return knapsack_schedule(savings, gaps, L_b, resolution=resolution)
