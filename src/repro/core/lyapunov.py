"""Online scheduling via the Lyapunov drift-plus-penalty framework (Sec. V).

Queues:
    Q(t+1) = max(Q(t) - b(t), 0) + A(t)                       (Eq. 15)
    H(t+1) = max(H(t) + sum_i g_i(t,t+tau) - L_b, 0)          (Eq. 16)

Per-slot, per-user decision (Alg. 2 line 6, Eqs. 21-23):

    alpha_i = argmin over {schedule, idle} of
        V * P_i(alpha, s) * t_d  -  Q(t) * b_i(alpha)  +  H(t) * g_i(alpha)

with g_i(schedule) from Eq. (4) using the server-supplied lag estimate and
g_i(idle) = previous gap + epsilon (Eq. 12). Theorem 1 gives the
[O(1/V), O(V)] energy-staleness trade-off.

The implementation is the paper's *distributed* variant: each user needs only
(Q, H, V, its own power profile, the lag estimate and the momentum norm) —
two scalars from the server, no app-usage leakage.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .staleness import gradient_gap


@dataclasses.dataclass
class UserSlotState:
    """What user i knows at slot t."""
    p_corun: float        # P^{a'} for the currently-running app (if any)
    p_app: float          # P^a
    p_train: float        # P^b
    p_idle: float         # P^d
    app_running: bool
    lag_estimate: int     # supplied by server (Alg. 2 line 4)
    idle_gap: float       # accumulated g_i from idle slots (Eq. 12)


@dataclasses.dataclass
class Decision:
    schedule: bool
    gap: float            # the g_i(t, t+tau) this decision contributes
    cost: float


@dataclasses.dataclass
class BatchDecision:
    schedule: np.ndarray  # (k,) bool, in waiting-set order
    gaps: np.ndarray      # (k,) float, the g_i each decision contributes
    gap_sum: float        # sum of gaps (feeds Eq. 16)
    n_served: int


class OnlineScheduler:
    """Server-side queue state + the per-user argmin (distributed form)."""

    def __init__(self, V: float, L_b: float, eta: float, beta: float,
                 epsilon: float = 0.05, t_d: float = 1.0):
        self.V = float(V)
        self.L_b = float(L_b)
        self.eta = eta
        self.beta = beta
        self.epsilon = epsilon
        self.t_d = t_d
        self.Q = 0.0
        self.H = 0.0

    # ---------------------------------------------------------------- client
    def decide(self, u: UserSlotState, v_norm: float) -> Decision:
        """Alg. 2 line 6: argmin_{alpha} V*P - Q*b + H*g. Pure O(1)."""
        gap_sched = gradient_gap(v_norm, max(u.lag_estimate, 0), self.eta, self.beta)
        gap_idle = u.idle_gap + self.epsilon

        p_sched = u.p_corun if u.app_running else u.p_train     # Eq. (10)
        p_idle = u.p_app if u.app_running else u.p_idle

        cost_sched = self.V * p_sched * self.t_d - self.Q + self.H * gap_sched
        cost_idle = self.V * p_idle * self.t_d + self.H * gap_idle
        if cost_sched <= cost_idle:
            return Decision(True, gap_sched, cost_sched)
        return Decision(False, gap_idle, cost_idle)

    def decide_batch(self, p_sched, p_idle, idle_gap, lag_base: int,
                     v_norm: float) -> BatchDecision:
        """Vectorized Alg. 2 line 6 over the whole waiting set.

        ``p_sched``/``p_idle`` are the Eq. (10) powers of the schedule/idle
        branch for each waiting user (the caller already selected co-run vs
        background powers from the app status); ``idle_gap`` the accumulated
        Eq. (12) gaps; ``lag_base`` the server lag estimate (in-flight tasks)
        at the start of the slot.

        Replicates the sequential in-slot coupling of repeated ``decide``
        calls exactly: every user that schedules raises the next user's lag
        estimate by one. When H == 0 the gap term cannot influence the
        argmin, so all decisions collapse to one elementwise comparison; the
        sequential dependence only materializes when the staleness queue has
        backlog, where we fall back to an O(k) scalar pass over a shared
        precomputed gap table.
        """
        p_s = np.asarray(p_sched, dtype=float)
        p_i = np.asarray(p_idle, dtype=float)
        ig = np.asarray(idle_gap, dtype=float)
        k = len(p_s)
        # Same elementwise operation order as decide(): V * P * t_d - Q + H*g
        base = self.V * p_s * self.t_d - self.Q
        rhs = self.V * p_i * self.t_d
        gap_idle = ig + self.epsilon
        # g(schedule) at every possible in-slot lag: lag_base + #scheduled-so-far
        gap_vec = gradient_gap(v_norm,
                               max(int(lag_base), 0) + np.arange(k + 1),
                               self.eta, self.beta)
        if self.H == 0.0 or k == 0:
            # +H*g adds exactly 0.0 to both branches -> order-free argmin
            schedule = base <= rhs
        else:
            # cost_sched(j) = base + H*gap_vec[j] is nondecreasing in j
            # (gap_vec is sorted, H > 0, IEEE mult/add are monotone), so
            # user i schedules iff its prefix count j_i <= K_i, the largest
            # j where the comparison holds. Users that pass even at the
            # worst-case lag ("always") or fail at the best ("never") are
            # order-free; only the rest need the sequential prefix replay.
            H = self.H
            ci = rhs + H * gap_idle
            if not np.all(np.diff(gap_vec) >= 0.0):
                # eta/v_norm < 0 inverts the gap ordering; the threshold
                # trick below would misclassify, so replay sequentially
                return self._decide_batch_sequential(base, rhs, gap_idle,
                                                     gap_vec, k)
            p_best = base + H * gap_vec[0] <= ci
            p_worst = base + H * gap_vec[k - 1] <= ci
            schedule = p_worst.copy()
            middle = p_best & ~p_worst
            if middle.any():
                midx = np.nonzero(middle)[0]
                bm, cm = base[midx], ci[midx]
                blo = np.zeros(len(midx), np.int64)       # comparison true
                bhi = np.full(len(midx), k - 1, np.int64)  # comparison false
                while np.any(bhi - blo > 1):
                    mid = (blo + bhi) >> 1
                    ok = bm + H * gap_vec[mid] <= cm
                    blo = np.where(ok, mid, blo)
                    bhi = np.where(ok, bhi, mid)
                K = blo.tolist()
                ca = (np.cumsum(schedule) - schedule)[midx].tolist()
                m = 0
                x = np.zeros(len(midx), dtype=bool)
                for ii in range(len(midx)):
                    if ca[ii] + m <= K[ii]:
                        x[ii] = True
                        m += 1
                schedule[midx] = x
        before = np.cumsum(schedule) - schedule          # exclusive prefix
        gaps = np.where(schedule, gap_vec[before], gap_idle)
        return BatchDecision(schedule, gaps, float(np.sum(gaps)),
                             int(np.count_nonzero(schedule)))

    def _decide_batch_sequential(self, base, rhs, gap_idle, gap_vec, k):
        """Literal replay of k decide() calls — correct for any gap
        ordering, O(k) Python; only reached with pathological eta/v_norm."""
        H = self.H
        schedule = np.zeros(k, dtype=bool)
        gaps = np.empty(k)
        bl, rl, gl, gv = base.tolist(), rhs.tolist(), gap_idle.tolist(), \
            gap_vec.tolist()
        j = 0
        for i in range(k):
            if bl[i] + H * gv[j] <= rl[i] + H * gl[i]:
                schedule[i] = True
                gaps[i] = gv[j]
                j += 1
            else:
                gaps[i] = gl[i]
        return BatchDecision(schedule, gaps, float(np.sum(gaps)), j)

    # ---------------------------------------------------------------- server
    def update_queues(self, arrivals: int, served: int, gap_sum: float,
                      departures: int = 0):
        """Eqs. (15)-(16); called once per slot with that slot's totals.
        ``departures`` extends Eq. (15) for device churn
        (core/dynamics.py): a waiting user whose device goes down leaves
        the request queue without being served, so the backlog drains by
        ``served + departures``. Zero (the default) is the paper's
        always-on fleet — bit-identical to the historical update."""
        self.Q = max(self.Q - served - departures, 0.0) + arrivals
        self.H = max(self.H + gap_sum - self.L_b, 0.0)

    def queue_state(self):
        return self.Q, self.H


def schedule_threshold(V: float, t_d: float, p_sched: float, p_idle: float) -> float:
    """Sec. V.B (Eq. 22), no-staleness regime: schedule iff
    Q >= V * t_d * (P_sched - P_idle). Exposed for tests/analysis."""
    return V * t_d * (p_sched - p_idle)
