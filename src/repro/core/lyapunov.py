"""Online scheduling via the Lyapunov drift-plus-penalty framework (Sec. V).

Queues:
    Q(t+1) = max(Q(t) - b(t), 0) + A(t)                       (Eq. 15)
    H(t+1) = max(H(t) + sum_i g_i(t,t+tau) - L_b, 0)          (Eq. 16)

Per-slot, per-user decision (Alg. 2 line 6, Eqs. 21-23):

    alpha_i = argmin over {schedule, idle} of
        V * P_i(alpha, s) * t_d  -  Q(t) * b_i(alpha)  +  H(t) * g_i(alpha)

with g_i(schedule) from Eq. (4) using the server-supplied lag estimate and
g_i(idle) = previous gap + epsilon (Eq. 12). Theorem 1 gives the
[O(1/V), O(V)] energy-staleness trade-off.

The implementation is the paper's *distributed* variant: each user needs only
(Q, H, V, its own power profile, the lag estimate and the momentum norm) —
two scalars from the server, no app-usage leakage.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .staleness import gradient_gap


@dataclasses.dataclass
class UserSlotState:
    """What user i knows at slot t."""
    p_corun: float        # P^{a'} for the currently-running app (if any)
    p_app: float          # P^a
    p_train: float        # P^b
    p_idle: float         # P^d
    app_running: bool
    lag_estimate: int     # supplied by server (Alg. 2 line 4)
    idle_gap: float       # accumulated g_i from idle slots (Eq. 12)


@dataclasses.dataclass
class Decision:
    schedule: bool
    gap: float            # the g_i(t, t+tau) this decision contributes
    cost: float


class OnlineScheduler:
    """Server-side queue state + the per-user argmin (distributed form)."""

    def __init__(self, V: float, L_b: float, eta: float, beta: float,
                 epsilon: float = 0.05, t_d: float = 1.0):
        self.V = float(V)
        self.L_b = float(L_b)
        self.eta = eta
        self.beta = beta
        self.epsilon = epsilon
        self.t_d = t_d
        self.Q = 0.0
        self.H = 0.0

    # ---------------------------------------------------------------- client
    def decide(self, u: UserSlotState, v_norm: float) -> Decision:
        """Alg. 2 line 6: argmin_{alpha} V*P - Q*b + H*g. Pure O(1)."""
        gap_sched = gradient_gap(v_norm, max(u.lag_estimate, 0), self.eta, self.beta)
        gap_idle = u.idle_gap + self.epsilon

        p_sched = u.p_corun if u.app_running else u.p_train     # Eq. (10)
        p_idle = u.p_app if u.app_running else u.p_idle

        cost_sched = self.V * p_sched * self.t_d - self.Q + self.H * gap_sched
        cost_idle = self.V * p_idle * self.t_d + self.H * gap_idle
        if cost_sched <= cost_idle:
            return Decision(True, gap_sched, cost_sched)
        return Decision(False, gap_idle, cost_idle)

    # ---------------------------------------------------------------- server
    def update_queues(self, arrivals: int, served: int, gap_sum: float):
        """Eqs. (15)-(16); called once per slot with that slot's totals."""
        self.Q = max(self.Q - served, 0.0) + arrivals
        self.H = max(self.H + gap_sum - self.L_b, 0.0)

    def queue_state(self):
        return self.Q, self.H


def schedule_threshold(V: float, t_d: float, p_sched: float, p_idle: float) -> float:
    """Sec. V.B (Eq. 22), no-staleness regime: schedule iff
    Q >= V * t_d * (P_sched - P_idle). Exposed for tests/analysis."""
    return V * t_d * (p_sched - p_idle)
