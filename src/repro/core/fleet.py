"""Pluggable device fleets.

The paper's evaluation assigns every user a device from the frozen four-row
Table II testbed, round-robin then shuffled. This module turns fleet
composition into a composable object: a ``Fleet`` builds the per-user
device assignment AND the struct-of-arrays ``DeviceTables`` the batched
engines gather from — so fleets are no longer limited to the Table II
catalog.

Ships: ``paper`` (Table II round-robin, draw-for-draw identical to the
pre-registry simulator), ``synthetic`` (a scaled catalog of jittered
Table II variants for fleet-heterogeneity studies at any catalog size),
and ``custom`` (bring-your-own ``DeviceProfile`` catalog).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple, Type

import numpy as np

from .energy import (APPS, DEVICE_NAMES, TESTBED, AppProfile, DeviceProfile,
                     DeviceTables, build_tables, catalog_tables, device_ids)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A built fleet: what FederatedSim and the batched engines consume.

    ``devices[i]`` is user i's profile (the loop oracle reads it);
    ``tables`` is this fleet's catalog flattened for the vectorized/jax
    engines, and ``device_ids[i]`` the row of ``tables`` user i gathers.
    """
    devices: Tuple[DeviceProfile, ...]
    tables: DeviceTables
    device_ids: np.ndarray


class Fleet:
    name: str = ""

    def build(self, rng: np.random.Generator, n_users: int) -> FleetSpec:
        """Assign a device to each of ``n_users`` users. Draws (if any)
        must come from ``rng`` so runs stay seed-reproducible."""
        raise NotImplementedError


_REGISTRY: Dict[str, Type[Fleet]] = {}


def register_fleet(cls: Type[Fleet]) -> Type[Fleet]:
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    _REGISTRY[cls.name] = cls
    return cls


def registered_fleets() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_fleet(fleet) -> Fleet:
    """String -> default-constructed registered fleet; instance -> itself."""
    if isinstance(fleet, Fleet):
        return fleet
    if isinstance(fleet, str):
        if fleet not in _REGISTRY:
            raise ValueError(f"unknown fleet {fleet!r}; expected one of "
                             f"{registered_fleets()} or a Fleet instance")
        try:
            return _REGISTRY[fleet]()
        except TypeError as e:
            raise ValueError(f"fleet {fleet!r} needs constructor arguments; "
                             f"pass an instance instead ({e})") from None
    raise ValueError(f"fleet must be a name or Fleet instance, "
                     f"got {type(fleet).__name__}")


def _validate_catalog(devices: Sequence[DeviceProfile]):
    if not devices:
        raise ValueError("fleet catalog is empty")
    for d in devices:
        missing = [a for a in APPS if a not in d.apps]
        if missing:
            raise ValueError(
                f"device {d.name!r} lacks profiles for apps {missing}; "
                "every device must profile the full energy.APPS list")


@register_fleet
class PaperFleet(Fleet):
    """Table II testbed, round-robin across users then shuffled.

    Reproduces the pre-registry ``FederatedSim.__init__`` assignment
    draw-for-draw: the single ``rng.shuffle`` here is the first rng use of
    a run, exactly as before."""

    name = "paper"

    def build(self, rng, n_users):
        names = [DEVICE_NAMES[i % len(DEVICE_NAMES)]
                 for i in range(n_users)]
        rng.shuffle(names)
        return FleetSpec(devices=tuple(TESTBED[n] for n in names),
                         tables=catalog_tables(),
                         device_ids=device_ids(names))


@register_fleet
class CustomCatalogFleet(Fleet):
    """Bring-your-own catalog of ``DeviceProfile``s.

    ``assignment``: "round_robin" (deterministic, catalog order) or
    "random" (uniform per user, drawn from the run rng)."""

    name = "custom"

    def __init__(self, catalog: Sequence[DeviceProfile],
                 assignment: str = "round_robin"):
        devices = list(catalog.values()) \
            if isinstance(catalog, dict) else list(catalog)
        _validate_catalog(devices)
        if assignment not in ("round_robin", "random"):
            raise ValueError(f"unknown assignment {assignment!r}; expected "
                             "'round_robin' or 'random'")
        self.catalog = devices
        self.assignment = assignment
        self._tables = build_tables(devices)

    def build(self, rng, n_users):
        k = len(self.catalog)
        if self.assignment == "round_robin":
            ids = np.arange(n_users, dtype=np.int64) % k
        else:
            ids = rng.integers(0, k, n_users)
        return FleetSpec(devices=tuple(self.catalog[i] for i in ids),
                         tables=self._tables,
                         device_ids=ids)


@register_fleet
class SyntheticFleet(Fleet):
    """Scaled synthetic catalog: ``n_types`` device classes derived from
    Table II rows by jittering power draw and speed.

    Each synthetic class starts from a Table II device (round-robin) and
    applies an independent power factor and speed factor drawn uniformly
    from [1 - spread, 1 + spread]. Scaling all four power states by one
    factor preserves the paper's P^{a'} > P^a > P^b ordering per device and
    keeps co-run savings positive; the speed factor stretches both
    standalone and co-run durations. Users are assigned classes uniformly
    at random. The catalog itself is sampled from the run rng, so a fleet
    instance is reusable and every run stays seed-deterministic."""

    name = "synthetic"

    def __init__(self, n_types: int = 16, spread: float = 0.3):
        if n_types <= 0:
            raise ValueError(f"n_types must be positive, got {n_types}")
        if not 0.0 <= spread < 1.0:
            raise ValueError(f"spread must be in [0, 1), got {spread}")
        self.n_types = int(n_types)
        self.spread = float(spread)

    def _make_catalog(self, rng) -> List[DeviceProfile]:
        catalog = []
        for k in range(self.n_types):
            base = TESTBED[DEVICE_NAMES[k % len(DEVICE_NAMES)]]
            pf = 1.0 + self.spread * rng.uniform(-1.0, 1.0)
            sf = 1.0 + self.spread * rng.uniform(-1.0, 1.0)
            apps = {a: AppProfile(p_app=ap.p_app * pf,
                                  p_corun=ap.p_corun * pf,
                                  t_corun=ap.t_corun * sf)
                    for a, ap in base.apps.items()}
            catalog.append(DeviceProfile(
                name=f"{base.name}-synth{k}",
                p_train=base.p_train * pf,
                t_train=base.t_train * sf,
                p_idle=base.p_idle * pf,
                p_sched=base.p_sched * pf,
                apps=apps))
        return catalog

    def build(self, rng, n_users):
        catalog = self._make_catalog(rng)
        ids = rng.integers(0, self.n_types, n_users)
        return FleetSpec(devices=tuple(catalog[i] for i in ids),
                         tables=build_tables(catalog),
                         device_ids=ids)
