"""Real-ML coupling for the simulator (Fig. 5): real models on cifarlike
data, momentum SGD (Eq. 1), async parameter server vs FedAvg.

Two ways to couple a schedule to actual JAX training:

* ``BatchedMLBackend`` — the first-class protocol. A backend owns the
  server, the per-client shards and the in-flight (pulled) parameter
  snapshots, and exposes *batched* entry points the vectorized engine
  dispatches once per slot cohort instead of n Python callbacks (cohort
  indices, pull versions and lags all come from the engine's shared
  ``EngineState`` — core/engine_state.py):
  ``pull_batch`` -> ``local_train_batch`` (one ``jax.vmap``'d masked epoch
  over the whole finisher cohort, jit-compiled once per cohort shape) ->
  ``push_batch``/``submit_batch`` (sequential server application in user
  order, preserving the loop oracle's push ordering exactly).
* ``make_ml_hooks`` — the historical per-user callback dict for the loop
  engine, now a thin adapter over ``LeNetBackend.hooks()``. Same
  construction order, same rng stream, same jitted per-client epoch, so
  pre-existing seeded loop runs reproduce bit-for-bit.

The batched protocol is model-agnostic: ``ImageClassifierBackend`` holds
all the cohort batching / fused-scan machinery parameterized by three
module-level model functions (init / loss / logits), and ``LeNetBackend``
(the paper's workload) and ``MLPBackend`` (models/mlp.py) are thin
subclasses — the jitted cohort programs key on the loss function as a
static argument, so each model compiles its own executables while sharing
every line of driver code. The push-apply side is kernel-switchable
(``kernel="pallas"|"reference"|"auto"``): under ``"pallas"`` the fused
finish scan flattens the model once and applies every push with the
single-HBM-pass ``fused_apply_2d`` Pallas kernel — the per-push momentum
norm chains through the scan carry as a scalar instead of re-traversing
the pytree.

Equivalence contract (pinned by tests/test_real_mode.py): under the
paper's queue regime (L_b large enough that H stays 0, where the online
decision is independent of the momentum norm) the batched path reproduces
the loop oracle's schedule — update counts, lags, push order — exactly;
accuracy/energy/gap trajectories match within float tolerance (vmap'd XLA
programs are not bit-identical to their per-client counterparts).
"""
from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Dict, Optional, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (AggregationRule, ReplaceRule,
                                    aggregation_support)
from repro.core.client import Client
from repro.core.policies import _jax_gradient_gap
from repro.core.server import AsyncParameterServer, SyncServer
from repro.core.staleness import gradient_gap
from repro.data.synthetic import cifarlike_dataset, dirichlet_partition
from repro.kernels.fused_update import kernel_interpret, resolve_kernel_mode
from repro.kernels.fused_update.kernel import LANES, fused_apply_2d
from repro.kernels.fused_update.ops import clamp_block_rows
from repro.models.lenet import init_lenet, lenet_logits, lenet_loss
from repro.models.mlp import init_mlp, mlp_logits, mlp_loss


class BatchedMLBackend:
    """Protocol for batched real-ML coupling (vectorized-engine capable).

    A backend instance is single-run state: it owns the parameter server,
    the per-client data, and the pulled-parameter snapshots of every
    in-flight user. The vectorized engine drives it with whole cohorts;
    the loop oracle drives the same instance through ``hooks()``. Construct
    a fresh backend per run (server state is consumed by a run).

    Attributes engines rely on: ``n_users`` (validated against
    ``SimConfig.n_users``), ``sync`` (FedAvg lock-step vs async parameter
    server — must match the policy's ``sync_rounds``), ``eval_every``
    (slots between accuracy samples).
    """

    name: str = ""
    n_users: int = 0
    sync: bool = False
    eval_every: int = 600

    # ------------------------------------------------------------ loop adapter
    def hooks(self) -> dict:
        """Per-user callback dict for ``FederatedSim``'s loop engine —
        the same backend state behind the historical hook protocol."""
        raise NotImplementedError

    def bind_fleet(self, fleet_spec, cfg=None) -> None:
        """Receive the run's ``FleetSpec`` and ``SimConfig``
        (``FederatedSim`` calls this at construction). Fleet-conditioned
        aggregation rules (core/aggregation.py ``hetero_aware``) need
        the fleet to derive device-class scales, and the config is
        forwarded to the rule's ``scan_operands``/``init_carry`` on the
        fused push-scan path; the default is a no-op."""

    # ------------------------------------------------------------ batched path
    def pull_batch(self, uids: np.ndarray, version: int) -> None:
        """Snapshot the current global parameters for every uid starting
        training this slot. ``version`` is the engine's global model
        version at pull time — ``EngineState.version``, the same counter
        every engine threads (core/engine_state.py) — for staleness-aware
        backends."""
        raise NotImplementedError

    def local_train_batch(self, uids: np.ndarray, versions: np.ndarray):
        """One local epoch for the whole finisher cohort at once; returns
        the trained parameters stacked on a leading ``len(uids)`` axis.
        ``versions`` are the per-uid pull versions the engine recorded in
        ``EngineState.pulled_at``."""
        raise NotImplementedError

    def push_batch(self, uids: np.ndarray, trained, lags: np.ndarray,
                   eta: float, beta: float):
        """Apply the cohort's pushes to the async server sequentially in
        ``uids`` order (the loop oracle's ordering), returning
        ``(gaps, weights)``: the Eq. (4) gap of each push evaluated
        against the momentum norm *before* that push was applied —
        exactly what the loop's per-user finish does — and the
        aggregation rule's applied mixing weight per push."""
        raise NotImplementedError

    def submit_batch(self, uids: np.ndarray, trained, lags: np.ndarray,
                     eta: float, beta: float):
        """Sync-mode twin of ``push_batch``: submit the cohort's results
        to the FedAvg server (aggregation happens at round close).
        Returns ``(gaps, weights)`` with unit weights (FedAvg averages;
        there is no per-push weight)."""
        raise NotImplementedError

    def finish_async_batch(self, uids: np.ndarray, versions: np.ndarray,
                           lags: np.ndarray, eta: float, beta: float,
                           need_gaps: bool = True):
        """Whole async finish for a cohort: local_train_batch followed by
        push_batch; returns ``(gaps, weights)``. Backends may override
        with a fused implementation (one device dispatch for train +
        weighted ordered pushes). With ``need_gaps=False`` (no push log
        collected) the return value is ignored and backends may skip the
        gap/weight read-back — and with it any host-device
        synchronization."""
        trained = self.local_train_batch(uids, versions)
        return self.push_batch(uids, trained, lags, eta, beta)

    def sync_aggregate(self) -> None:
        """Close a FedAvg round (sync backends only)."""
        raise NotImplementedError

    def v_norm(self) -> float:
        """Current global momentum-norm estimate (0.0 for sync)."""
        raise NotImplementedError

    def evaluate(self) -> float:
        """Test accuracy of the current global model."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Backend registry (Scenario's ml="lenet" resolves here)
# ---------------------------------------------------------------------------
ML_BACKENDS: Dict[str, Type[BatchedMLBackend]] = {}


def register_ml_backend(cls: Type[BatchedMLBackend]) -> Type[BatchedMLBackend]:
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    ML_BACKENDS[cls.name] = cls
    return cls


def registered_ml_backends() -> tuple:
    return tuple(ML_BACKENDS)


def make_backend(ml: Union[str, BatchedMLBackend], n_users: int, *,
                 sync: bool = False, seed: int = 0,
                 **kwargs) -> BatchedMLBackend:
    """Resolve ``ml`` to a fresh backend instance. Strings go through the
    registry; instances pass through as-is (their constructor already fixed
    n_users/sync/seed)."""
    if isinstance(ml, BatchedMLBackend):
        return ml
    if isinstance(ml, str):
        if ml not in ML_BACKENDS:
            raise ValueError(f"unknown ML backend {ml!r}; expected one of "
                             f"{registered_ml_backends()} or a "
                             "BatchedMLBackend instance")
        return ML_BACKENDS[ml](n_users, sync=sync, seed=seed, **kwargs)
    raise ValueError(f"ml must be a name or BatchedMLBackend instance, "
                     f"got {type(ml).__name__}")


# ---------------------------------------------------------------------------
# Jitted cohort programs (module-level so every backend instance with the
# same data shapes and hyperparameters shares one compiled executable).
# ---------------------------------------------------------------------------
def _masked_epoch(params, idx, mask, flat_x, flat_y, eta, beta, loss_fn):
    """One local momentum-SGD epoch (Eq. 1, the Client._epoch step rule)
    over minibatches ``flat_x[idx]``; masked steps are no-ops (ragged
    shards / padding lanes). ``loss_fn`` is the backend's model loss
    (a module-level function — the jit static-arg key)."""
    bx = flat_x[idx]                       # (S, B, H, W, C)
    by = flat_y[idx]                       # (S, B)
    v0 = jax.tree.map(jnp.zeros_like, params)

    def step(carry, xs):
        p, v = carry
        x, y, m = xs
        grads, _ = jax.grad(
            lambda q: loss_fn(q, {"images": x, "labels": y}),
            has_aux=True)(p)
        v2 = jax.tree.map(lambda vv, g: beta * vv + (1 - beta) * g,
                          v, grads)
        p2 = jax.tree.map(lambda pp, vv: pp - eta * vv, p, v2)
        p = jax.tree.map(lambda a, b: jnp.where(m, a, b), p2, p)
        v = jax.tree.map(lambda a, b: jnp.where(m, a, b), v2, v)
        return (p, v), None

    (params, _), _ = jax.lax.scan(step, (params, v0), (bx, by, mask))
    return params


def _tree_l2_norm_traced(tree):
    """staleness.tree_l2_norm, usable under jit (same accumulation order:
    Python sum over tree.leaves, f32)."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _lanes(params, idx, shared):
    """The chunk's per-lane parameter stack. ``shared=True`` means every
    lane pulled the SAME global snapshot (lock-step cohorts under replace
    aggregation — the common case), so the caller passed one tree and the
    lanes are a free in-device broadcast. Otherwise ``params`` is a tuple
    of per-lane trees and the stack happens HERE, inside the jit — eager
    per-leaf stacking costs milliseconds per op on CPU."""
    if not shared:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *params)
    C = idx.shape[0]
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (C,) + a.shape), params)


@functools.partial(jax.jit, static_argnames=("n_epochs", "n_i"))
def _perm_bank(key, n_epochs, n_i):
    """``n_epochs`` iterations of the Client.local_train key protocol —
    ``key, sub = split(key)`` then ``permutation(sub, n_i)`` — in one
    dispatch. The scanned split chain is bit-identical to sequential
    eager splits, so banked draws equal the loop engine's."""
    def step(k, _):
        k2, sub = jax.random.split(k)
        return k2, sub

    key, subs = jax.lax.scan(step, key, None, length=n_epochs)
    perms = jax.vmap(lambda s: jax.random.permutation(s, n_i))(subs)
    return key, perms


@functools.partial(jax.jit,
                   static_argnames=("eta", "beta", "shared", "loss_fn"))
def _train_chunk(params, idx, mask, flat_x, flat_y, eta, beta, shared,
                 loss_fn):
    """vmap'd masked epoch over one cohort chunk."""
    return jax.vmap(
        lambda p, i, m: _masked_epoch(p, i, m, flat_x, flat_y, eta, beta,
                                      loss_fn)
    )(_lanes(params, idx, shared), idx, mask)


_FINISH_FN_CACHE: dict = {}
_FINISH_FN_CACHE_MAX = 16


def _finish_chunk_fn(rule, eta, beta, shared, need_gaps, loss_fn, kernel):
    """The fused-finish executable for one (rule, hyperparams, layout,
    model, kernel) combination, memoized on ``rule.jax_cache_key()`` — the
    same keying the trace engine's scan cache uses, so fresh
    knob-configured instances of operand-driven rules (knobs ride the
    traced ``agg_ops``) share ONE compiled program instead of retracing
    the most expensive jit in the repo per instance. ``loss_fn`` (the
    model, a module-level function) and ``kernel`` (a resolved
    "pallas"/"reference") key alongside."""
    key = (rule.jax_cache_key(), eta, beta, shared, need_gaps, loss_fn,
           kernel)
    fn = _FINISH_FN_CACHE.pop(key, None)    # pop+reinsert = LRU order
    if fn is None:
        fn = _build_finish_chunk(rule, eta, beta, shared, need_gaps,
                                 loss_fn, kernel)
        if len(_FINISH_FN_CACHE) >= _FINISH_FN_CACHE_MAX:
            _FINISH_FN_CACHE.pop(next(iter(_FINISH_FN_CACHE)))
    _FINISH_FN_CACHE[key] = fn
    return fn


def _build_finish_chunk(rule, eta, beta, shared, need_gaps, loss_fn,
                        kernel):
    """Fused async finish: train the whole chunk (vmap) then apply the
    pushes sequentially in lane order (lax.scan) with the aggregation
    rule's mixing weight (core/aggregation.py — the rule's traced
    ``scan_weight`` hook runs IN the scan, so weighted rules cost zero
    per-push host round-trips) and the server momentum recursion of
    ``AsyncParameterServer.push``:

        w       = rule.scan_weight(lag_j, gap_j, ||v||_pre)
        params <- w * trained_j + (1 - w) * params
        s       = (params_old - params_new) / eta
        v      <- beta * v + (1 - beta) * s

    Under the paper's "replace" rule the weight math is skipped entirely
    (``params <- trained_j``, the historical op sequence, kept
    bit-identical for the golden oracle). Emits ``||v||`` and the
    applied weight at each step *start* — the momentum norm each push's
    Eq. (4) gap is evaluated against in the loop oracle (the norm left
    by the previous finisher). Invalid (padding) lanes leave the carry
    untouched.

    ``kernel="pallas"`` swaps the per-push pytree traversals for the
    single-HBM-pass ``fused_apply_2d`` kernel: the global params/momentum
    ride the scan carry as one padded (rows, 128) f32 matrix (flattened
    ONCE per chunk, not per push), each push is one kernel dispatch
    (mix + momentum + Sum(v'^2)), and the pre-push Eq. (4) norm is
    ``sqrt`` of the carried sum-of-squares scalar — no
    ``_tree_l2_norm_traced`` traversals anywhere in the scan.
    """
    replace = isinstance(rule, ReplaceRule)
    # per-step pre-push norms feed the push-log gaps AND gap-reading
    # rule weights; without either they are dead weight (10 tree
    # reductions per push)
    need_norms = need_gaps or rule.needs_gap
    eta_s = max(eta, 1e-12)
    if kernel == "pallas":
        return _build_finish_chunk_pallas(rule, eta, beta, shared,
                                          need_norms, loss_fn, replace,
                                          eta_s)

    @jax.jit
    def finish(params, idx, mask, valid, lags, uids, agg_carry, agg_ops,
               server_params, server_v, flat_x, flat_y):
        trained = jax.vmap(
            lambda p, i, m: _masked_epoch(p, i, m, flat_x, flat_y, eta,
                                          beta, loss_fn)
        )(_lanes(params, idx, shared), idx, mask)

        def push_step(carry, xs):
            p, v = carry
            t_j, ok, lag_j, uid_j = xs
            vnorm_pre = _tree_l2_norm_traced(v) if need_norms \
                else jnp.asarray(0.0, jnp.float32)
            if replace:
                w = jnp.asarray(1.0, jnp.float32)
                p_new = t_j
            else:
                # Eq. (4) gap against the pre-push norm, the value the
                # server's host path feeds the rule — the same traced
                # twin the jax trace engine uses
                gap_j = _jax_gradient_gap(vnorm_pre, lag_j, eta, beta)
                pv = SimpleNamespace(jnp=jnp, lag=lag_j, gap=gap_j,
                                     v_norm=vnorm_pre, users=uid_j,
                                     consts=agg_ops,
                                     float_dtype=vnorm_pre.dtype)
                _, w = rule.scan_weight(agg_carry, pv)
                p_new = jax.tree.map(lambda n_, o: w * n_ + (1 - w) * o,
                                     t_j, p)
            s = jax.tree.map(lambda o, n_: (o - n_) / eta_s, p, p_new)
            v2 = jax.tree.map(lambda vv, g: beta * vv + (1 - beta) * g,
                              v, s)
            p = jax.tree.map(lambda a, b: jnp.where(ok, a, b), p_new, p)
            v = jax.tree.map(lambda a, b: jnp.where(ok, a, b), v2, v)
            return (p, v), (vnorm_pre, w)

        (p_out, v_out), (vnorms, ws) = jax.lax.scan(
            push_step, (server_params, server_v),
            (trained, valid, lags, uids))
        return p_out, v_out, vnorms, ws, _tree_l2_norm_traced(v_out)

    return finish


def _build_finish_chunk_pallas(rule, eta, beta, shared, need_norms,
                               loss_fn, replace, eta_s):
    """The Pallas twin of ``_build_finish_chunk``'s push scan (same
    signature, same outputs to rtol): train the chunk, flatten the global
    (params, momentum) to one padded (rows, 128) f32 carry, then apply
    each push as ONE ``fused_apply_2d`` dispatch. The post-push
    sum-of-squares chains through the carry, so each push's pre-norm
    (Eq. 4) is a scalar ``sqrt`` and the final ``||v||`` costs nothing —
    the reference path's 10-leaf tree reductions per push disappear.
    Replace degenerates to w=1 through the same kernel (mixed == t_j)."""
    interpret = kernel_interpret()

    @jax.jit
    def finish(params, idx, mask, valid, lags, uids, agg_carry, agg_ops,
               server_params, server_v, flat_x, flat_y):
        trained = jax.vmap(
            lambda p, i, m: _masked_epoch(p, i, m, flat_x, flat_y, eta,
                                          beta, loss_fn)
        )(_lanes(params, idx, shared), idx, mask)

        # ---- flatten ONCE per chunk to the kernel's (rows, 128) layout
        leaves = jax.tree.leaves(server_params)
        treedef = jax.tree.structure(server_params)
        shapes = [l.shape for l in leaves]
        sizes = [l.size for l in leaves]
        n_tot = sum(sizes)
        block_rows = clamp_block_rows(n_tot)
        per_block = block_rows * LANES
        padded = -(-n_tot // per_block) * per_block
        rows = padded // LANES

        def flat2d(tree):
            f = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                 for l in jax.tree.leaves(tree)])
            return jnp.pad(f, (0, padded - n_tot)).reshape(rows, LANES)

        p2 = flat2d(server_params)
        v2 = flat2d(server_v)
        # trained lanes: (C, rows, 128), padded along the flat axis —
        # padding lanes mix 0 with 0 and add 0 to the norm
        t2 = jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32)
             for l in jax.tree.leaves(trained)], axis=1)
        t2 = jnp.pad(t2, ((0, 0), (0, padded - n_tot)))
        t2 = t2.reshape(t2.shape[0], rows, LANES)
        # entry sum-of-squares: one reduction per CHUNK; every in-scan
        # pre-norm after this is carried forward by the kernel
        sumsq0 = jnp.sum(v2 * v2)
        inv_eta = 1.0 / eta_s

        def push_step(carry, xs):
            p, v, sq = carry
            t_j, ok, lag_j, uid_j = xs
            vnorm_pre = jnp.sqrt(sq) if need_norms \
                else jnp.asarray(0.0, jnp.float32)
            if replace:
                w = jnp.asarray(1.0, jnp.float32)
            else:
                gap_j = _jax_gradient_gap(vnorm_pre, lag_j, eta, beta)
                pv = SimpleNamespace(jnp=jnp, lag=lag_j, gap=gap_j,
                                     v_norm=vnorm_pre, users=uid_j,
                                     consts=agg_ops,
                                     float_dtype=vnorm_pre.dtype)
                _, w = rule.scan_weight(agg_carry, pv)
            mixed, v_new, sq_new = fused_apply_2d(
                p, v, t_j, w, inv_eta, beta, block_rows=block_rows,
                interpret=interpret)
            p = jnp.where(ok, mixed, p)
            v = jnp.where(ok, v_new, v)
            sq = jnp.where(ok, sq_new, sq)
            return (p, v, sq), (vnorm_pre, w)

        (p2, v2, sq), (vnorms, ws) = jax.lax.scan(
            push_step, (p2, v2, sumsq0), (t2, valid, lags, uids))

        def unflat(f2):
            f = f2.reshape(-1)[:n_tot]
            out, off = [], 0
            for shp, sz in zip(shapes, sizes):
                out.append(f[off:off + sz].reshape(shp))
                off += sz
            return treedef.unflatten(out)

        return unflat(p2), unflat(v2), vnorms, ws, jnp.sqrt(sq)

    return finish


class ImageClassifierBackend(BatchedMLBackend):
    """Model-agnostic batched backend: any image classifier on cifarlike
    shards. Subclasses bind three module-level model functions
    (``model_init`` / ``model_loss`` / ``model_logits``) and a registry
    ``name`` — everything else (cohort batching, permutation banks, the
    fused train+push scan, kernel dispatch) lives here once. The model
    functions are staticmethods of MODULE-LEVEL functions on purpose:
    their identity is the jit static-arg and finish-cache key, so every
    instance of a subclass shares one set of compiled executables.

    Per-client pulled parameters are pytree REFERENCES (``_inflight``),
    so a pull costs zero device work; at train time a cohort whose lanes
    all share one snapshot (lock-step pulls under replace aggregation,
    the common case) is broadcast in-device, and ragged cohorts stack
    their lanes inside the jit (tuple-of-trees argument — never eagerly).
    Cohorts are processed in chunks padded to the next power of FOUR
    (capped at ``cohort_pad`` lanes, padding lanes masked out, up to ~4x
    masked waste on the smallest cohorts), so the vmap'd epoch and the
    fused train+push program compile O(log4 cohort_pad) distinct shapes
    per run — not once per ragged cohort size — and the executables are
    shared across backend instances (module-level jit). Per-event host
    work is plain numpy: minibatch permutations come from precomputed
    per-client banks (same key chain as ``Client.local_train``), so the
    hot path issues one or two stable-shape device dispatches per chunk
    and never blocks. Shards are ragged
    (Dirichlet split): every lane runs ``S_max`` scan steps with per-step
    masks, where ``S_max`` is the fleet-wide maximum steps-per-epoch, and
    masked steps leave (params, momentum) untouched. The whole finish —
    cohort epoch + ordered weighted sequential pushes + per-push momentum
    norms — is one device dispatch (``_finish_chunk_fn``) for EVERY
    aggregation rule with a traced ``scan_weight`` hook (all registered
    rules: replace, fedasync_poly, gap_aware, hetero_aware —
    core/aggregation.py), the weights mixed inside the push scan with no
    per-push host round-trips; only custom numpy-only rules fall back to
    per-push server calls. ``kernel="pallas"`` routes every push apply —
    the server's and the fused scan's — through the single-HBM-pass
    Pallas kernel (``kernels/fused_update``); the default ``"auto"``
    keeps the bit-stable reference path off-TPU.
    """

    # bound by subclasses: module-level (init, loss, logits) functions
    model_init: staticmethod
    model_loss: staticmethod
    model_logits: staticmethod

    def __init__(self, n_users: int, *, sync: bool = False,
                 eta: float = 0.01, beta: float = 0.9,
                 n_train: int = 10000, n_test: int = 2000,
                 alpha: float = 100.0, batch_size: int = 20,
                 aggregation: Union[str, AggregationRule] = "replace",
                 noise: float = 8.0,
                 seed: int = 0, eval_every: int = 600,
                 cohort_pad: int = 16, partition: str = "dirichlet",
                 kernel: str = "auto"):
        # construction order (data -> shards -> clients -> params -> server)
        # is pinned: it is the historical make_ml_hooks rng stream, and the
        # loop-oracle golden (tests/data/real_mode_golden.json) depends on it
        images, labels = cifarlike_dataset(n_train, seed=seed, noise=noise)
        test_x, test_y = cifarlike_dataset(n_test, seed=seed + 1, noise=noise)
        if partition == "dirichlet":       # the paper's non-IID split
            shards = dirichlet_partition(labels, n_users, alpha=alpha,
                                         seed=seed)
        elif partition == "uniform":
            # IID near-equal shards (exactly equal when n_users divides
            # n_train): uniform step counts mean one jit shape for the
            # loop's per-client epoch and minimal masked-step waste in
            # the batched cohort epoch
            shards = np.array_split(np.arange(n_train, dtype=np.int64),
                                    n_users)
        else:
            raise ValueError(f"unknown partition {partition!r}; expected "
                             "'dirichlet' or 'uniform'")
        self.clients = [
            Client(i, jnp.asarray(images[s]), jnp.asarray(labels[s]),
                   self.model_loss, batch_size=batch_size, eta=eta,
                   beta=beta)
            for i, s in enumerate(shards)]
        params0 = self.model_init(jax.random.PRNGKey(seed))
        self.server: object
        if sync:
            self.server = SyncServer(params0)
        else:
            self.server = AsyncParameterServer(params0, eta=eta, beta=beta,
                                               aggregation=aggregation,
                                               kernel=kernel)
        self.kernel = resolve_kernel_mode(kernel)
        self.n_users = n_users
        self.sync = sync
        self.eta = eta
        self.beta = beta
        self.batch_size = batch_size
        self.eval_every = eval_every
        self.cohort_pad = max(int(cohort_pad), 1)
        # the run's FleetSpec/SimConfig and the aggregation rule's carry
        # (device arrays for the fused push scan), set by bind_fleet
        self.fleet_spec = None
        self._sim_cfg = None
        self._agg_carry = None

        # ---- batched-training layout ---------------------------------
        # client shards concatenated flat; per-epoch minibatch gathers are
        # one fancy-index into these (offset + client-local permutation)
        self._offsets = np.zeros(n_users, dtype=np.int64)
        off = 0
        for i, s in enumerate(shards):
            self._offsets[i] = off
            off += len(s)
        self._shard_sizes = np.array([len(s) for s in shards], np.int64)
        self._flat_x = jnp.asarray(np.concatenate(
            [images[s] for s in shards], axis=0))
        self._flat_y = jnp.asarray(np.concatenate(
            [labels[s] for s in shards], axis=0))
        self._steps = self._shard_sizes // batch_size
        self._s_max = int(self._steps.max()) if n_users else 0
        # pulled-parameter snapshot per in-flight uid: pytree REFERENCES
        # (immutable), so a pull costs zero device work. Cohorts whose
        # lanes all share one snapshot (lock-step pulls under replace
        # aggregation) are broadcast in-device at train time; ragged
        # cohorts pay one host-side stack.
        self._inflight: list = [params0] * n_users
        # per-client minibatch-permutation banks: epochs of
        # jax.random.permutation draws precomputed in batches so the hot
        # path never touches the device RNG (parity: identical key chain
        # and draws as Client.local_train, verified by the golden tests)
        self._perm_bank: list = [None] * n_users
        self._bank_pos = np.zeros(n_users, dtype=np.int64)
        self._bank_epochs = 16

        test_x_j = jnp.asarray(test_x)
        test_y_j = jnp.asarray(test_y)
        logits_fn = self.model_logits

        @jax.jit
        def _acc(params):
            logits = logits_fn(params, test_x_j)
            return jnp.mean((jnp.argmax(logits, -1) == test_y_j)
                            .astype(jnp.float32))

        self._acc = _acc

    # ------------------------------------------------------------ loop adapter
    def hooks(self) -> dict:
        """The historical per-user hook dict over this backend's state."""
        hooks = {
            "pull": lambda uid: self.server.pull(uid)[0],
            "local_train":
                lambda uid, params: self.clients[uid].local_train(params)[0],
            "evaluate": self.evaluate,
            "v_norm": self.v_norm,
            "eval_every": self.eval_every,
        }
        if self.sync:
            hooks["sync_submit"] = self.server.submit
            hooks["sync_aggregate"] = self.server.aggregate
        else:
            hooks["push"] = lambda uid, params: self.server.push(uid, params)
        return hooks

    def bind_fleet(self, fleet_spec, cfg=None) -> None:
        """Bind the run's FleetSpec + SimConfig (FederatedSim calls
        this): the fleet is forwarded to the async server for
        fleet-conditioned host-path weights, the rule carry (e.g.
        hetero_aware's per-user scales) is gathered once as device
        arrays for the fused push scan, and the config is kept so the
        rule's ``scan_operands`` sees the same cfg the trace engines
        pass."""
        self.fleet_spec = fleet_spec
        self._sim_cfg = cfg
        if isinstance(self.server, AsyncParameterServer):
            self.server.fleet_spec = fleet_spec
            carry = self.server.rule.init_carry(self.n_users, cfg,
                                                fleet_spec)
            self._agg_carry = jax.tree.map(jnp.asarray, carry)

    # ------------------------------------------------------------ batched path
    def _next_perm(self, uid: int) -> np.ndarray:
        """The client's next epoch permutation, from its precomputed
        bank. Banks are filled ``_bank_epochs`` at a time by consuming
        the client's key stream exactly like ``Client.local_train`` (one
        split per epoch), so loop and batched runs draw identical
        per-client minibatch permutations in epoch order."""
        bank = self._perm_bank[uid]
        pos = int(self._bank_pos[uid])
        if bank is None or pos >= len(bank):
            c = self.clients[uid]
            n_i = int(self._shard_sizes[uid])
            if n_i:
                # one dispatch per refill; bit-identical to per-epoch
                # jax.random.permutation calls (pinned by the golden tests)
                c._key, perms = _perm_bank(c._key, self._bank_epochs, n_i)
                bank = np.asarray(perms, dtype=np.int64)
            else:
                # zero-shard straggler: advance the key chain anyway
                for _ in range(self._bank_epochs):
                    c._key, _ = jax.random.split(c._key)
                bank = np.zeros((self._bank_epochs, 0), np.int64)
            self._perm_bank[uid] = bank
            pos = 0
        self._bank_pos[uid] = pos + 1
        return bank[pos]

    @staticmethod
    def _bucket(k: int) -> int:
        """Smallest power of four >= k: lane-count buckets keep the jit
        shape count at O(log_4 cohort_pad) per run while wasting at most
        ~4x the smallest cohort's (masked-out) compute."""
        c = 1
        while c < k:
            c <<= 2
        return c

    def _cohort_chunks(self, uids):
        """Yield ``(params, shared, idx, mask, valid, k)`` chunks for a
        finisher cohort: at most ``cohort_pad`` lanes per chunk, lane
        count padded to a power of four, scan depth fixed at the
        fleet-wide max steps-per-epoch — so the fused programs compile a
        handful of stable shapes per run, not one per ragged cohort.
        ``shared=True`` means all lanes pulled one snapshot and ``params``
        is that single tree (broadcast in-device); otherwise ``params``
        is a host-stacked ``(C, ...)`` tree. Per-event host work is plain
        numpy (permutation banks, index arithmetic)."""
        B, S = self.batch_size, self._s_max
        uids = np.asarray(uids)
        for c0 in range(0, len(uids), self.cohort_pad):
            chunk = uids[c0:c0 + self.cohort_pad]
            k = len(chunk)
            C = self._bucket(k)
            idx = np.zeros((C, S, B), np.int64)
            mask = np.zeros((C, S), bool)
            valid = np.zeros(C, bool)
            valid[:k] = True
            for j, uid in enumerate(chunk):
                uid = int(uid)
                steps = int(self._steps[uid])
                perm = self._next_perm(uid)      # consume even if 0 steps
                if steps:
                    idx[j, :steps] = (self._offsets[uid]
                                      + perm[:steps * B]).reshape(steps, B)
                    mask[j, :steps] = True
            lanes = [self._inflight[int(u)] for u in chunk]
            first = lanes[0]
            if all(l is first for l in lanes):
                yield first, True, idx, mask, valid, k
            else:
                lanes.extend([first] * (C - k))  # padding lanes
                yield tuple(lanes), False, idx, mask, valid, k

    def pull_batch(self, uids, version):
        for uid in np.asarray(uids):
            params, _ = self.server.pull(int(uid))
            self._inflight[int(uid)] = params

    def local_train_batch(self, uids, versions=None):
        uids = np.asarray(uids)
        if len(uids) == 0:
            return None
        parts = []
        for params, shared, idx, mask, valid, k in self._cohort_chunks(uids):
            out = _train_chunk(params, idx, mask,
                               self._flat_x, self._flat_y,
                               self.eta, self.beta, shared,
                               self.model_loss)
            parts.append(jax.tree.map(lambda a: a[:k], out))
        if len(parts) == 1:
            return parts[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)

    def finish_async_batch(self, uids, versions, lags, eta, beta,
                           need_gaps=True):
        """Fused finish: each chunk is ONE device dispatch covering the
        vmap'd cohort epoch and the ordered weighted sequential pushes
        (the aggregation rule's ``scan_weight`` runs IN the scan — no
        per-push host round-trips for any registered rule); the host
        only updates server bookkeeping and never blocks — with
        ``need_gaps=False`` the whole finish is async dispatch (the
        momentum norm stays a lazy device scalar). Custom numpy-only
        rules (no traced hook) take the generic local_train_batch +
        push_batch path."""
        rule = self.server.rule
        if not aggregation_support(rule)["jax"] or \
                (type(rule).init_carry is not AggregationRule.init_carry
                 and self._agg_carry is None):
            # no traced weight hook (or a carry-needing rule without a
            # bound fleet): per-push server calls
            return super().finish_async_batch(uids, versions, lags,
                                              eta, beta, need_gaps)
        uids = np.asarray(uids)
        lags = np.asarray(lags)
        agg_ops = tuple(jnp.asarray(x)
                        for x in rule.scan_operands(self._sim_cfg))
        vnorms, weights = [], []
        p, v = self.server.params, self.server._v
        vn_out = None
        pos = 0
        for params, shared, idx, mask, valid, k in self._cohort_chunks(uids):
            C = len(valid)
            lag_c = np.zeros(C, np.int64)
            lag_c[:k] = lags[pos:pos + k]
            uid_c = np.zeros(C, np.int64)
            uid_c[:k] = uids[pos:pos + k]
            pos += k
            fn = _finish_chunk_fn(rule, self.eta, self.beta, shared,
                                  need_gaps, self.model_loss, self.kernel)
            p, v, vn, ws, vn_out = fn(
                params, idx, mask, valid, jnp.asarray(lag_c),
                jnp.asarray(uid_c), self._agg_carry, agg_ops, p, v,
                self._flat_x, self._flat_y)
            if need_gaps:
                vnorms.append(np.asarray(vn[:k], dtype=np.float64))
                weights.append(np.asarray(ws[:k], dtype=np.float64))
        self.server.params = p
        self.server._v = v
        # lazy: a 0-d device scalar; v_norm() converts on demand so
        # policies that never read it (immediate/sync) never block on it
        self.server.v_norm = vn_out
        for uid in uids:
            self.server.lag_tracker.on_push(int(uid))
            self.server.in_flight.discard(int(uid))
        if not need_gaps:
            return None, None
        # Eq. (4) gaps against the pre-push momentum norms (loop ordering)
        return (np.asarray(gradient_gap(np.concatenate(vnorms), lags,
                                        eta, beta), dtype=float),
                np.concatenate(weights))

    def push_batch(self, uids, trained, lags, eta, beta):
        gaps = np.empty(len(uids))
        weights = np.empty(len(uids))
        for j, uid in enumerate(np.asarray(uids)):
            uid = int(uid)
            # loop-oracle order: the gap uses the momentum norm *before*
            # this push (but after every earlier finisher's in this slot)
            gaps[j] = gradient_gap(self.v_norm(), int(lags[j]), eta, beta)
            res = self.server.push(uid,
                                   jax.tree.map(lambda a: a[j], trained))
            weights[j] = res.applied_weight
        return gaps, weights

    def submit_batch(self, uids, trained, lags, eta, beta):
        gaps = np.empty(len(uids))
        for j, uid in enumerate(np.asarray(uids)):
            uid = int(uid)
            gaps[j] = gradient_gap(self.v_norm(), int(lags[j]), eta, beta)
            self.server.submit(jax.tree.map(lambda a: a[j], trained))
        return gaps, np.ones(len(uids))

    def sync_aggregate(self):
        self.server.aggregate()

    def v_norm(self) -> float:
        # float() realizes the lazy device scalar the fused finish leaves
        # behind; a plain float (eager loop pushes) passes through
        return 0.0 if self.sync else float(self.server.v_norm)

    def evaluate(self) -> float:
        return float(self._acc(self.server.params))


@register_ml_backend
class LeNetBackend(ImageClassifierBackend):
    """The paper's workload: LeNet-5 (Sec. VI, ~62k params) on cifarlike
    shards. Construction order and rng stream are pinned by the loop
    oracle's golden (tests/data/real_mode_golden.json) — the model
    functions are the only thing this subclass adds.

    noise=8.0 calibrates cifarlike difficulty so LeNet accuracy climbs
    gradually over many local epochs (CIFAR-10-like convergence dynamics)
    rather than saturating after one epoch.
    """

    name = "lenet"
    model_init = staticmethod(init_lenet)
    model_loss = staticmethod(lenet_loss)
    model_logits = staticmethod(lenet_logits)


@register_ml_backend
class MLPBackend(ImageClassifierBackend):
    """Second real model (``Scenario(ml="mlp")``): a dense MLP
    (models/mlp.py) with a different pytree structure than LeNet (no conv
    leaves) through the identical fused train+push scan — the proof that
    the batched protocol and the Pallas apply path are not LeNet-shaped.
    Pinned by its own golden (tests/data/mlp_golden.json)."""

    name = "mlp"
    model_init = staticmethod(init_mlp)
    model_loss = staticmethod(mlp_loss)
    model_logits = staticmethod(mlp_logits)


def make_ml_hooks(n_users: int, *, sync: bool = False, eta: float = 0.01,
                  beta: float = 0.9, n_train: int = 10000,
                  n_test: int = 2000, alpha: float = 100.0,
                  batch_size: int = 20, aggregation: str = "replace",
                  noise: float = 8.0, seed: int = 0):
    """Returns (hooks dict, state dict with server/clients/eval/backend).

    Historical loop-engine entry point, now an adapter over
    ``LeNetBackend`` (same construction order, same rng stream, same
    jitted per-client epoch — seeded loop runs reproduce bit-for-bit)."""
    backend = LeNetBackend(n_users, sync=sync, eta=eta, beta=beta,
                           n_train=n_train, n_test=n_test, alpha=alpha,
                           batch_size=batch_size, aggregation=aggregation,
                           noise=noise, seed=seed)
    return backend.hooks(), {"server": backend.server,
                             "clients": backend.clients,
                             "accuracy": backend._acc,
                             "backend": backend}
