"""Real-ML coupling for the simulator (Fig. 5): LeNet-5 on cifarlike data,
25 clients, momentum SGD (Eq. 1), async parameter server vs FedAvg.

``make_ml_hooks`` returns the hook dict ``FederatedSim(ml_mode="real")``
consumes, so the slot-level schedule (energy decisions) drives actual JAX
training and the reported accuracy/wall-clock curves are real.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.client import Client
from repro.core.server import AsyncParameterServer, SyncServer
from repro.data.synthetic import cifarlike_dataset, dirichlet_partition
from repro.models.lenet import init_lenet, lenet_logits, lenet_loss


def make_ml_hooks(n_users: int, *, sync: bool = False, eta: float = 0.01,
                  beta: float = 0.9, n_train: int = 10000,
                  n_test: int = 2000, alpha: float = 100.0,
                  batch_size: int = 20, aggregation: str = "replace",
                  noise: float = 8.0, seed: int = 0):
    """Returns (hooks dict, state dict with server/clients/eval).

    noise=8.0 calibrates cifarlike difficulty so LeNet accuracy climbs
    gradually over many local epochs (CIFAR-10-like convergence dynamics)
    rather than saturating after one epoch."""
    images, labels = cifarlike_dataset(n_train, seed=seed, noise=noise)
    test_x, test_y = cifarlike_dataset(n_test, seed=seed + 1, noise=noise)
    shards = dirichlet_partition(labels, n_users, alpha=alpha, seed=seed)
    clients = [Client(i, jnp.asarray(images[s]), jnp.asarray(labels[s]),
                      lenet_loss, batch_size=batch_size, eta=eta, beta=beta)
               for i, s in enumerate(shards)]
    params0 = init_lenet(jax.random.PRNGKey(seed))
    server: object
    if sync:
        server = SyncServer(params0)
    else:
        server = AsyncParameterServer(params0, eta=eta, beta=beta,
                                      aggregation=aggregation)

    test_x_j = jnp.asarray(test_x)
    test_y_j = jnp.asarray(test_y)

    @jax.jit
    def _acc(params):
        logits = lenet_logits(params, test_x_j)
        return jnp.mean((jnp.argmax(logits, -1) == test_y_j)
                        .astype(jnp.float32))

    hooks = {
        "pull": lambda uid: server.pull(uid)[0],
        "local_train": lambda uid, params: clients[uid].local_train(params)[0],
        "evaluate": lambda: float(_acc(server.params)),
        "v_norm": (lambda: server.v_norm) if not sync else (lambda: 0.0),
        "eval_every": 600,
    }
    if sync:
        hooks["sync_submit"] = server.submit
        hooks["sync_aggregate"] = server.aggregate
    else:
        hooks["push"] = lambda uid, params: server.push(uid, params)
    return hooks, {"server": server, "clients": clients, "accuracy": _acc}
