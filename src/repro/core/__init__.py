"""The paper's primary contribution: energy-aware scheduling of asynchronous
federated training (energy model, staleness metrics, offline knapsack,
online Lyapunov scheduler, async parameter server, slotted-time simulator)."""
from .energy import APPS, DEVICE_NAMES, TESTBED, DeviceProfile, table2_savings
from .lyapunov import OnlineScheduler, UserSlotState, schedule_threshold
from .offline import knapsack_schedule, lemma1_lag_bounds, offline_schedule
from .server import AsyncParameterServer, SyncServer
from .simulator import FederatedSim, SimConfig, SimResult
from .staleness import (LagTracker, gradient_gap, momentum_scale,
                        predict_weights, tree_l2_norm, true_gap)

__all__ = [
    "APPS", "DEVICE_NAMES", "TESTBED", "DeviceProfile", "table2_savings",
    "OnlineScheduler", "UserSlotState", "schedule_threshold",
    "knapsack_schedule", "lemma1_lag_bounds", "offline_schedule",
    "AsyncParameterServer", "SyncServer",
    "FederatedSim", "SimConfig", "SimResult",
    "LagTracker", "gradient_gap", "momentum_scale", "predict_weights",
    "tree_l2_norm", "true_gap",
]
