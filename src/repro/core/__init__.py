"""The paper's primary contribution: energy-aware scheduling of asynchronous
federated training (energy model, staleness metrics, offline knapsack,
online Lyapunov scheduler, async parameter server, slotted-time simulator
with loop / vectorized / jax engines), behind a composable Scenario API
(pluggable policies, arrival processes, and device fleets)."""
from .aggregation import (AggregationRule, FedAsyncPolyRule, GapAwareRule,
                          HeteroAwareRule, ReplaceRule,
                          register_aggregation, registered_aggregations,
                          resolve_aggregation)
from .arrivals import (ArrivalProcess, BernoulliArrivals, DiurnalArrivals,
                       MarkovModulatedArrivals, TraceArrivals,
                       register_arrival, registered_arrivals,
                       resolve_arrival)
from .dynamics import (DeviceDynamics, DynEffects, MarkovChurnDynamics,
                       NoDynamics, register_dynamics, registered_dynamics,
                       resolve_dynamics)
from .energy import (APPS, DEVICE_NAMES, TESTBED, AppProfile, DeviceProfile,
                     DeviceTables, build_tables, catalog_tables, device_ids,
                     table2_savings)
from .engine_state import EVENT_FIELDS, EngineState, PushBuffer, PushLog
from .fleet import (CustomCatalogFleet, Fleet, FleetSpec, PaperFleet,
                    SyntheticFleet, register_fleet, registered_fleets,
                    resolve_fleet)
from .lyapunov import (BatchDecision, OnlineScheduler, UserSlotState,
                       schedule_threshold)
from .offline import (knapsack_schedule, lemma1_lag_bounds,
                      lemma1_lag_bounds_loop, offline_schedule)
from .policies import (EpsGreedyPolicy, GreedyThresholdPolicy,
                       ImmediatePolicy, OfflinePolicy, OnlinePolicy, Policy,
                       SyncPolicy, register_policy, registered_policies,
                       resolve_policy)
from .realml import (BatchedMLBackend, LeNetBackend, make_backend,
                     make_ml_hooks, register_ml_backend,
                     registered_ml_backends)
from .scenario import Scenario, run_experiment, run_sweep
from .server import AsyncParameterServer, SyncServer
from .simulator import ENGINES, POLICIES, FederatedSim, SimConfig, SimResult
from .staleness import (LagTracker, gradient_gap, momentum_scale,
                        predict_weights, tree_l2_norm, true_gap)

__all__ = [
    "AggregationRule", "FedAsyncPolyRule", "GapAwareRule",
    "HeteroAwareRule", "ReplaceRule", "register_aggregation",
    "registered_aggregations", "resolve_aggregation",
    "APPS", "DEVICE_NAMES", "TESTBED", "AppProfile", "DeviceProfile",
    "DeviceTables", "build_tables", "catalog_tables", "device_ids",
    "table2_savings",
    "EVENT_FIELDS", "EngineState", "PushBuffer", "PushLog",
    "ArrivalProcess", "BernoulliArrivals", "DiurnalArrivals",
    "MarkovModulatedArrivals", "TraceArrivals",
    "register_arrival", "registered_arrivals", "resolve_arrival",
    "DeviceDynamics", "DynEffects", "MarkovChurnDynamics", "NoDynamics",
    "register_dynamics", "registered_dynamics", "resolve_dynamics",
    "CustomCatalogFleet", "Fleet", "FleetSpec", "PaperFleet",
    "SyntheticFleet", "register_fleet", "registered_fleets", "resolve_fleet",
    "BatchDecision", "OnlineScheduler", "UserSlotState",
    "schedule_threshold",
    "knapsack_schedule", "lemma1_lag_bounds", "lemma1_lag_bounds_loop",
    "offline_schedule",
    "EpsGreedyPolicy", "GreedyThresholdPolicy", "ImmediatePolicy",
    "OfflinePolicy", "OnlinePolicy", "Policy", "SyncPolicy",
    "register_policy", "registered_policies", "resolve_policy",
    "BatchedMLBackend", "LeNetBackend", "make_backend", "make_ml_hooks",
    "register_ml_backend", "registered_ml_backends",
    "Scenario", "run_experiment", "run_sweep",
    "AsyncParameterServer", "SyncServer",
    "ENGINES", "POLICIES", "FederatedSim", "SimConfig", "SimResult",
    "LagTracker", "gradient_gap", "momentum_scale", "predict_weights",
    "tree_l2_norm", "true_gap",
]
