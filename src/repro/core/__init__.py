"""The paper's primary contribution: energy-aware scheduling of asynchronous
federated training (energy model, staleness metrics, offline knapsack,
online Lyapunov scheduler, async parameter server, slotted-time simulator
with loop / vectorized / jax engines)."""
from .energy import (APPS, DEVICE_NAMES, TESTBED, DeviceProfile,
                     DeviceTables, catalog_tables, device_ids,
                     table2_savings)
from .lyapunov import (BatchDecision, OnlineScheduler, UserSlotState,
                       schedule_threshold)
from .offline import (knapsack_schedule, lemma1_lag_bounds,
                      lemma1_lag_bounds_loop, offline_schedule)
from .server import AsyncParameterServer, SyncServer
from .simulator import ENGINES, POLICIES, FederatedSim, SimConfig, SimResult
from .staleness import (LagTracker, gradient_gap, momentum_scale,
                        predict_weights, tree_l2_norm, true_gap)

__all__ = [
    "APPS", "DEVICE_NAMES", "TESTBED", "DeviceProfile", "DeviceTables",
    "catalog_tables", "device_ids", "table2_savings",
    "BatchDecision", "OnlineScheduler", "UserSlotState",
    "schedule_threshold",
    "knapsack_schedule", "lemma1_lag_bounds", "lemma1_lag_bounds_loop",
    "offline_schedule",
    "AsyncParameterServer", "SyncServer",
    "ENGINES", "POLICIES", "FederatedSim", "SimConfig", "SimResult",
    "LagTracker", "gradient_gap", "momentum_scale", "predict_weights",
    "tree_l2_norm", "true_gap",
]
