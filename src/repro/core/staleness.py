"""Staleness metrics: lag (Def. 1) and gradient gap (Def. 2, Eqs. 2-4).

The gradient gap between the model a client pulled at t and the global model
at push time t+tau is estimated with Linear Weight Prediction (Eq. 3):

    theta_{t+tau} = theta_t - eta * (1 - beta^l) / (1 - beta) * v_t
    g(t, t+tau)   = || eta * (1 - beta^l) / (1 - beta) * v_t ||_2      (Eq. 4)

Only the *norm* of the momentum vector and the lag l are needed, which is
what makes the paper's distributed implementation O(1) per client: the server
ships two scalars, never the momentum tree.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def momentum_scale(lag, eta: float, beta: float):
    """eta * (1 - beta^lag) / (1 - beta) — the LWP multiplier in Eq. (4).

    ``lag`` may be a scalar (Python number or jax tracer — the scalar path
    stays in operator-land so Eq. (3)/(4) remain jit-traceable) or an
    ndarray (float64 array out). np.power and Python ``**`` both resolve
    to the C library pow for float64, so the loop (scalar) and vectorized
    (array) simulator engines see bit-identical gap values — pinned by
    tests/test_sim_engines.py.
    """
    if isinstance(lag, np.ndarray):
        if beta == 0.0:
            return np.where(lag > 0, float(eta), 0.0)
        return eta * (1.0 - np.power(beta, lag)) / (1.0 - beta)
    if isinstance(lag, (int, float, np.integer, np.floating)):
        # concrete scalar: same np.power ufunc as the array path — Python's
        # ** and np.power disagree by an ulp for some (beta, lag >= 1024)
        if beta == 0.0:
            return float(eta) if lag > 0 else 0.0
        return float(eta * (1.0 - np.power(beta, lag)) / (1.0 - beta))
    # duck-typed scalar (jax tracer): operator-land only, stays traceable
    if beta == 0.0:
        return eta if lag > 0 else 0.0
    return eta * (1.0 - beta ** lag) / (1.0 - beta)


def gradient_gap(v_norm, lag, eta: float, beta: float):
    """Eq. (4): predicted parameter-space L2 distance over `lag` updates.

    Accepts scalar or array ``lag`` / ``v_norm`` (broadcast elementwise)."""
    return momentum_scale(lag, eta, beta) * v_norm


def tree_l2_norm(tree: Any) -> float:
    """||v||_2 over a parameter pytree (f32 accumulation)."""
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return float(jnp.sqrt(sq))


def predict_weights(theta: Any, v: Any, lag: float, eta: float, beta: float):
    """Eq. (3): linear weight prediction of the future global parameters."""
    s = momentum_scale(lag, eta, beta)
    return jax.tree.map(lambda t, m: t - s * m, theta, v)


def true_gap(theta_t: Any, theta_tau: Any) -> float:
    """Eq. (2): exact norm difference (used to validate the LWP estimate)."""
    sq = sum(jnp.sum(jnp.square((a - b).astype(jnp.float32)))
             for a, b in zip(jax.tree.leaves(theta_t), jax.tree.leaves(theta_tau)))
    return float(jnp.sqrt(sq))


class LagTracker:
    """Server-side version counter implementing Def. 1.

    lag(client) = number of global updates applied between the client's pull
    and its push."""

    def __init__(self):
        self.version = 0
        self._pull_version: dict[Any, int] = {}

    def on_pull(self, client_id) -> int:
        self._pull_version[client_id] = self.version
        return self.version

    def lag(self, client_id) -> int:
        return self.version - self._pull_version.get(client_id, self.version)

    def on_push(self, client_id) -> int:
        l = self.lag(client_id)
        self.version += 1
        return l

    def estimate_lag_during(self, in_flight: int) -> int:
        """Server-supplied lag estimate for Alg. 2 line 4: the number of
        currently-running tasks expected to land within the client's window."""
        return in_flight
