"""Composable experiment scenarios: policy x arrivals x fleet x config.

The experiment-facing entrypoint over the simulator. A ``Scenario`` names
one composition of the three pluggable axes (core/policies.py,
core/arrivals.py, core/fleet.py) plus the ``SimConfig`` knobs, and
``run_experiment`` executes it on whatever engine ``SimConfig.engine``
resolves to::

    from repro.core import Scenario, run_experiment

    # the paper's evaluation, verbatim (defaults = Sec. VII.B setup)
    r = run_experiment(Scenario(policy="online", n_users=25,
                                horizon_s=10800))

    # a non-paper composition: bursty arrivals on a 64-type synthetic
    # fleet under the greedy energy-threshold baseline
    from repro.core import MarkovModulatedArrivals, SyntheticFleet
    r = run_experiment(Scenario(policy="greedy",
                                arrivals=MarkovModulatedArrivals(),
                                fleet=SyntheticFleet(n_types=64),
                                n_users=400, horizon_s=3600))

    # REAL training coupled to the schedule (Fig. 5): ml="lenet" builds a
    # batched LeNet backend per run; the vectorized engine trains whole
    # finisher cohorts with one vmap'd epoch
    r = run_experiment(Scenario(policy="online", ml="lenet",
                                n_users=64, horizon_s=2400,
                                app_arrival_p=0.004))

    # per-user arrival-rate heterogeneity: app_arrival_p accepts an
    # (n_users,) vector (propagated to the default Bernoulli process)
    import numpy as np
    rates = np.linspace(0.0005, 0.02, 50)
    r = run_experiment(Scenario(policy="online", n_users=50,
                                app_arrival_p=rates, horizon_s=3600))

Strings resolve through the registries; objects pass through as-is.
``run_experiment(policy="online", n_users=25)`` builds the Scenario
inline for one-liners.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from .arrivals import ArrivalProcess, resolve_arrival_or_default
from .fleet import Fleet, resolve_fleet
from .policies import Policy, resolve_policy
from .realml import BatchedMLBackend, make_backend
from .simulator import FederatedSim, SimConfig, SimResult


class Scenario:
    """One composed experiment: resolved policy/arrivals/fleet + SimConfig.

    ``policy`` is a registry name or ``Policy`` instance; ``arrivals`` /
    ``fleet`` likewise (``None`` keeps the paper defaults: Bernoulli at
    ``app_arrival_p`` on the Table II round-robin fleet). ``ml`` couples
    the schedule to real training: a ``core.realml`` backend name (e.g.
    ``"lenet"``) or ``BatchedMLBackend`` instance — setting it forces
    ``ml_mode="real"`` and ``build()`` constructs a fresh backend per run
    (seeded from ``SimConfig.seed``, round mode matched to the policy's
    ``sync_rounds``, training eta/beta defaulting to the config's);
    ``ml_kwargs`` are extra backend constructor arguments (n_train,
    batch_size, ...). Remaining keyword arguments are ``SimConfig``
    fields; alternatively pass a prebuilt ``config=`` (its ``policy``
    field is overridden by ``policy=`` only if one is given explicitly).
    """

    def __init__(self, policy: Union[str, Policy, None] = None,
                 arrivals: Union[str, ArrivalProcess, None] = None,
                 fleet: Union[str, Fleet, None] = None,
                 name: Optional[str] = None,
                 config: Optional[SimConfig] = None,
                 ml: Union[str, BatchedMLBackend, None] = None,
                 ml_kwargs: Optional[dict] = None,
                 **sim_kwargs):
        if config is not None:
            if sim_kwargs:
                raise ValueError(
                    f"pass either config= or SimConfig kwargs, not both "
                    f"(got {sorted(sim_kwargs)})")
            if policy is not None and policy is not config.policy:
                config = dataclasses.replace(config, policy=policy)
            self.config = config
        else:
            self.config = SimConfig(
                policy="online" if policy is None else policy, **sim_kwargs)
        if ml is not None and self.config.ml_mode != "real":
            # requesting an ML backend IS requesting real mode
            self.config = dataclasses.replace(self.config, ml_mode="real")
        if ml is None and ml_kwargs:
            raise ValueError("ml_kwargs without ml= has no effect; "
                             "pass ml='lenet' (or a backend instance)")
        self.ml = ml
        self.ml_kwargs = dict(ml_kwargs or {})
        # raw arrivals argument, kept so grid() re-resolves it against
        # each point's config (a swept app_arrival_p rebinds the default
        # Bernoulli process; an explicit instance keeps its own rates)
        self._arrivals_arg = arrivals
        self.policy = resolve_policy(self.config.policy)
        # one resolution rule shared with FederatedSim: None/"bernoulli"
        # mean the paper process at the configured app_arrival_p
        self.arrivals = resolve_arrival_or_default(
            arrivals, self.config.app_arrival_p)
        self.fleet = None if fleet is None else resolve_fleet(fleet)
        self.name = name if name is not None else self.policy.name

    def build(self, ml_hooks: Optional[dict] = None,
              ml_backend: Optional[BatchedMLBackend] = None) -> FederatedSim:
        """Construct the (seeded) simulator without running it."""
        backend = ml_backend
        if backend is None and self.ml is not None:
            if ml_hooks is not None:
                raise ValueError(
                    "Scenario has ml= set; pass ml_hooks only to scenarios "
                    "without a backend")
            from .aggregation import resolve_aggregation
            kw = dict(self.ml_kwargs)
            kw.setdefault("eta", self.config.eta)
            kw.setdefault("beta", self.config.beta)
            kw.setdefault("seed", self.config.seed)
            # the backend's server applies the pushes, so it gets the
            # config's aggregation rule (core/aggregation.py) — but only
            # when a non-default rule was requested: custom registered
            # backends predating the kwarg must keep building under the
            # default replace rule
            if resolve_aggregation(self.config.aggregation).name \
                    != "replace":
                kw.setdefault("aggregation", self.config.aggregation)
            # same guard for the apply-kernel knob: the default "auto"
            # is the backend's own default, so only explicit requests
            # are threaded (and custom backends without the kwarg keep
            # building)
            if self.config.kernel != "auto":
                kw.setdefault("kernel", self.config.kernel)
            backend = make_backend(self.ml, self.config.n_users,
                                   sync=self.policy.sync_rounds, **kw)
        return FederatedSim(self.config, ml_hooks=ml_hooks,
                            ml_backend=backend,
                            arrivals=self.arrivals, fleet=self.fleet)

    def run(self, ml_hooks: Optional[dict] = None,
            ml_backend: Optional[BatchedMLBackend] = None) -> SimResult:
        return self.build(ml_hooks=ml_hooks, ml_backend=ml_backend).run()

    def grid(self, **axes) -> "list[Scenario]":
        """Cartesian product of ``SimConfig`` overrides as a scenario
        list, e.g. ``base.grid(V=[1e2, 1e3, 1e4], L_b=[5.0, 10.0])`` —
        six scenarios, the last-named axis varying fastest. Each point
        keeps this scenario's arrivals/fleet/ml composition; a swept
        ``app_arrival_p`` rebinds the default Bernoulli process per
        point (an explicit arrivals instance keeps its own rates). Feed
        the list to :func:`run_sweep` — points sharing static shapes run
        batched under one compiled program."""
        import itertools
        names = list(axes)
        vals = [list(axes[k]) for k in names]
        out = []
        for combo in itertools.product(*vals):
            cfg = dataclasses.replace(self.config, **dict(zip(names, combo)))
            out.append(Scenario(config=cfg, arrivals=self._arrivals_arg,
                                fleet=self.fleet, name=self.name,
                                ml=self.ml,
                                ml_kwargs=self.ml_kwargs or None))
        return out

    def sweep(self, **axes) -> "list[SimResult]":
        """``run_sweep(self.grid(**axes))`` — run the knob grid, batched
        wherever points share one executable. Results align with
        ``grid(**axes)`` order."""
        return run_sweep(self.grid(**axes))

    def __repr__(self):
        arr = self.arrivals.name
        flt = self.fleet.name if self.fleet is not None else "paper"
        ml = "" if self.ml is None else \
            f", ml={getattr(self.ml, 'name', self.ml)!r}"
        return (f"Scenario({self.name!r}: policy={self.policy.name!r}, "
                f"arrivals={arr!r}, fleet={flt!r}, "
                f"n_users={self.config.n_users}, "
                f"horizon_s={self.config.horizon_s}, "
                f"engine={self.config.engine!r}{ml})")


def run_sweep(scenarios) -> "list[SimResult]":
    """Run many ``Scenario``s, batching compatible ones under ONE
    compiled program (the jax engine's vmapped sweep path).

    Scenarios are bucketed by static shape — ``(n_users, horizon,
    jax_chunk, policy/aggregation/dynamics cache keys, scan_statics,
    push-log capacity)`` — so mixed grids work: each bucket of two or
    more compatible points runs as one ``jax.vmap``-ped chunked scan
    over stacked configs; singletons and jax/vmap-ineligible scenarios
    (real-ML runs, the offline policy's host-callback planning, explicit
    ``engine="loop"`` requests, custom components without jax support)
    fall back to their own ``Scenario.run()``. Results come back in
    input order, each identical to its per-point run (bit-for-bit on
    discrete outputs; energies to float-sum reordering).

    Everything per-config — V, L_b, policy ``scan_operands``, arrival
    draws, seeds — is traced, so a 100-point V-grid compiles once and a
    repeat sweep compiles nothing."""
    from .vector_engine import (reserve_jax_cache_capacity, run_jax_sweep,
                                sweep_bucket_key)
    scenarios = list(scenarios)
    sims = []
    for sc in scenarios:
        if not isinstance(sc, Scenario):
            raise TypeError(
                f"run_sweep takes Scenarios, got {type(sc).__name__}; "
                "build one with Scenario(...) or Scenario.grid(...)")
        sims.append(sc.build())
    buckets: dict = {}
    for idx, sim in enumerate(sims):
        buckets.setdefault(sweep_bucket_key(sim), []).append(idx)
    batched = [idxs for key, idxs in buckets.items()
               if key is not None and len(idxs) >= 2]
    if batched:
        # keep every bucket resident for the sweep's lifetime (2 entries
        # per bucket covers one push-buffer doubling retry)
        reserve_jax_cache_capacity(2 * len(batched) + 8)
    results: "list[Optional[SimResult]]" = [None] * len(sims)
    for key, idxs in buckets.items():
        if key is not None and len(idxs) >= 2:
            for i, res in zip(idxs, run_jax_sweep([sims[i] for i in idxs])):
                results[i] = res
        else:
            for i in idxs:
                results[i] = sims[i].run()
    return results


def run_experiment(scenario: Optional[Scenario] = None, *,
                   ml_hooks: Optional[dict] = None,
                   ml_backend: Optional[BatchedMLBackend] = None,
                   **kwargs) -> SimResult:
    """Run a ``Scenario`` (or build one inline from kwargs) end to end."""
    if scenario is None:
        scenario = Scenario(**kwargs)
    elif kwargs:
        raise TypeError(
            f"pass either a Scenario or Scenario kwargs, not both "
            f"(got {sorted(kwargs)})")
    return scenario.run(ml_hooks=ml_hooks, ml_backend=ml_backend)
