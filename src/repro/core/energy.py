"""Device energy model — Table II / Table III of the paper, verbatim.

Power states per device i (Eq. 10):  P^{a'} (co-run) > P^a (app only)
> P^b (training only, background) > P^d (idle).

Energy-saving of co-running (Sec. IV):   s_i = P^b + P^a - P^{a'}
Percentage saving (Sec. VII.A):          1 - P^{a'} t_a / (P^b t_b + P^a t_a)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import numpy as np

APPS = ["Map", "News", "Etrade", "Youtube", "Tiktok", "Zoom", "CandyCru", "Angrybird"]


@dataclasses.dataclass(frozen=True)
class AppProfile:
    p_app: float      # P^a  (W) app running alone
    p_corun: float    # P^{a'} (W) training co-running with the app
    t_corun: float    # (s) training execution time while co-running


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    p_train: float            # P^b (W) training alone in background
    t_train: float            # (s) standalone training duration (one local epoch)
    p_idle: float             # P^d (W)
    p_sched: float            # (W) while evaluating the online decision (Table III)
    apps: Dict[str, AppProfile]

    def energy_saving_rate(self, app: str) -> float:
        """s_i = P^b + P^a - P^{a'} (W): per-second saving of co-running."""
        a = self.apps[app]
        return self.p_train + a.p_app - a.p_corun

    def saving_percent(self, app: str) -> float:
        a = self.apps[app]
        separate = self.p_train * self.t_train + a.p_app * a.t_corun
        return 1.0 - (a.p_corun * a.t_corun) / separate

    def duration(self, corun: bool, app: str | None = None) -> float:
        return self.apps[app].t_corun if corun and app else self.t_train

    def power(self, training: bool, app_running: bool, app: str | None = None) -> float:
        """Eq. (10): power as a function of (control decision, app status)."""
        if training and app_running:
            return self.apps[app].p_corun        # P^{a'}
        if training:
            return self.p_train                  # P^b
        if app_running:
            return self.apps[app].p_app          # P^a
        return self.p_idle                       # P^d


def _dev(name, p_train, t_train, p_idle, p_sched, rows):
    apps = {app: AppProfile(*row) for app, row in zip(APPS, rows)}
    return DeviceProfile(name, p_train, t_train, p_idle, p_sched, apps)


# Table II (measured W / s) + Table III idle & scheduler powers.
# Hikey970 idle power is not in Table II/III (the paper's Table III covers
# only the Snapdragon phones); we calibrate 0.6 W — headless dev-board idle,
# consistent with the phone idle range and with the paper's Fig. 4a absolute
# energy scale (documented deviation, DESIGN.md §2).
TESTBED: Dict[str, DeviceProfile] = {
    "Nexus6": _dev("Nexus6", 1.8, 204, 0.238, 0.245, [
        (3.4, 3.5, 274), (1.7, 2.2, 239), (1.4, 2.4, 236), (0.5, 1.9, 284),
        (1.6, 2.3, 296), (1.2, 2.1, 370), (1.3, 2.3, 997), (2.5, 2.8, 400)]),
    "Nexus6P": _dev("Nexus6P", 0.9, 211, 0.486, 0.525, [
        (0.5, 1.3, 225), (0.44, 1.2, 362), (0.48, 0.96, 228), (0.53, 1.2, 220),
        (1.0, 1.1, 675), (1.4, 1.6, 340), (0.7, 1.3, 280), (1.1, 1.2, 620)]),
    "Hikey970": _dev("Hikey970", 7.87, 213, 0.6, 0.65, [
        (8.82, 9.42, 186), (9.17, 9.76, 210), (8.50, 9.15, 195), (9.15, 11.45, 210),
        (11.0, 11.2, 271), (7.89, 8.53, 209), (11.1, 11.26, 233), (10.1, 10.7, 200)]),
    "Pixel2": _dev("Pixel2", 1.35, 223, 0.689, 0.736, [
        (1.60, 2.20, 196), (1.82, 2.40, 197), (1.72, 2.23, 206), (2.04, 2.21, 226),
        (2.37, 2.52, 212), (2.57, 3.11, 206), (2.89, 2.92, 199), (2.86, 2.88, 285)]),
}

DEVICE_NAMES = list(TESTBED)


def table2_savings() -> Dict[str, Dict[str, float]]:
    """Reproduce the saving(%) column of Table II for every (device, app)."""
    return {d: {a: TESTBED[d].saving_percent(a) for a in APPS} for d in TESTBED}


# ---------------------------------------------------------------------------
# Struct-of-arrays view of the catalog, for the vectorized simulator engine.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DeviceTables:
    """Table II/III flattened into dense lookup tables.

    Per-device vectors have shape ``(n_devices,)``; per-(device, app)
    tables have shape ``(n_devices, n_apps)`` with the app axis ordered as
    ``APPS``. ``saving_rate[d, a]`` is Sec. IV's s_i = P^b + P^a - P^{a'}.
    """
    names: Tuple[str, ...]
    p_train: np.ndarray
    t_train: np.ndarray
    p_idle: np.ndarray
    p_sched: np.ndarray
    p_app: np.ndarray
    p_corun: np.ndarray
    t_corun: np.ndarray
    saving_rate: np.ndarray


def build_tables(devices) -> DeviceTables:
    """Flatten any device catalog into ``DeviceTables`` (frozen arrays).

    ``devices``: sequence of ``DeviceProfile``; each must carry an
    ``AppProfile`` for every entry of ``APPS`` (the app axis is shared
    across fleets). This is what lets custom/synthetic fleets feed the
    vectorized and jax engines without touching the Table II catalog.
    """
    devs = list(devices)
    names = tuple(d.name for d in devs)
    p_train = np.array([d.p_train for d in devs])
    p_app = np.array([[d.apps[a].p_app for a in APPS] for d in devs])
    p_corun = np.array([[d.apps[a].p_corun for a in APPS] for d in devs])
    tables = DeviceTables(
        names=names,
        p_train=p_train,
        t_train=np.array([d.t_train for d in devs]),
        p_idle=np.array([d.p_idle for d in devs]),
        p_sched=np.array([d.p_sched for d in devs]),
        p_app=p_app,
        p_corun=p_corun,
        t_corun=np.array([[d.apps[a].t_corun for a in APPS] for d in devs]),
        # same operation order as DeviceProfile.energy_saving_rate
        saving_rate=(p_train[:, None] + p_app) - p_corun,
    )
    # tables may be shared across runs (catalog_tables hands out one
    # process-wide instance); freeze the arrays so an accidental in-place
    # write can't corrupt every later run
    for f in dataclasses.fields(tables):
        v = getattr(tables, f.name)
        if isinstance(v, np.ndarray):
            v.setflags(write=False)
    return tables


@functools.lru_cache(maxsize=1)
def catalog_tables() -> DeviceTables:
    """The Table II/III testbed as ``DeviceTables`` (cached singleton)."""
    return build_tables(TESTBED.values())


def device_ids(names: Sequence[str]) -> np.ndarray:
    """Map device names onto row indices of ``catalog_tables()``."""
    order = {n: i for i, n in enumerate(catalog_tables().names)}
    return np.array([order[n] for n in names], dtype=np.int64)
