"""Device dynamics: availability, battery, and network churn as per-user
state machines updated in-scan.

The paper's simulator assumes an always-on fleet — every device that pulls
a model finishes and pushes, batteries never gate participation, and the
post-push re-arrival delay is a constant. Real battery-powered fleets
churn constantly: AutoFL (Kim & Wu '21) shows stochastic runtime/energy
variance from co-running apps and network conditions is first-order, and
DEAL (Zou et al. '21) gates participation on battery level. This module
makes that churn a first-class simulation layer, following the
Policy/AggregationRule protocol shape (core/policies.py,
core/aggregation.py): a registry of ``DeviceDynamics`` objects whose
per-user state rides in ``EngineState.dyn`` and whose per-slot transition
runs at the TOP of every slot on all three engines —

``init_state(n, cfg, fleet=None)``
    One pytree of per-user ``(n,)`` arrays (availability chain state,
    battery level, network state, drop counters, plus any run-constant
    per-user parameter gathers — per-device-class values must be gathered
    per user here, like ``hetero_aware``'s scale carry). ``None`` for the
    inactive ``none`` dynamics.
``host_step(dyn, rng_key, mode, corun, t_d)``
    The host (numpy) transition, shared verbatim by the loop oracle and
    the numpy engine — ONE implementation, so loop/vectorized parity
    holds by construction. Randomness comes from the run's
    ``EngineState.rng_key`` via jax's counter-based threefry (drawn
    eagerly here, traced in ``scan_step`` — identical bits, the
    ``eps_greedy`` trick), consumed UNCONDITIONALLY once per slot so the
    key chain advances identically on every engine. Returns
    ``(new_dyn, new_rng_key, DynEffects)``.
``scan_step(dyn, dv)``
    The traced twin inside the jax engine's ``lax.scan`` step. ``dv`` is
    the dynamics slot view (``jnp``/``jax``, ``rng_key`` — read AND
    write back the split key — ``mode``, ``corun``, ``t_d``, ``fp_zero``
    — a traced 0.0 for fma-contraction armor — ``consts``
    from ``scan_operands``). Returns ``(new_dyn, DynEffects)`` with
    jnp-array fields. Instance knobs must flow through
    ``scan_operands`` (traced), never be closed over; compiled scans are
    cached per ``jax_cache_key()``.

The ENGINES apply the effects — the dynamics object only decides who went
up/down. The shared effect semantics every engine implements identically
(pinned by tests/test_dynamics_faults.py):

- a WAITING user that goes down leaves the request queue: ``mode`` becomes
  OFF and the slot's ``departures`` count feeds
  ``OnlineScheduler.update_queues`` (Eq. 15 becomes
  ``Q <- max(Q - served - departures, 0) + arrivals``);
- a TRAINING user that goes down follows the dynamics' ``dropout`` rule:
  ``"lose"`` — the in-flight work is lost (mode OFF, ``train_rem``
  cleared, ``in_flight`` decremented, no push, no version bump);
  ``"resume"`` — the user stays in TRAIN but paused (``train_rem``
  frozen while down) and pays ``resume_penalty`` extra training seconds,
  so the eventual push lands with extra lag;
- a COOLING user that goes down parks in OFF;
- an OFF user that comes back up re-enters the arrival process: mode COOL
  with ``cooldown = ready_delay + net_extra`` (the time-varying network
  state feeding the lag model — a bad-network user re-arrives late, so
  its next pull is staler), then cooldown -> waiting counts as a queue
  arrival exactly like a normal re-arrival;
- down users draw no power (the device is off) and a paused trainer makes
  no training progress; app arrivals stay exogenous (the pre-sampled
  usage trace keeps its meaning and no rng stream shifts).

``none`` (the default) is INACTIVE: no state, no draws, no effect — runs
are bit-identical to the pre-dynamics engines (the goldens pin this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple, Type

import numpy as np

from .engine_state import MODE_TRAIN

__all__ = ["DeviceDynamics", "DynEffects", "NoDynamics",
           "MarkovChurnDynamics", "register_dynamics",
           "registered_dynamics", "resolve_dynamics", "dynamics_support"]

DROPOUT_RULES = ("lose", "resume")


@dataclasses.dataclass
class DynEffects:
    """One slot's transition outcome, host or traced arrays alike.

    ``up`` is the post-transition effective availability (Markov state AND
    battery above threshold) — engines gate energy and training progress
    on it; ``went_down``/``went_up`` the edge masks; ``net_extra`` the
    per-user extra re-arrival delay (slots) of the current network state,
    read both at recovery and at push-finish time; ``resume_penalty`` the
    extra training seconds a dropped-and-resumed user pays (scalar)."""

    up: Any
    went_down: Any
    went_up: Any
    net_extra: Any
    resume_penalty: Any


class DeviceDynamics:
    """Base device-dynamics model. Subclass, set ``name``, implement the
    paths, and decorate with ``@register_dynamics``.

    Class attributes engines dispatch on:

    - ``active``: False means the engines skip the dynamics phase
      entirely (no state, no rng draws — bit-identical to the historical
      engines). Only ``NoDynamics`` should clear it.
    - ``supports_jax``: a traced ``scan_step`` exists. ``SimConfig``
      validates the flag against the actual hook at construction; active
      dynamics without it degrade the jax engine to the numpy path.
    - ``supports_shard``: ``scan_step`` may run with the user axis
      sharded over a device mesh (``SimConfig.n_devices``). The engine's
      slot view then carries ``dv.n`` (LIVE user count), ``dv.n_arr``
      (padded array length) and ``dv.pad_users(x, fill)`` — per-user
      draws must happen at ``dv.n`` and pad with a fill that keeps pad
      lanes inert (threefry draws are shape-dependent; drawing at
      ``n_arr`` would fork the stream from the unsharded engines).

    ``dropout`` is the instance's ``DropoutRule`` — ``"lose"`` or
    ``"resume"`` — a STATIC behavioral branch (engines compile/apply it
    structurally), so it must be part of ``jax_cache_key()``.
    """

    name: str = ""
    active: bool = True
    supports_jax: bool = True
    supports_shard: bool = True
    dropout: str = "lose"

    # ------------------------------------------------------------- state
    def init_state(self, n: int, cfg=None, fleet=None):
        """Per-run per-user state as ONE pytree of ``(n,)``-leading
        arrays (``EngineState.dyn``); ``None`` for inactive dynamics.
        Per-device-class parameters must be gathered per user HERE (the
        scan reads only this carry plus ``scan_operands`` scalars)."""
        return None

    def scan_operands(self, cfg) -> tuple:
        """Scalar instance knobs the traced hook needs (traced operands
        — ``dv.consts`` — so knob sweeps share one compiled scan)."""
        return ()

    def pad_state(self, k: int):
        """``(k,)``-leading INERT rows matching ``init_state``'s pytree
        structure, appended when the sharded scan pads the user axis to a
        multiple of the mesh size (``SimConfig.n_devices`` with a
        non-divisible ``n_users``; core/vector_engine.py). Inert means:
        the rows must keep their users permanently up under the engine's
        fill-1.0 padded draws — no ``went_up``/``went_down`` edges ever,
        so a pad user parked in MODE_OFF stays there. The base returns
        None ("no recipe"), which makes a padded sharded run fail fast
        with instructions; see ``MarkovChurnDynamics.pad_state``."""
        return None

    def jax_cache_key(self):
        """Hashable token identifying this dynamics' ``scan_step`` AND
        effect semantics (the ``dropout`` rule is applied structurally by
        the engines, so it is always part of the key). Class-keyed when
        provably safe — no ad-hoc instance attrs, or knobs routed
        through ``scan_operands`` — else instance-keyed (same contract
        as ``Policy.jax_cache_key``)."""
        if not vars(self) or \
                type(self).scan_operands is not DeviceDynamics.scan_operands:
            return (type(self), self.dropout)
        return self

    # --------------------------------------------------------- host path
    def host_step(self, dyn, rng_key, mode, corun, t_d
                  ) -> Tuple[Any, Any, DynEffects]:
        """One slot's transition on host numpy — shared verbatim by the
        loop oracle and the numpy engine. Must consume the rng
        unconditionally (or not at all) so the key chain is
        engine-invariant."""
        raise NotImplementedError(
            f"dynamics {self.name!r} implements no host_step()")

    # ------------------------------------------------------- traced path
    def scan_step(self, dyn, dv):
        """Traced transition inside the jax scan step; read/write
        ``dv.rng_key``, return ``(dyn, DynEffects)``. Only called when
        ``supports_jax``."""
        raise TypeError(
            f"dynamics {self.name!r} sets supports_jax but inherits the "
            "base scan_step; implement the hook or clear the flag to "
            "degrade to the numpy engines")

    # -------------------------------------------------------- accessors
    def total_drops(self, dyn) -> int:
        """Mid-training drops recorded in ``dyn`` (0 when untracked)."""
        return 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[DeviceDynamics]] = {}
_INSTANCES: Dict[str, DeviceDynamics] = {}      # singletons for strings


def register_dynamics(cls: Type[DeviceDynamics]) -> Type[DeviceDynamics]:
    """Class decorator: make ``cls`` resolvable as ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a registry name")
    _REGISTRY[cls.name] = cls
    _INSTANCES.pop(cls.name, None)              # re-registration wins
    return cls


def registered_dynamics() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_dynamics(dyn) -> DeviceDynamics:
    """String -> registered singleton; DeviceDynamics instance -> itself."""
    if isinstance(dyn, DeviceDynamics):
        return dyn
    if isinstance(dyn, str):
        if dyn not in _REGISTRY:
            raise ValueError(
                f"unknown dynamics {dyn!r}; expected one of "
                f"{registered_dynamics()} or a DeviceDynamics instance")
        if dyn not in _INSTANCES:
            _INSTANCES[dyn] = _REGISTRY[dyn]()
        return _INSTANCES[dyn]
    raise ValueError(f"dynamics must be a name or DeviceDynamics instance, "
                     f"got {type(dyn).__name__}")


def dynamics_support(dyn: DeviceDynamics) -> Dict[str, bool]:
    """Which paths ``dyn`` GENUINELY implements (flag set AND the base
    stub overridden) — the SimConfig-validation twin of
    ``policies.engine_support``. Inactive dynamics support everything
    (there is nothing to run)."""
    cls = type(dyn)
    if not dyn.active:
        return {"host": True, "jax": True}
    return {
        "host": cls.host_step is not DeviceDynamics.host_step,
        "jax": (dyn.supports_jax and
                cls.scan_step is not DeviceDynamics.scan_step),
    }


# ---------------------------------------------------------------------------
# Shipped dynamics
# ---------------------------------------------------------------------------
@register_dynamics
class NoDynamics(DeviceDynamics):
    """The paper's always-on fleet (the default). Inactive: engines skip
    the dynamics phase entirely, so runs are bit-identical to the
    pre-dynamics engines — no per-user state, no rng draws."""

    name = "none"
    active = False


def _dyn_draw(rng_key, n):
    """One slot's dynamics uniforms on the host: split the run key, draw
    ``(2, n)`` f32 — row 0 drives the availability chain, row 1 the
    network chain. threefry is counter-based and jit-invariant, so the
    traced twin inside ``scan_step`` produces the same bits (the
    ``eps_greedy`` trick that makes the three engines decision-
    identical)."""
    import jax
    import jax.numpy as jnp

    k2, sub = jax.random.split(jnp.asarray(rng_key))
    u = jax.random.uniform(sub, (2, n), jnp.float32)
    return np.asarray(k2, dtype=np.uint32), np.asarray(u)


def _per_user(value, n, fleet, what) -> np.ndarray:
    """Broadcast a scalar to ``(n,)`` or gather a per-device-class
    vector (one entry per catalog row of the run's ``FleetSpec``) per
    user — the ``hetero_aware`` carry pattern."""
    v = np.asarray(value, dtype=np.float64)
    if v.ndim == 0:
        return np.full(n, float(v))
    if fleet is None:
        raise ValueError(
            f"per-device-class {what} needs the run's FleetSpec to "
            "gather per-user values; engines pass it automatically")
    n_classes = len(fleet.tables.t_train)
    if v.shape != (n_classes,):
        raise ValueError(
            f"{what} must be a scalar or a ({n_classes},) per-device-"
            f"class vector for this fleet, got shape {v.shape}")
    return v[fleet.device_ids]


@register_dynamics
class MarkovChurnDynamics(DeviceDynamics):
    """Markov availability + battery trajectories + 2-state network churn.

    Three coupled per-user state machines, stepped once per slot:

    - **Availability**: a 2-state Markov chain (FLGo-style per-client
      availability). ``p_off``/``p_on`` are per-slot transition
      probabilities — scalars, or per-device-class vectors (one entry
      per catalog row of the run's ``FleetSpec``, gathered per user at
      init like ``hetero_aware``'s scales).
    - **Battery**: drains while actually training (``drain_train``
      capacity-fractions/s; ``drain_corun`` while co-running — co-run
      training works the SoC harder) and charges otherwise
      (``charge_rate``), clipped to ``[0, capacity]``. A user
      participates only while ``battery > battery_min`` (DEAL-style
      battery gating): the threshold is part of effective availability,
      so a mid-training battery collapse IS a dropout.
    - **Network**: a good/bad 2-state chain (``p_net_bad`` /
      ``p_net_recover``); in the bad state re-arrival — post-push AND
      post-recovery — costs ``net_delay_slots`` extra cooldown slots,
      feeding the lag model (late re-arrival => staler next pull).

    ``dropout`` picks the mid-training rule: ``"lose"`` (in-flight work
    lost) or ``"resume"`` (paused while down, ``resume_penalty_s`` extra
    training seconds). ``drops`` counts mid-training down-edges either
    way.
    """

    name = "markov"

    def __init__(self, p_off=0.002, p_on=0.05, *,
                 battery_capacity: float = 1.0,
                 battery_init: float = 1.0,
                 drain_train: float = 2e-4, drain_corun: float = 3e-4,
                 charge_rate: float = 1e-4, battery_min: float = 0.0,
                 p_net_bad: float = 0.0, p_net_recover: float = 0.1,
                 net_delay_slots: int = 20,
                 dropout: str = "lose", resume_penalty_s: float = 0.0):
        for what, v in (("p_net_bad", p_net_bad),
                        ("p_net_recover", p_net_recover)):
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"{what} must be in [0, 1], got {v}")
        for what, v in (("p_off", p_off), ("p_on", p_on)):
            a = np.asarray(v, dtype=float)
            if a.size == 0 or not np.all((a >= 0.0) & (a <= 1.0)):
                raise ValueError(f"{what} must be in [0, 1], got {v}")
        if battery_capacity <= 0.0:
            raise ValueError(
                f"battery_capacity must be positive, got {battery_capacity}")
        if not 0.0 <= battery_init <= 1.0:
            raise ValueError(
                f"battery_init is a capacity fraction in [0, 1], "
                f"got {battery_init}")
        if not 0.0 <= battery_min < battery_capacity:
            raise ValueError(
                f"battery_min must be in [0, capacity), got {battery_min}")
        if min(drain_train, drain_corun, charge_rate) < 0.0:
            raise ValueError("drain/charge rates must be non-negative")
        if net_delay_slots < 0:
            raise ValueError(
                f"net_delay_slots must be >= 0, got {net_delay_slots}")
        if dropout not in DROPOUT_RULES:
            raise ValueError(f"unknown dropout rule {dropout!r}; expected "
                             f"one of {DROPOUT_RULES}")
        if resume_penalty_s < 0.0:
            raise ValueError(
                f"resume_penalty_s must be >= 0, got {resume_penalty_s}")
        self.p_off = p_off
        self.p_on = p_on
        self.capacity = float(battery_capacity)
        self.battery_init = float(battery_init)
        self.drain_train = float(drain_train)
        self.drain_corun = float(drain_corun)
        self.charge_rate = float(charge_rate)
        self.battery_min = float(battery_min)
        self.p_net_bad = float(p_net_bad)
        self.p_net_recover = float(p_net_recover)
        self.net_delay_slots = int(net_delay_slots)
        self.dropout = dropout
        self.resume_penalty_s = float(resume_penalty_s)

    # ------------------------------------------------------------- state
    def init_state(self, n, cfg=None, fleet=None):
        return {
            "on": np.ones(n, dtype=bool),
            "up": np.ones(n, dtype=bool),
            "battery": np.full(n, self.battery_init * self.capacity),
            "net_bad": np.zeros(n, dtype=bool),
            "drops": np.zeros(n, dtype=np.int64),
            # run-constant per-user parameter gathers (traced carry)
            "p_off": _per_user(self.p_off, n, fleet, "p_off"),
            "p_on": _per_user(self.p_on, n, fleet, "p_on"),
        }

    def scan_operands(self, cfg):
        return (self.capacity, self.drain_train, self.drain_corun,
                self.charge_rate, self.battery_min, self.p_net_bad,
                self.p_net_recover, self.net_delay_slots,
                self.resume_penalty_s)

    def pad_state(self, k):
        # permanently-up rows: full battery (> battery_min, validated),
        # p_off=0 keeps the availability chain on under the engine's
        # fill-1.0 padded draws (1.0 >= 0), the net chain never turns bad
        # (1.0 < p_net_bad is false), and `up` never edges — so pad users
        # parked in MODE_OFF draw nothing and stay parked forever
        return {
            "on": np.ones(k, dtype=bool),
            "up": np.ones(k, dtype=bool),
            "battery": np.full(k, self.capacity),
            "net_bad": np.zeros(k, dtype=bool),
            "drops": np.zeros(k, dtype=np.int64),
            "p_off": np.zeros(k),
            "p_on": np.zeros(k),
        }

    def total_drops(self, dyn) -> int:
        return 0 if dyn is None else int(np.asarray(dyn["drops"]).sum())

    # ----------------------------------------------------------- the step
    # host_step and _transition/scan_step MUST stay formula-identical:
    # the fault-injection parity suite (tests/test_dynamics_faults.py)
    # pins loop/vectorized/jax push-log digests under x64.
    def host_step(self, dyn, rng_key, mode, corun, t_d):
        rng_key, u = _dyn_draw(rng_key, len(dyn["battery"]))
        dyn, eff = self._transition(
            np, dyn, u[0], u[1], mode, corun, t_d,
            self.capacity, self.drain_train, self.drain_corun,
            self.charge_rate, self.battery_min, self.p_net_bad,
            self.p_net_recover, self.net_delay_slots,
            self.resume_penalty_s)
        return dyn, rng_key, eff

    def scan_step(self, dyn, dv):
        jax, jnp = dv.jax, dv.jnp
        k2, sub = jax.random.split(dv.rng_key)
        u = jax.random.uniform(sub, (2, dv.n), jnp.float32)
        # live-n draw + fill-1.0 pad (identity unsharded): the threefry
        # stream matches the host engines, and 1.0 keeps pad lanes' chains
        # pinned on/never-bad (see pad_state)
        u = dv.pad_users(u, 1.0)
        dv.rng_key = k2
        (capacity, drain_train, drain_corun, charge_rate, battery_min,
         p_net_bad, p_net_recover, net_delay_slots,
         resume_penalty_s) = dv.consts
        return self._transition(
            jnp, dyn, u[0], u[1], dv.mode, dv.corun, dv.t_d,
            capacity, drain_train, drain_corun, charge_rate, battery_min,
            p_net_bad, p_net_recover, net_delay_slots, resume_penalty_s,
            zero=dv.fp_zero)

    @staticmethod
    def _transition(xp, dyn, u_avail, u_net, mode, corun, t_d,
                    capacity, drain_train, drain_corun, charge_rate,
                    battery_min, p_net_bad, p_net_recover,
                    net_delay_slots, resume_penalty_s, zero=0.0):
        """One slot, numpy or jnp (``xp``): elementwise only, identical
        operation order on both — bitwise parity under x64. ``zero`` is
        a traced 0.0 on the jax path: it forces the delta*t_d product to
        round before the battery add, which XLA's fma contraction would
        otherwise skip (see policies._jax_trace_v_norm)."""
        up_prev = dyn["up"]
        training = mode == MODE_TRAIN
        # battery: drain while ACTUALLY training (a paused trainer is
        # off, not burning), charge otherwise — off devices are assumed
        # plugged/idle-charging
        active_train = training & up_prev
        drain = xp.where(corun & active_train, drain_corun, drain_train)
        battery = xp.clip(
            dyn["battery"]
            + (xp.where(active_train, -drain, charge_rate) * t_d + zero),
            0.0, capacity)
        # Markov chains: availability (per-user probabilities from the
        # carry) and network (scalar knobs)
        on = xp.where(dyn["on"], u_avail >= dyn["p_off"],
                      u_avail < dyn["p_on"])
        net_bad = xp.where(dyn["net_bad"], u_net >= p_net_recover,
                           u_net < p_net_bad)
        # effective availability: chain on AND battery above threshold
        up = on & (battery > battery_min)
        went_down = up_prev & ~up
        went_up = ~up_prev & up
        drops = dyn["drops"] + (went_down & training)
        net_extra = xp.where(net_bad, net_delay_slots, 0)
        dyn2 = {"on": on, "up": up, "battery": battery, "net_bad": net_bad,
                "drops": drops, "p_off": dyn["p_off"], "p_on": dyn["p_on"]}
        return dyn2, DynEffects(up=up, went_down=went_down,
                                went_up=went_up, net_extra=net_extra,
                                resume_penalty=resume_penalty_s)
