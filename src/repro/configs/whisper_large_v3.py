"""whisper-large-v3 [audio] — arXiv:2212.04356, enc-dec.

32L(enc)+32L(dec) d_model=1280 20H (MHA kv=20) d_ff=5120 vocab=51866.
Conv mel frontend is a STUB: input_specs() supplies precomputed
(B, 1500, 1280) frame embeddings. LayerNorm + GELU MLP; learned encoder
positions, RoPE-free sinusoidal decoder positions (deviation noted in
DESIGN.md — upstream whisper uses learned decoder positions capped at 448).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    encoder_layers=32,
    encoder_seq=1500,
    norm_type="layernorm",
    mlp_act="gelu",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-large-v3-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    is_encoder_decoder=True,
    encoder_layers=2,
    encoder_seq=24,
    norm_type="layernorm",
    mlp_act="gelu",
    remat="none",
)
