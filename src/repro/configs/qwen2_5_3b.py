"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5 family (GQA, QKV bias).

36L d_model=2048 16H (GQA kv=2, head_dim=128) d_ff=11008 vocab=151936.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2.5-3b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qkv_bias=True,
    tie_embeddings=True,
    remat="none",
)
