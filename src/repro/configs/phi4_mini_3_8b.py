"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (RoPE, SwiGLU, GQA).

32L d_model=3072 24H (GQA kv=8, head_dim=128) d_ff=8192 vocab=200064.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="phi4-mini-3.8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    tie_embeddings=True,
    remat="none",
)
