"""internvl2-76b [vlm] — arXiv:2404.16821 (InternViT + LLM backbone).

80L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=28672 vocab=128256.
The InternViT vision frontend is a STUB: input_specs() supplies 256
precomputed patch embeddings (B, 256, 8192) prepended to the text sequence.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_vision_tokens=256,
    rope_theta=5e5,
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    num_vision_tokens=8,
    remat="none",
)
