"""mamba2-370m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L d_model=1024, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*1024 = 2048, head_dim 64 -> 32 SSD heads, ngroups 1, tied embeds.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_ngroups=1,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=128,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    ssm_ngroups=1,
    tie_embeddings=True,
    remat="none",
)
