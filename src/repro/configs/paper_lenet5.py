"""paper-lenet5 — the paper's own workload (LeNet-5 on CIFAR-10, Sec. VI).

Not an LM config: used by the paper-faithful federated simulation tier
(25 clients, local batch 20, momentum SGD per Eq. 1).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LeNetConfig:
    name: str = "paper-lenet5"
    family: str = "cnn"
    num_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    batch_size: int = 20          # paper Sec. VI: "retrieve in batch size of 20"
    learning_rate: float = 0.01
    momentum: float = 0.9         # beta in Eq. (1)


CONFIG = LeNetConfig()
SMOKE_CONFIG = LeNetConfig(name="paper-lenet5-smoke", image_size=32, batch_size=4)
