"""qwen3-0.6b [dense] — hf:Qwen/Qwen3 family (qk_norm, GQA).

28L d_model=1024 16H (GQA kv=8, head_dim=128) d_ff=3072 vocab=151936.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-0.6b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=128,
    qk_norm=True,
    tie_embeddings=True,
    remat="none",
)
