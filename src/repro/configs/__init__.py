"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

Every assigned architecture is a selectable config (``--arch <id>``); each
module also defines a REDUCED smoke config of the same family for CPU tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "mamba2_370m",
    "qwen3_moe_30b_a3b",
    "granite_moe_1b_a400m",
    "internlm2_20b",
    "qwen3_0_6b",
    "qwen2_5_3b",
    "phi4_mini_3_8b",
    "whisper_large_v3",
    "zamba2_2_7b",
    "internvl2_76b",
]

# canonical ids as assigned (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "mamba2-370m": "mamba2_370m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "internlm2-20b": "internlm2_20b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2.5-3b": "qwen2_5_3b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "whisper-large-v3": "whisper_large_v3",
    "zamba2-2.7b": "zamba2_2_7b",
    "internvl2-76b": "internvl2_76b",
    "paper-lenet5": "paper_lenet5",
})


def _module(arch: str):
    name = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    return _module(arch).SMOKE_CONFIG


def all_arch_ids():
    return [a.replace("_", "-") if a not in ("qwen3_0_6b", "qwen2_5_3b", "phi4_mini_3_8b") else
            {"qwen3_0_6b": "qwen3-0.6b", "qwen2_5_3b": "qwen2.5-3b",
             "phi4_mini_3_8b": "phi4-mini-3.8b"}[a] for a in ARCHS]
