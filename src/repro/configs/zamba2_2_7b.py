"""zamba2-2.7b [hybrid] — arXiv:2411.15242 (Mamba2 backbone + shared attn).

54L d_model=2560 32H (kv=32, head_dim=80) d_ff=10240 vocab=32000,
ssm_state=64. d_inner=5120, ssd head_dim 64 -> 80 SSD heads. Two shared
transformer blocks cycled every 6 mamba layers (9 invocations).
Simplification vs upstream (noted in DESIGN.md): shared blocks use standard
pre-norm residual wiring (no concat-reproject / per-invocation LoRA).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    ssm_ngroups=1,
    hybrid_period=6,
    num_shared_blocks=2,
    rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=8,
    ssm_ngroups=1,
    hybrid_period=2,
    num_shared_blocks=2,
    remat="none",
)
