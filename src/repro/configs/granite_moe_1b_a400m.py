"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512, vocab=49155,
MoE 32 experts top-8, tied embeddings.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    num_experts_per_tok=8,
    moe_impl="sorted",
    router_norm_topk=True,
    tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=128,
    num_experts=4,
    num_experts_per_tok=2,
    moe_impl="sorted",
    router_norm_topk=True,
    tie_embeddings=True,
    remat="none",
)
