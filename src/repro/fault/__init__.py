from .monitor import HeartbeatMonitor, StragglerDetector, ElasticCohort

__all__ = ["HeartbeatMonitor", "StragglerDetector", "ElasticCohort"]
