from .monitor import (ElasticCohort, FleetMonitor, HeartbeatMonitor,
                      SlotClock, StragglerDetector)

__all__ = ["ElasticCohort", "FleetMonitor", "HeartbeatMonitor",
           "SlotClock", "StragglerDetector"]
