"""Fault tolerance for the async federation: heartbeats, straggler EWMAs,
elastic cohort membership.

The paper's asynchronous design is itself the primary straggler mitigation —
no barrier means a slow island only stales, never stalls. These utilities
close the loop at datacenter scale: detect islands whose update cadence has
collapsed (failure or chronic straggle), evict them, re-queue their shard,
and let the Lyapunov queue re-absorb the arrival — membership is just A(t).

Both monitors take an injectable ``clock`` callable; ``SlotClock`` adapts
them to the simulator's slotted time (slot index * t_d seconds) so
``FleetMonitor`` can watch a simulated fleet's push stream — live or
replayed from a ``SimResult`` push log — and flag exactly the users the
device-dynamics layer (core/dynamics.py) churned off. Eviction is
non-final by design: an evicted user's next push re-registers it, the
server-side mirror of the simulator's recovered users re-entering the
arrival process.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Set, Tuple


class SlotClock:
    """The simulator's slotted time as a monitor clock: ``advance()``/
    ``seek()`` move the slot cursor, calling the clock reads
    ``slot * t_d`` seconds. One instance shared by every monitor keeps
    heartbeat timeouts and straggler EWMAs on the same timeline."""

    def __init__(self, t_d: float = 1.0):
        if t_d <= 0:
            raise ValueError(f"t_d must be positive, got {t_d}")
        self.t_d = float(t_d)
        self.slot = 0

    def __call__(self) -> float:
        return self.slot * self.t_d

    def advance(self, slots: int = 1) -> None:
        self.slot += int(slots)

    def seek(self, slot: int) -> None:
        if slot < self.slot:
            raise ValueError(
                f"slot clock cannot rewind: at {self.slot}, asked for "
                f"{slot}")
        self.slot = int(slot)


@dataclasses.dataclass
class WorkerStats:
    last_seen: float
    ewma_interval: Optional[float] = None
    updates: int = 0


class HeartbeatMonitor:
    """Tracks last-seen times; a worker is dead after `timeout` seconds."""

    def __init__(self, timeout: float, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.workers: Dict[str, WorkerStats] = {}

    def beat(self, worker_id: str):
        now = self.clock()
        w = self.workers.get(worker_id)
        if w is None:
            self.workers[worker_id] = WorkerStats(last_seen=now)
        else:
            w.last_seen = now

    def dead(self) -> Set[str]:
        now = self.clock()
        return {wid for wid, w in self.workers.items()
                if now - w.last_seen > self.timeout}

    def remove(self, worker_id: str):
        self.workers.pop(worker_id, None)


class StragglerDetector:
    """EWMA of per-worker update intervals; flags workers slower than
    `factor` x the cohort median."""

    def __init__(self, alpha: float = 0.3, factor: float = 3.0,
                 clock: Callable[[], float] = time.monotonic):
        self.alpha = alpha
        self.factor = factor
        self.clock = clock
        self.workers: Dict[str, WorkerStats] = {}

    def on_update(self, worker_id: str):
        now = self.clock()
        w = self.workers.setdefault(worker_id, WorkerStats(last_seen=now))
        if w.updates > 0:
            interval = now - w.last_seen
            w.ewma_interval = interval if w.ewma_interval is None else \
                self.alpha * interval + (1 - self.alpha) * w.ewma_interval
        w.last_seen = now
        w.updates += 1

    def median_interval(self) -> Optional[float]:
        xs = sorted(w.ewma_interval for w in self.workers.values()
                    if w.ewma_interval is not None)
        if not xs:
            return None
        return xs[len(xs) // 2]

    def stragglers(self) -> Set[str]:
        med = self.median_interval()
        if med is None:
            return set()
        return {wid for wid, w in self.workers.items()
                if w.ewma_interval is not None
                and w.ewma_interval > self.factor * med}

    def remove(self, worker_id: str):
        """Forget a worker (eviction): its stale EWMA must not skew the
        cohort median while it is gone; a later update re-registers it
        with a fresh history."""
        self.workers.pop(worker_id, None)


class FleetMonitor:
    """Heartbeat + straggler monitoring of a simulated fleet on ONE shared
    ``SlotClock``: every push in the simulator's push stream is a
    heartbeat and a cadence sample, ``sweep()`` evicts users whose last
    push is older than ``timeout_slots`` — exactly the users the
    device-dynamics layer churned off (or starved) — and an evicted
    user's next push re-registers it, mirroring the simulator's recovery
    path where a returned device re-enters the arrival process.

    Use it live (call ``observe_push``/``sweep`` from the serving tier)
    or post-hoc via ``replay(result.push_log, horizon)``.
    """

    def __init__(self, timeout_slots: int, t_d: float = 1.0, *,
                 alpha: float = 0.3, factor: float = 3.0):
        if timeout_slots <= 0:
            raise ValueError(
                f"timeout_slots must be positive, got {timeout_slots}")
        self.clock = SlotClock(t_d)
        self.heartbeat = HeartbeatMonitor(timeout_slots * t_d,
                                          clock=self.clock)
        self.straggler = StragglerDetector(alpha=alpha, factor=factor,
                                           clock=self.clock)
        self.evictions: List[Tuple[int, int]] = []   # (slot, user)

    def observe_push(self, slot: int, user: int) -> None:
        """One push event: heartbeat + cadence sample. Slots must be
        observed in nondecreasing order (the push stream's order)."""
        self.clock.seek(int(slot))
        self.heartbeat.beat(int(user))
        self.straggler.on_update(int(user))

    def observe_heartbeat(self, slot: int, user: int) -> None:
        """Liveness-only beat (no cadence sample): the serving tier calls
        this per shard PACKET, so a multi-shard push keeps its island
        alive while in flight without the burst of same-slot deliveries
        collapsing the straggler EWMA to zero intervals. Only completed
        pushes (``observe_push``) are cadence samples."""
        self.clock.seek(int(slot))
        self.heartbeat.beat(int(user))

    def sweep(self, slot: int) -> Set[int]:
        """Advance to ``slot`` and evict every user whose last push aged
        past the timeout. Eviction removes the user from BOTH monitors —
        its stale interval must not skew the straggler median — but is
        non-final: the next observed push re-registers it."""
        self.clock.seek(int(slot))
        dead = self.heartbeat.dead()
        for uid in sorted(dead):
            self.heartbeat.remove(uid)
            self.straggler.remove(uid)
            self.evictions.append((int(slot), uid))
        return dead

    def replay(self, push_log, horizon_slots: int,
               sweep_every: int = 1) -> List[Tuple[int, int]]:
        """Drive the monitor from a finished run's push log (a
        ``SimResult.push_log``): observe each slot's pushes, then sweep.
        Returns the eviction list ``[(slot, user), ...]``."""
        events = [(int(e["t"]), int(e["user"])) for e in push_log]
        k = 0
        for slot in range(int(horizon_slots)):
            while k < len(events) and events[k][0] == slot:
                self.observe_push(slot, events[k][1])
                k += 1
            if slot % max(int(sweep_every), 1) == 0:
                self.sweep(slot)
        return self.evictions

    @property
    def active(self) -> Set[int]:
        return set(self.heartbeat.workers)


class ElasticCohort:
    """Dynamic membership: join/leave/evict with shard reassignment. The
    training step itself never recompiles — only the arrival process A(t)
    changes (paper Def. 3)."""

    def __init__(self, shards: list):
        self.free_shards = list(shards)
        self.assignment: Dict[str, object] = {}

    def join(self, worker_id: str):
        if not self.free_shards:
            raise RuntimeError("no free shards; grow the shard pool")
        shard = self.free_shards.pop()
        self.assignment[worker_id] = shard
        return shard

    def leave(self, worker_id: str):
        shard = self.assignment.pop(worker_id, None)
        if shard is not None:
            self.free_shards.append(shard)
        return shard

    def evict(self, worker_ids) -> list:
        return [self.leave(w) for w in worker_ids]

    @property
    def active(self) -> Set[str]:
        return set(self.assignment)
