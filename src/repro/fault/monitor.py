"""Fault tolerance for the async federation: heartbeats, straggler EWMAs,
elastic cohort membership.

The paper's asynchronous design is itself the primary straggler mitigation —
no barrier means a slow island only stales, never stalls. These utilities
close the loop at datacenter scale: detect islands whose update cadence has
collapsed (failure or chronic straggle), evict them, re-queue their shard,
and let the Lyapunov queue re-absorb the arrival — membership is just A(t).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Set


@dataclasses.dataclass
class WorkerStats:
    last_seen: float
    ewma_interval: Optional[float] = None
    updates: int = 0


class HeartbeatMonitor:
    """Tracks last-seen times; a worker is dead after `timeout` seconds."""

    def __init__(self, timeout: float, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.workers: Dict[str, WorkerStats] = {}

    def beat(self, worker_id: str):
        now = self.clock()
        w = self.workers.get(worker_id)
        if w is None:
            self.workers[worker_id] = WorkerStats(last_seen=now)
        else:
            w.last_seen = now

    def dead(self) -> Set[str]:
        now = self.clock()
        return {wid for wid, w in self.workers.items()
                if now - w.last_seen > self.timeout}

    def remove(self, worker_id: str):
        self.workers.pop(worker_id, None)


class StragglerDetector:
    """EWMA of per-worker update intervals; flags workers slower than
    `factor` x the cohort median."""

    def __init__(self, alpha: float = 0.3, factor: float = 3.0,
                 clock: Callable[[], float] = time.monotonic):
        self.alpha = alpha
        self.factor = factor
        self.clock = clock
        self.workers: Dict[str, WorkerStats] = {}

    def on_update(self, worker_id: str):
        now = self.clock()
        w = self.workers.setdefault(worker_id, WorkerStats(last_seen=now))
        if w.updates > 0:
            interval = now - w.last_seen
            w.ewma_interval = interval if w.ewma_interval is None else \
                self.alpha * interval + (1 - self.alpha) * w.ewma_interval
        w.last_seen = now
        w.updates += 1

    def median_interval(self) -> Optional[float]:
        xs = sorted(w.ewma_interval for w in self.workers.values()
                    if w.ewma_interval is not None)
        if not xs:
            return None
        return xs[len(xs) // 2]

    def stragglers(self) -> Set[str]:
        med = self.median_interval()
        if med is None:
            return set()
        return {wid for wid, w in self.workers.items()
                if w.ewma_interval is not None
                and w.ewma_interval > self.factor * med}


class ElasticCohort:
    """Dynamic membership: join/leave/evict with shard reassignment. The
    training step itself never recompiles — only the arrival process A(t)
    changes (paper Def. 3)."""

    def __init__(self, shards: list):
        self.free_shards = list(shards)
        self.assignment: Dict[str, object] = {}

    def join(self, worker_id: str):
        if not self.free_shards:
            raise RuntimeError("no free shards; grow the shard pool")
        shard = self.free_shards.pop()
        self.assignment[worker_id] = shard
        return shard

    def leave(self, worker_id: str):
        shard = self.assignment.pop(worker_id, None)
        if shard is not None:
            self.free_shards.append(shard)
        return shard

    def evict(self, worker_ids) -> list:
        return [self.leave(w) for w in worker_ids]

    @property
    def active(self) -> Set[str]:
        return set(self.assignment)
