"""Sharded host-side loader: double-buffered prefetch of globally-sharded
batches onto the mesh (device_put with NamedSharding)."""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Iterator

import jax
import numpy as np


class ShardedLoader:
    """Wraps a host batch iterator; places each batch with the given
    shardings; prefetches `depth` batches ahead on a worker thread."""

    def __init__(self, it: Iterator[Any], shardings: Any, depth: int = 2):
        self._it = it
        self._shardings = shardings
        self._buf: collections.deque = collections.deque()
        self._depth = depth
        self._lock = threading.Lock()
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _place(self, batch):
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), batch, self._shardings)

    def _fill(self):
        for batch in self._it:
            placed = self._place(batch)
            while True:
                with self._lock:
                    if len(self._buf) < self._depth:
                        self._buf.append(placed)
                        break
                threading.Event().wait(0.001)
        self._done = True

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            with self._lock:
                if self._buf:
                    return self._buf.popleft()
            if self._done:
                with self._lock:
                    if self._buf:
                        return self._buf.popleft()
                raise StopIteration
            threading.Event().wait(0.001)
