from .synthetic import (cifarlike_dataset, synthetic_tokens, token_batches,
                        dirichlet_partition)
from .loader import ShardedLoader

__all__ = ["cifarlike_dataset", "synthetic_tokens", "token_batches",
           "dirichlet_partition", "ShardedLoader"]
