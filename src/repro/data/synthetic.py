"""Deterministic synthetic datasets.

* ``cifarlike_dataset`` — class-conditional Gaussian images (32x32x3, 10
  classes) standing in for CIFAR-10 (not available offline). LeNet-5 learns
  it the same qualitative way; scheme-to-scheme convergence *ratios* (the
  paper's claim) are preserved.
* ``synthetic_tokens`` — structured token streams for LM smoke training: a
  noisy order-2 Markov chain so cross-entropy measurably falls below the
  uniform baseline.
* ``dirichlet_partition`` — standard non-IID federated split.
"""
from __future__ import annotations

import numpy as np


def cifarlike_dataset(n: int = 10000, num_classes: int = 10, image_size: int = 32,
                      channels: int = 3, noise: float = 0.35, seed: int = 0,
                      template_seed: int = 1234):
    """Returns (images (n,H,W,C) float32 in [-1,1]-ish, labels (n,) int32).

    Class templates come from ``template_seed`` (FIXED across train/test
    splits — the templates ARE the class definitions); only the sample
    noise and the label draw vary with ``seed``."""
    trng = np.random.default_rng(template_seed)
    rng = np.random.default_rng(seed)
    # class templates: low-frequency random patterns (so convs help)
    freq = 4
    coarse = trng.normal(0, 1, (num_classes, freq, freq, channels))
    templates = np.stack([
        np.kron(coarse[c], np.ones((image_size // freq, image_size // freq, 1)))
        for c in range(num_classes)])                       # (10,H,W,C)
    labels = rng.integers(0, num_classes, n).astype(np.int32)
    images = templates[labels] + noise * rng.normal(0, 1, (n, image_size, image_size, channels))
    return images.astype(np.float32), labels


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float = 1.0,
                        seed: int = 0):
    """Non-IID split: per-class Dirichlet proportions. Returns list of index
    arrays (one per client)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            shards[cid].extend(part.tolist())
    return [np.array(sorted(s), dtype=np.int64) for s in shards]


def synthetic_tokens(n_tokens: int, vocab: int, seed: int = 0,
                     order: int = 2, noise: float = 0.1):
    """Noisy deterministic-Markov token stream: next = (a*prev + b*prev2) % V
    with probability (1-noise), uniform otherwise. Learnable structure."""
    rng = np.random.default_rng(seed)
    a, b = 31, 17
    out = np.empty(n_tokens, np.int32)
    out[0], out[1] = rng.integers(0, vocab, 2)
    noise_mask = rng.random(n_tokens) < noise
    noise_tok = rng.integers(0, vocab, n_tokens)
    for i in range(order, n_tokens):
        out[i] = noise_tok[i] if noise_mask[i] else (a * out[i - 1] + b * out[i - 2] + 7) % vocab
    return out


def token_batches(stream: np.ndarray, batch: int, seq: int, n_batches: int,
                  seed: int = 0):
    """Yield {"tokens","labels"} batches from a stream (next-token labels)."""
    rng = np.random.default_rng(seed)
    max_start = len(stream) - seq - 1
    for _ in range(n_batches):
        starts = rng.integers(0, max_start, batch)
        toks = np.stack([stream[s: s + seq] for s in starts])
        labs = np.stack([stream[s + 1: s + seq + 1] for s in starts])
        yield {"tokens": toks.astype(np.int32), "labels": labs.astype(np.int32)}
