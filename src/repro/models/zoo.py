"""build_model(cfg) -> Model: family-specific assembly of init/loss/prefill/decode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import cross_kv
from .common import apply_norm, dense_init, embed_init, norm_params
from .config import ModelConfig
from .model import (Model, _lm_logits, _scan, _stacked_init, cross_entropy,
                    dense_stack, hybrid_stack, init_mamba_layer,
                    init_transformer_block, ssm_decode_stack, ssm_stack,
                    transformer_block)
from .ssm import init_ssm_state


def _adt(cfg):
    return jnp.dtype(cfg.dtype)


def _embed_tokens(params, tokens, cfg):
    return params["embed"][tokens].astype(_adt(cfg))


def _kv_cache_zeros(cfg, n_layers, batch, max_seq):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    z = jnp.zeros((n_layers, batch, max_seq, kv, hd), _adt(cfg))
    return {"k": z, "v": z}


# =============================================================== decoder-only LM
def build_lm(cfg: ModelConfig) -> Model:
    """dense / moe / vlm decoder-only LM."""
    Nv = cfg.num_vision_tokens

    def init(key):
        ks = jax.random.split(key, 4)
        p = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
             "layers": _stacked_init(lambda k: init_transformer_block(k, cfg),
                                     ks[1], cfg.num_layers),
             "final_norm": norm_params(cfg.d_model, cfg)}
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size)
        return p

    def embed_inputs(params, batch):
        x = _embed_tokens(params, batch["tokens"], cfg)
        if Nv:
            vis = batch["vision_embeds"].astype(_adt(cfg))
            x = jnp.concatenate([vis, x], axis=1)
        S = x.shape[1]
        return x, jnp.arange(S)

    def loss(params, batch):
        x, positions = embed_inputs(params, batch)
        x, _, aux = dense_stack(x, params["layers"], cfg, positions=positions)
        if Nv:
            x = x[:, Nv:, :]
        logits = _lm_logits(x, params, cfg)
        l = cross_entropy(logits, batch["labels"])
        return l + 0.01 * aux, {"loss": l, "aux_loss": aux}

    def init_cache(batch, max_seq):
        return {"layers": _kv_cache_zeros(cfg, cfg.num_layers, batch, max_seq + Nv),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(params, batch, cache):
        x, positions = embed_inputs(params, batch)
        x, new_kv, _ = dense_stack(x, params["layers"], cfg, positions=positions,
                                   cache=cache["layers"], cache_pos=0)
        logits = _lm_logits(x[:, -1:, :], params, cfg)
        return logits, {"layers": new_kv, "pos": jnp.int32(x.shape[1])}

    def decode_step(params, cache, batch):
        pos = cache["pos"]
        x = _embed_tokens(params, batch["tokens"], cfg)         # (B,1,D)
        positions = pos + jnp.arange(x.shape[1])
        x, new_kv, _ = dense_stack(x, params["layers"], cfg, positions=positions,
                                   cache=cache["layers"], cache_pos=pos)
        logits = _lm_logits(x, params, cfg)
        return logits, {"layers": new_kv, "pos": pos + x.shape[1]}

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


# =============================================================== pure SSM LM
def build_ssm_lm(cfg: ModelConfig) -> Model:
    def init(key):
        ks = jax.random.split(key, 4)
        p = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
             "layers": _stacked_init(lambda k: init_mamba_layer(k, cfg),
                                     ks[1], cfg.num_layers),
             "final_norm": norm_params(cfg.d_model, cfg)}
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size)
        return p

    def loss(params, batch):
        x = _embed_tokens(params, batch["tokens"], cfg)
        x, _ = ssm_stack(x, params["layers"], cfg)
        logits = _lm_logits(x, params, cfg)
        l = cross_entropy(logits, batch["labels"])
        return l, {"loss": l}

    def init_cache(batch, max_seq):
        st = init_ssm_state(cfg, batch)
        states = jax.tree.map(
            lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype), st)
        return {"layers": states, "pos": jnp.zeros((), jnp.int32)}

    def prefill(params, batch, cache):
        x = _embed_tokens(params, batch["tokens"], cfg)
        x, new_states = ssm_stack(x, params["layers"], cfg, states=cache["layers"])
        logits = _lm_logits(x[:, -1:, :], params, cfg)
        return logits, {"layers": new_states, "pos": jnp.int32(x.shape[1])}

    def decode_step(params, cache, batch):
        x = _embed_tokens(params, batch["tokens"], cfg)
        x, new_states = ssm_decode_stack(x, params["layers"], cfg, cache["layers"])
        logits = _lm_logits(x, params, cfg)
        return logits, {"layers": new_states, "pos": cache["pos"] + 1}

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


# =============================================================== hybrid (zamba2)
def build_hybrid_lm(cfg: ModelConfig) -> Model:
    G = cfg.num_layers // cfg.hybrid_period

    def init(key):
        ks = jax.random.split(key, 5)
        p = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
             "layers": {
                 "mamba": _stacked_init(lambda k: init_mamba_layer(k, cfg),
                                        ks[1], cfg.num_layers),
                 "shared": _stacked_init(lambda k: init_transformer_block(k, cfg),
                                         ks[2], cfg.num_shared_blocks)},
             "final_norm": norm_params(cfg.d_model, cfg)}
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(ks[3], cfg.d_model, cfg.vocab_size)
        return p

    def loss(params, batch):
        x = _embed_tokens(params, batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])
        x, _, _ = hybrid_stack(x, params["layers"], cfg, positions=positions)
        logits = _lm_logits(x, params, cfg)
        l = cross_entropy(logits, batch["labels"])
        return l, {"loss": l}

    def init_cache(batch, max_seq):
        st = init_ssm_state(cfg, batch)
        states = jax.tree.map(
            lambda t: jnp.zeros((cfg.num_layers,) + t.shape, t.dtype), st)
        return {"ssm": states, "attn": _kv_cache_zeros(cfg, G, batch, max_seq),
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(params, batch, cache):
        x = _embed_tokens(params, batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])
        x, new_ssm, new_kv = hybrid_stack(
            x, params["layers"], cfg, positions=positions,
            ssm_states=cache["ssm"], attn_cache=cache["attn"], cache_pos=0)
        logits = _lm_logits(x[:, -1:, :], params, cfg)
        return logits, {"ssm": new_ssm, "attn": new_kv,
                        "pos": jnp.int32(x.shape[1])}

    def decode_step(params, cache, batch):
        pos = cache["pos"]
        x = _embed_tokens(params, batch["tokens"], cfg)
        positions = pos + jnp.arange(x.shape[1])
        x, new_ssm, new_kv = hybrid_stack(
            x, params["layers"], cfg, positions=positions,
            ssm_states=cache["ssm"], attn_cache=cache["attn"], cache_pos=pos,
            decode=True)
        logits = _lm_logits(x, params, cfg)
        return logits, {"ssm": new_ssm, "attn": new_kv, "pos": pos + 1}

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


# =============================================================== whisper enc-dec
def build_encdec(cfg: ModelConfig) -> Model:
    """Whisper-style: stub conv frontend supplies (B, encoder_seq, D) frames."""
    enc_cfg = cfg  # same dims; encoder is non-causal

    def init(key):
        ks = jax.random.split(key, 7)
        return {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "enc_pos": 0.02 * jax.random.normal(ks[1], (cfg.encoder_seq, cfg.d_model)),
            "enc_layers": _stacked_init(lambda k: init_transformer_block(k, cfg),
                                        ks[2], cfg.encoder_layers),
            "enc_norm": norm_params(cfg.d_model, cfg),
            "dec_layers": _stacked_init(
                lambda k: init_transformer_block(k, cfg, cross=True),
                ks[3], cfg.num_layers),
            "final_norm": norm_params(cfg.d_model, cfg),
            "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab_size),
        }

    def encode(params, batch):
        x = batch["audio_embeds"].astype(_adt(cfg)) + \
            params["enc_pos"].astype(_adt(cfg))[None]

        def body(carry, p):
            from .model import _shard_seq
            h, _, _ = transformer_block(_shard_seq(carry, cfg), p, cfg,
                                        positions=None, mask=jnp.bool_(True))
            return h, jnp.float32(0.0)

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        x, _ = _scan(body, x, params["enc_layers"], cfg)
        return apply_norm(x, params["enc_norm"], cfg)

    def all_cross_kv(params, enc):
        """Cross-attention K/V for every decoder layer at once: computed
        ONCE per request (at prefill) and cached — recomputing them per
        decode token costs ~100x the useful decode flops."""
        return jax.vmap(lambda p: cross_kv(enc, p["cross_attn"], cfg))(
            params["dec_layers"])          # each (L, B, Senc, KV, hd)

    def decode_stack(x, params, positions, cross_cache, cache=None,
                     cache_pos=None):
        from .model import _read_layer, _shard_seq, _write_layer
        ck_all, cv_all = cross_cache

        if cache is None:
            L = cfg.num_layers

            def body(carry, xs):
                p, ck, cv = xs
                h, _, _ = transformer_block(_shard_seq(carry, cfg), p, cfg,
                                            positions=positions,
                                            cross=(ck, cv))
                return h, jnp.float32(0.0)

            if cfg.remat == "full":
                body = jax.checkpoint(body)
            x, _ = _scan(body, x, (params["dec_layers"], ck_all, cv_all), cfg)
            return x, None

        L = cfg.num_layers

        def body(carry, xs):
            h, cache_all = carry
            h = _shard_seq(h, cfg)
            p, idx = xs
            h, new_kv, _ = transformer_block(
                h, p, cfg, positions=positions,
                kv_cache=_read_layer(cache_all, idx), cache_pos=cache_pos,
                cross=(_read_layer(ck_all, idx), _read_layer(cv_all, idx)))
            return (h, _write_layer(cache_all, new_kv, idx)), None

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        (x, new_cache), _ = _scan(body, (x, cache),
                                  (params["dec_layers"], jnp.arange(L)), cfg)
        return x, new_cache

    def loss(params, batch):
        enc = encode(params, batch)
        x = _embed_tokens(params, batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])
        x, _ = decode_stack(x, params, positions, all_cross_kv(params, enc))
        logits = _lm_logits(x, params, cfg)
        l = cross_entropy(logits, batch["labels"])
        return l, {"loss": l}

    def init_cache(batch, max_seq):
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        zc = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, kv, hd),
                       _adt(cfg))
        return {"layers": _kv_cache_zeros(cfg, cfg.num_layers, batch, max_seq),
                "cross": {"k": zc, "v": zc},
                "pos": jnp.zeros((), jnp.int32)}

    def prefill(params, batch, cache):
        enc = encode(params, batch)
        ck, cv = all_cross_kv(params, enc)
        x = _embed_tokens(params, batch["tokens"], cfg)
        positions = jnp.arange(x.shape[1])
        x, new_kv = decode_stack(x, params, positions, (ck, cv),
                                 cache=cache["layers"], cache_pos=0)
        logits = _lm_logits(x[:, -1:, :], params, cfg)
        return logits, {"layers": new_kv, "cross": {"k": ck, "v": cv},
                        "pos": jnp.int32(x.shape[1])}

    def decode_step(params, cache, batch):
        pos = cache["pos"]
        x = _embed_tokens(params, batch["tokens"], cfg)
        positions = pos + jnp.arange(x.shape[1])
        x, new_kv = decode_stack(x, params, positions,
                                 (cache["cross"]["k"], cache["cross"]["v"]),
                                 cache=cache["layers"], cache_pos=pos)
        logits = _lm_logits(x, params, cfg)
        return logits, {"layers": new_kv, "cross": cache["cross"],
                        "pos": pos + 1}

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


FAMILIES = {
    "dense": build_lm,
    "moe": build_lm,
    "vlm": build_lm,
    "ssm": build_ssm_lm,
    "hybrid": build_hybrid_lm,
    "audio": build_encdec,
}


def build_model(cfg: ModelConfig) -> Model:
    return FAMILIES[cfg.family](cfg)
