"""Dense MLP and Mixture-of-Experts layers.

Two MoE dispatch implementations:
  * "dense"  — one-hot combine einsum over ALL experts. Simple, differentiable,
               pure-pjit friendly. FLOPs cost = E/k x the active compute; used
               for smoke tests / small expert counts and as a fallback.
  * "sorted" — capacity-based sort+gather dispatch (GShard/MaxText style) that
               only computes routed tokens (x capacity factor). Tokens are
               sorted by expert id, gathered into an (E, C, D) buffer via an
               offset table, batched-matmul'd, and scatter-added back. This is
               the production path; it runs inside the global pjit with local
               token views (sort is per data shard by construction as the
               token axis is data-sharded and the op chain is elementwise in
               the shard dimension; see launch/shardings.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ACTS, dense_init


# ------------------------------------------------------------------ dense MLP
def init_mlp(key, cfg, d_model=None, d_ff=None):
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "silu":  # SwiGLU
        return {"w_gate": dense_init(ks[0], D, F), "w_up": dense_init(ks[1], D, F),
                "w_down": dense_init(ks[2], F, D)}
    return {"w_up": dense_init(ks[0], D, F), "b_up": jnp.zeros((F,), jnp.float32),
            "w_down": dense_init(ks[1], F, D), "b_down": jnp.zeros((D,), jnp.float32)}


def mlp(x, p, cfg):
    dt = x.dtype
    act = ACTS[cfg.mlp_act]
    if cfg.mlp_act == "silu":
        h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
        return h @ p["w_down"].astype(dt)
    h = act(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# ------------------------------------------------------------------------ MoE
def init_moe(key, cfg):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    std = D ** -0.5
    return {
        "router": dense_init(ks[0], D, E),
        "w_gate": (std * jax.random.truncated_normal(ks[1], -3, 3, (E, D, F))).astype(jnp.float32),
        "w_up":   (std * jax.random.truncated_normal(ks[2], -3, 3, (E, D, F))).astype(jnp.float32),
        "w_down": (F ** -0.5 * jax.random.truncated_normal(ks[3], -3, 3, (E, F, D))).astype(jnp.float32),
    }


def router_topk(x, w_router, cfg):
    """Returns (weights (T,k), indices (T,k), aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    if cfg.router_norm_topk:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balancing auxiliary loss.
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return weights, idx, aux


def moe_dense(x, p, cfg):
    """One-hot combine over all experts.  x: (T, D)."""
    T, D = x.shape
    dt = x.dtype
    weights, idx, aux = router_topk(x, p["router"], cfg)
    E = cfg.num_experts
    # combine (T, E): sum of gate weights routed to each expert
    comb = jax.nn.one_hot(idx, E, dtype=jnp.float32) * weights[..., None]  # (T,k,E)
    comb = comb.sum(axis=1)                                  # (T, E)
    act = ACTS[cfg.mlp_act]
    h = jnp.einsum("td,edf->etf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("td,edf->etf", x, p["w_up"].astype(dt))
    y = jnp.einsum("etf,efd->etd", act(h) * u, p["w_down"].astype(dt))
    out = jnp.einsum("etd,te->td", y.astype(jnp.float32), comb)
    return out.astype(dt), aux


def moe_sorted(x, p, cfg):
    """Capacity-based sort+gather dispatch.  x: (T, D) local tokens.

    Both dispatch and combine are pure gathers (the combine inverts the
    sort permutation instead of scatter-adding): GSPMD partitions batched
    gathers along the vmapped row dim, whereas a batched scatter forces an
    all-gather of every row's (E, C, D) buffer."""
    T, D = x.shape
    dt = x.dtype
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    C = max(int(T * k / E * cfg.moe_capacity_factor), 1)

    weights, idx, aux = router_topk(x, p["router"], cfg)      # (T,k)
    flat_e = idx.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)                  # sorted slot -> flat slot
    tok_of_slot = order // k                                  # token id per sorted slot
    gate_of_slot = weights.reshape(-1)[order]                 # (T*k,)
    # count via comparison matrix, NOT jnp.bincount: bincount lowers to a
    # scatter-add, and batched scatters make GSPMD replicate the whole
    # vmapped dispatch (all rows' (E,C,D) buffers on every device).
    sizes = (flat_e[None, :] == jnp.arange(E)[:, None]).sum(axis=1)   # (E,)
    offsets = jnp.cumsum(sizes) - sizes                       # exclusive cumsum
    gidx = offsets[:, None] + jnp.arange(C)[None, :]          # (E, C) slots per expert
    valid = (jnp.arange(C)[None, :] < sizes[:, None])         # (E, C)
    gidx = jnp.where(valid, gidx, 0)
    tok = jnp.where(valid, tok_of_slot[gidx], 0)              # (E, C) token ids
    gates = jnp.where(valid, gate_of_slot[gidx], 0.0)         # (E, C)

    xg = x[tok] * valid[..., None].astype(dt)                 # (E, C, D)
    act = ACTS[cfg.mlp_act]
    h = act(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", xg, p["w_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))  # (E, C, D)
    y = (y.astype(jnp.float32) * gates[..., None]).astype(dt)

    # scatter-free combine: flat slot j sits at sorted position inv[j],
    # which is position inv[j] - offsets[e_j] within expert e_j's buffer.
    inv = jnp.argsort(order)                                  # flat -> sorted pos
    c_of_flat = inv - offsets[flat_e]                         # (T*k,)
    ok = c_of_flat < C
    vals = y[flat_e, jnp.where(ok, c_of_flat, 0)]             # (T*k, D)
    vals = vals * ok[:, None].astype(dt)
    out = vals.reshape(T, k, D).sum(axis=1)
    return out.astype(dt), aux


def _pin_rows(x3):
    """Anchor the dispatch-batch dim onto the dp axes before the vmapped
    sort/gather chain: in python-unrolled graphs (dry-run calibration)
    GSPMD otherwise replicates some layers' (rows, E, C, D) buffers."""
    from repro.launch.mesh import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x3
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in mesh.axis_names if a != "model") or None
    if dp is None:
        return x3
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    if dpn <= 1 or x3.shape[0] % dpn:
        return x3
    U = P.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(x3, P(dp, U, U))


def moe(x3, p, cfg):
    """x3: (B, S, D) -> (B, S, D), aux loss.

    Sorted dispatch runs PER BATCH ROW via vmap: the argsort/bincount chain
    then lives entirely inside each data shard (the batch dim is
    dp-sharded), so GSPMD never all-gathers the global token axis — the
    unbatched formulation forced a full-token gather per MoE layer
    (observed: 300+ s of collective time per prefill step at 32k).
    Capacity is enforced per row (C = S*k/E * factor), a slightly stronger
    balance condition than the global bound.

    The sorted path also needs S >> E for the capacity bound to be
    statistically safe; for tiny token counts (decode steps, smoke tests)
    it would drop tokens, so fall back to the exact dense combine there."""
    B, S, D = x3.shape
    if cfg.moe_impl == "sorted" and S >= 4 * cfg.num_experts:
        # bound the (E, C, D) buffers: split long sequences into dispatch
        # chunks via a NESTED vmap (batch, then seq-chunks). The seq split
        # must not be folded into the batch dim — reshaping through the
        # dp-sharded batch axis breaks GSPMD propagation and every row's
        # dispatch buffer gets replicated (observed 80 GiB/device at 32k).
        C0 = cfg.moe_dispatch_chunk
        x3 = _pin_rows(x3)
        row_fn = lambda xr: moe_sorted(xr, p, cfg)
        if S > C0 and S % C0 == 0:
            # scan (not vmap) over the seq chunks: one chunk's (E, C, D)
            # dispatch buffers live at a time — 8x less prefill memory at
            # 32k; chunks would serialize through the MXU anyway.
            # (unrolled when cfg.scan_layers=False so the dry-run's flop
            # calibration counts every chunk — see models.model._scan)
            chunks = jnp.moveaxis(x3.reshape(B, S // C0, C0, D), 1, 0)

            if cfg.scan_layers:
                def step(_, xc):
                    return None, jax.vmap(row_fn)(xc)

                _, (out, aux) = jax.lax.scan(step, None, chunks)
            else:
                outs = [jax.vmap(row_fn)(chunks[i])
                        for i in range(chunks.shape[0])]
                out = jnp.stack([o for o, _ in outs])
                aux = jnp.stack([a for _, a in outs])
            out = jnp.moveaxis(out, 0, 1).reshape(B, S, D)
            return out, jnp.mean(aux)
        out, aux = jax.vmap(row_fn)(x3)
        return out, jnp.mean(aux)
    x = x3.reshape(B * S, D)
    out, aux = moe_dense(x, p, cfg)
    return out.reshape(B, S, D), aux
