"""GQA attention with RoPE, qk-norm, optional bias, KV-cache decode.

The default implementation is pure XLA (jnp einsums) so that dry-run
compilation on any backend succeeds; the Pallas flash kernel
(`repro.kernels.flash_attention`) is swapped in via cfg.attention_impl="flash"
on real TPU hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, dense_init, rms_norm


def init_attention(key, cfg, d_model=None):
    D = d_model or cfg.d_model
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, H * hd),
        "wk": dense_init(ks[1], D, KV * hd),
        "wv": dense_init(ks[2], D, KV * hd),
        "wo": dense_init(ks[3], H * hd, D),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(x, p, cfg, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, KV, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt).reshape(H, hd)
        k = k + p["bk"].astype(dt).reshape(KV, hd)
        v = v + p["bv"].astype(dt).reshape(KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:  # rope (None => learned/absolute handled by caller)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q: (B,Sq,H,hd)  k/v: (B,Sk,KV,hd). GQA via head grouping."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Sq, KV, G, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    if cfg.logits_softcap:
        scores = cfg.logits_softcap * jnp.tanh(scores / cfg.logits_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def causal_mask(Sq: int, Sk: int, offset: int = 0):
    """mask[i, j] = query (offset+i) may attend key j."""
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Sk)[None, :]
    return (kj <= qi)[None, None, None, :, :]  # (1,1,1,Sq,Sk) for bkgqs scores


def _sdpa_chunked(q, k, v, cfg, *, causal: bool = True, offset: int = 0):
    """Blocked attention: lax.scan over q-row blocks, scores for one block at
    a time — peak scores memory (B,KV,G,bq,Sk) instead of (...,Sq,Sk). The
    XLA stand-in for the Pallas flash kernel at 32k+ sequence (and its
    sharding/collective twin in the dry-run).

    q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(cfg.attn_q_block, Sq)
    nb = Sq // bq
    rem = Sq - nb * bq
    scale = hd ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def block(qb, qstart):
        """qb: (B,bq',KV,G,hd) -> (B,bq',H,hd)"""
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb.astype(jnp.float32), kf) * scale
        if cfg.logits_softcap:
            s = cfg.logits_softcap * jnp.tanh(s / cfg.logits_softcap)
        if causal:
            rows = offset + qstart + jnp.arange(qb.shape[1])[:, None]
            cols = jnp.arange(Sk)[None, :]
            s = jnp.where((cols <= rows)[None, None, None],
                          s, jnp.finfo(jnp.float32).min)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bskh->bqkgh", w, vf).astype(q.dtype)

    qg = q.reshape(B, Sq, KV, G, hd)
    main = qg[:, : nb * bq].reshape(B, nb, bq, KV, G, hd)

    # remat per block: without it the scan saves every block's (bq, Sk)
    # softmax weights for backward — O(Sq*Sk) again, defeating the blocking.
    block = jax.checkpoint(block, static_argnums=())

    def body(_, xs):
        qb, i = xs
        return None, block(qb, i * bq)

    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(main, 1, 0), jnp.arange(nb)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nb * bq, H, hd)
    if rem:
        tail = block(qg[:, nb * bq:], nb * bq)
        out = jnp.concatenate([out, tail.reshape(B, rem, H, hd)], axis=1)
    return out


def attention(x, p, cfg, positions=None, mask=None, kv_cache=None, cache_pos=None,
              kv_override=None):
    """Full attention block body (no residual / norm).

    kv_cache: optional dict {"k": (B,Smax,KV,hd), "v": ...} — decode mode:
      new k/v written at cache_pos, attention over the whole cache.
    kv_override: (k, v) precomputed — cross-attention (whisper decoder).
    Returns (out, new_cache).
    """
    dt = x.dtype

    def use_chunked(Sq):
        return (cfg.attention_impl == "chunked"
                and Sq >= 2 * cfg.attn_q_block)

    if kv_override is not None:
        B, Sq = x.shape[:2]
        H, hd = cfg.num_heads, cfg.head_dim
        q = (x @ p["wq"].astype(dt)).reshape(B, Sq, H, hd)
        if cfg.qkv_bias:
            q = q + p["bq"].astype(dt).reshape(H, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k, v = kv_override
        if use_chunked(Sq) and mask is None:
            out = _sdpa_chunked(q, k, v, cfg, causal=False)
        else:
            out = _sdpa(q, k, v, mask, cfg)
        return (out.reshape(B, Sq, H * hd) @ p["wo"].astype(dt)), None

    q, k, v = _project_qkv(x, p, cfg, positions)
    new_cache = None
    if kv_cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        Smax = ck.shape[1]
        if use_chunked(q.shape[1]) and mask is None and cfg.causal:
            out = _sdpa_chunked(q, ck.astype(dt), cv.astype(dt), cfg,
                                causal=True, offset=cache_pos)
        else:
            if mask is None:
                mask = causal_mask(q.shape[1], Smax, offset=cache_pos)
            out = _sdpa(q, ck.astype(dt), cv.astype(dt), mask, cfg)
    else:
        if use_chunked(q.shape[1]) and mask is None and cfg.causal:
            out = _sdpa_chunked(q, k, v, cfg, causal=True)
        else:
            if mask is None and cfg.causal:
                mask = causal_mask(q.shape[1], k.shape[1])
            out = _sdpa(q, k, v, mask, cfg)
    B, Sq = x.shape[:2]
    out = out.reshape(B, Sq, cfg.num_heads * cfg.head_dim) @ p["wo"].astype(dt)
    return out, new_cache


def cross_kv(enc, p, cfg):
    """Precompute cross-attention K/V from encoder output (whisper)."""
    B, S, _ = enc.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    dt = enc.dtype
    k = (enc @ p["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = (enc @ p["wv"].astype(dt)).reshape(B, S, KV, hd)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt).reshape(KV, hd)
        v = v + p["bv"].astype(dt).reshape(KV, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v
