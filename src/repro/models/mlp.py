"""A plain MLP image classifier — the second real federated workload.

Same input/output contract as models/lenet.py (cifarlike (B, 32, 32, C)
images -> logits), but pure dense layers over the flattened pixels, with
the hidden widths of LeNet-5's FC head (120, 84). Exists to prove the
``BatchedMLBackend`` fused train+push path is model-agnostic: a different
pytree structure (3 dense layers, no conv leaves) through the same jitted
cohort programs and the same Pallas apply kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_mlp(key, num_classes: int = 10, in_channels: int = 3,
             image_hw: int = 32, hidden=(120, 84)):
    d_in = image_hw * image_hw * in_channels
    dims = (d_in,) + tuple(hidden) + (num_classes,)
    ks = jax.random.split(key, len(dims) - 1)

    def fc_init(k, din, dout):
        return din ** -0.5 * jax.random.truncated_normal(k, -3, 3, (din, dout))

    return {
        f"fc{i + 1}": {"w": fc_init(ks[i], dims[i], dims[i + 1]),
                       "b": jnp.zeros(dims[i + 1])}
        for i in range(len(dims) - 1)
    }


def mlp_logits(params, images):
    """images: (B, H, W, C) float32 -> logits (B, num_classes)."""
    x = images.reshape(images.shape[0], -1)
    n = len(params)
    for i in range(1, n):
        p = params[f"fc{i}"]
        x = jax.nn.relu(x @ p["w"] + p["b"])
    p = params[f"fc{n}"]
    return x @ p["w"] + p["b"]


def mlp_loss(params, batch):
    logits = mlp_logits(params, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, {"loss": nll, "accuracy": acc}
