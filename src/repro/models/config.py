"""Model configuration for every architecture family in the zoo.

One frozen dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM
families; family-specific fields default to "off".  Exact assigned configs
live in ``repro.configs.<arch_id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0          # 0 => attention-free (pure SSM)
    num_kv_heads: int = 0
    d_ff: int = 0               # dense MLP hidden (per-expert hidden for MoE)
    vocab_size: int = 0
    head_dim: int = 0           # 0 => d_model // num_heads

    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    causal: bool = True
    attention_impl: str = "xla"   # xla (einsum) | chunked (blocked online-
                                  # softmax, fits 32k+) | flash (pallas, TPU)
    attn_q_block: int = 512       # q-block rows for the chunked impl

    # --- MLP / norm options -------------------------------------------------
    mlp_act: str = "silu"         # silu (SwiGLU) | gelu (plain GELU MLP)
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "dense"       # dense (one-hot einsum) | sorted (capacity gather)
    moe_dispatch_chunk: int = 4096  # sorted dispatch row length: long
                                  # sequences are split into chunks so the
                                  # (E, C, D) gather buffers stay bounded
    router_norm_topk: bool = True

    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_kernel: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (zamba2-style shared attention blocks) -----------------------
    hybrid_period: int = 0        # insert a shared attn block every k ssm layers
    num_shared_blocks: int = 0    # number of distinct shared blocks (alternating)

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper mel-frame count after conv frontend
    encoder_causal: bool = False

    # --- VLM ------------------------------------------------------------------
    num_vision_tokens: int = 0    # stubbed ViT patch embeddings prepended

    # --- numerics --------------------------------------------------------------
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"

    # --- training-step options ---------------------------------------------------
    remat: str = "full"           # none | full  (activation checkpoint per layer)
    logits_softcap: float = 0.0
    scan_layers: bool = True      # False => python-unrolled stacks. Used by the
                                  # dry-run flop calibration (XLA CPU cost
                                  # analysis counts while bodies once) and by
                                  # hillclimb experiments; semantics identical.
    parallel_layout: str = "tp"   # tp: weights sharded over "model" (the
                                  # default); dp: weights replicated and the
                                  # batch sharded over EVERY mesh axis — the
                                  # winning layout for sub-1B archs whose TP
                                  # activation psums dominate the roofline.
    shard_activations: bool = False  # sequence parallelism: constrain the
                                  # residual stream's seq dim onto "model"
                                  # between layers (norms are free under SP;
                                  # GSPMD inserts the gather before attention)
                                  # — shrinks the per-device remat stack L x
                                  # (B,S,D) by the TP degree.

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("ssm", "hybrid") and not self.ssm_state:
            raise ValueError(f"{self.name}: ssm family requires ssm_state")
        if self.family == "moe" and not self.num_experts:
            raise ValueError(f"{self.name}: moe family requires num_experts")

    # Derived quantities -----------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1) in sequence length (SSM recurrence)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (exact for our zoo definitions)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.head_dim
        n = V * D  # embed
        if not self.tie_embeddings:
            n += D * V  # lm_head

        def attn_params() -> int:
            a = D * self.num_heads * hd + D * self.num_kv_heads * hd * 2
            a += self.num_heads * hd * D  # o_proj
            if self.qkv_bias:
                a += (self.num_heads + 2 * self.num_kv_heads) * hd
            if self.qk_norm:
                a += 2 * hd
            return a

        def mlp_params(f: int) -> int:
            if self.mlp_act == "silu":
                return 3 * D * f
            return 2 * D * f + f + D   # plain MLP carries biases

        norm = 2 * D if self.norm_type == "layernorm" else D

        def ssm_params() -> int:
            di, g, s, nh = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_nheads
            p = D * (2 * di + 2 * g * s + nh)            # in_proj (z,x,B,C,dt)
            p += (self.ssm_conv_kernel + 1) * (di + 2 * g * s)  # conv w + b
            p += nh * 3                                   # A_log, D_skip, dt_bias
            p += di                                       # gated norm
            p += di * D                                   # out_proj
            return p

        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + mlp_params(F) + 2 * norm
            n += L * per_layer
        elif self.family == "moe":
            expert = 3 * D * F  # SwiGLU experts
            per_layer = attn_params() + D * self.num_experts + self.num_experts * expert + 2 * norm
            n += L * per_layer
        elif self.family == "ssm":
            n += L * (ssm_params() + norm)
        elif self.family == "hybrid":
            n += L * (ssm_params() + norm)
            shared = attn_params() + mlp_params(F) + 2 * norm
            n += self.num_shared_blocks * shared
        elif self.family == "audio":
            enc_layer = attn_params() + mlp_params(F) + 2 * norm
            dec_layer = 2 * attn_params() + mlp_params(F) + 3 * norm  # self+cross
            n += self.encoder_layers * enc_layer + L * dec_layer
            n += self.encoder_seq * D  # learned encoder positions
            n += norm                  # encoder final norm
        n += norm  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        expert = 3 * self.d_model * self.d_ff
        inactive = self.num_layers * (self.num_experts - self.num_experts_per_tok) * expert
        return self.param_count() - inactive
