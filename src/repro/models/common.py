"""Shared pure-JAX building blocks: init, norms, RoPE, dense layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32):
    """Fan-in scaled truncated-normal init (matches common LM practice)."""
    std = in_dim ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, (in_dim, out_dim))).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    """dim^-0.5-scaled: keeps tied-embedding logits O(1) at init."""
    std = dim ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, (vocab, dim))).astype(dtype)


def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, cfg):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def norm_params(dim: int, cfg, dtype=jnp.float32):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    return {"scale": jnp.zeros((dim,), dtype)}  # rmsnorm stores (scale-1)


# --------------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTS = {"silu": jax.nn.silu, "gelu": gelu}
