"""Parameter & activation PartitionSpec rules.

Rules are path-based over the param pytree produced by ``build_model(cfg)``.
Divisibility-aware: a tensor dim is sharded over "model" only when evenly
divisible (non-divisible cases — e.g. whisper's 20 heads, granite's 49155
vocab — are replicated rather than padded, so roofline FLOPs stay honest).

DP axes: batch dims shard over ("pod","data") when the pod axis exists,
else ("data",).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _msize(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _div(n: int, m: int) -> bool:
    return n > 0 and n % m == 0


def param_pspecs(cfg, params_shape, mesh: Mesh):
    """Tree of PartitionSpec matching the params shape tree (from eval_shape)."""
    m = _msize(mesh)

    def spec_for(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        keys = [k for k in keys if isinstance(k, str)]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        nd = len(shape)
        lead = nd - 2  # stacked-layer leading dims (L,) or (G,P)/(E,) etc.

        def with_lead(*tail):
            return P(*([None] * (nd - len(tail)) + list(tail)))

        # embeddings / heads ------------------------------------------------
        if name == "embed":
            return P("model", None) if _div(shape[0], m) else P(None, None)
        if name == "lm_head":
            return P(None, "model") if _div(shape[1], m) else P(None, None)
        if name == "enc_pos":
            return P(None, None)

        # attention ----------------------------------------------------------
        if name == "wq":
            return with_lead(None, "model") if _div(cfg.num_heads, m) else with_lead(None, None)
        if name in ("wk", "wv"):
            return with_lead(None, "model") if _div(cfg.num_kv_heads, m) else with_lead(None, None)
        if name == "wo":
            return with_lead("model", None) if _div(cfg.num_heads, m) else with_lead(None, None)
        if name == "bq":
            return with_lead("model") if _div(cfg.num_heads, m) else with_lead(None)
        if name in ("bk", "bv"):
            return with_lead("model") if _div(cfg.num_kv_heads, m) else with_lead(None)

        # MoE ------------------------------------------------------------------
        if name == "router":
            return with_lead(None, None)
        if "moe" in keys and name in ("w_gate", "w_up"):   # (.., E, D, F)
            return with_lead(None, None, "model") if _div(cfg.d_ff, m) else with_lead(None, None, None)
        if "moe" in keys and name == "w_down":             # (.., E, F, D)
            return with_lead(None, "model", None) if _div(cfg.d_ff, m) else with_lead(None, None, None)

        # dense MLP ---------------------------------------------------------------
        if name in ("w_gate", "w_up"):
            return with_lead(None, "model") if _div(cfg.d_ff, m) else with_lead(None, None)
        if name == "w_down":
            return with_lead("model", None) if _div(cfg.d_ff, m) else with_lead(None, None)
        if name == "b_up":
            return with_lead("model") if _div(cfg.d_ff, m) else with_lead(None)

        # SSM -------------------------------------------------------------------------
        if name in ("w_z", "w_x"):
            return with_lead(None, "model") if _div(cfg.ssm_nheads, m) else with_lead(None, None)
        if name == "w_dt":
            return with_lead(None, "model") if _div(cfg.ssm_nheads, m) else with_lead(None, None)
        if name == "w_out":
            return with_lead("model", None) if _div(cfg.ssm_nheads, m) else with_lead(None, None)
        if name in ("w_B", "w_C", "conv_w", "conv_b", "A_log", "D_skip",
                    "dt_bias"):
            return P(*([None] * nd))
        if name == "norm" and "ssm" in keys:
            return with_lead("model") if _div(cfg.ssm_nheads, m) else with_lead(None)

        # norms / everything else: replicated
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def apply_fsdp(specs, shapes, mesh: Mesh, min_size: int = 1 << 20):
    """ZeRO/FSDP post-pass: for every large leaf, additionally shard one
    not-yet-sharded dim over the dp axes (weights are all-gathered by GSPMD
    just before use; grads reduce-scattered). Makes the 20B–76B configs fit
    HBM: param/momentum bytes scale 1/(model x data) instead of 1/model.

    Picks the largest eligible dim divisible by the dp-axis product."""
    dp = dp_axes(mesh)
    dpn = _prod_dp(mesh)

    def upgrade(spec, leaf):
        if leaf.size < min_size:
            return spec
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        best, best_size = None, 0
        for i, (ax, n) in enumerate(zip(dims, leaf.shape)):
            if ax is None and n % dpn == 0 and n > best_size:
                best, best_size = i, n
        if best is None:
            return spec
        dims[best] = dp if len(dp) > 1 else dp[0]
        return P(*dims)

    return jax.tree.map(upgrade, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(cfg, cache_shape, mesh: Mesh):
    """KV/SSM cache specs. KV heads shard over "model" when divisible,
    otherwise the *sequence* axis of the cache shards over "model"
    (flash-decode style distributed attention, XLA-managed)."""
    m = _msize(mesh)
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        keys = [k for k in keys if isinstance(k, str)]
        name = keys[-1] if keys else ""
        shape = leaf.shape
        if name == "pos":
            return P()
        if name in ("k", "v"):                   # (L, B, Smax, KV, hd)
            batch_ok = _div(shape[1], _prod_dp(mesh))
            batch_ax = dp if batch_ok else None
            # When batch can't shard (e.g. long-context B=1), shard the
            # sequence axis over the data axes instead.
            seq_ax = None if batch_ok else (dp if _div(shape[2], _prod_dp(mesh)) else None)
            if _div(cfg.num_kv_heads, m):
                return P(None, batch_ax, seq_ax, "model", None)
            # non-divisible KV heads: flash-decode style seq sharding on
            # model — if the seq extent divides (whisper's 1500-frame cross
            # cache does not: stays replicated on "model").
            seq_mult = (1 if seq_ax is None else _prod_dp(mesh)) * m
            if _div(shape[2], seq_mult):
                seq_model = ("model",) if seq_ax is None \
                    else tuple(list(seq_ax) + ["model"])
                return P(None, batch_ax, seq_model, None, None)
            return P(None, batch_ax, seq_ax, None, None)
        if name == "conv":                       # (L, B, K-1, Ch)
            return P(None, dp if _div(shape[1], _prod_dp(mesh)) else None, None, None)
        if name == "ssd":                        # (L, B, nh, s, p)
            batch_ax = dp if _div(shape[1], _prod_dp(mesh)) else None
            heads_ax = "model" if _div(cfg.ssm_nheads, m) else None
            return P(None, batch_ax, heads_ax, None, None)
        if name == "enc":                        # (B, Senc, D)
            return P(dp if _div(shape[0], _prod_dp(mesh)) else None, None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def _prod_dp(mesh: Mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def batch_pspecs(cfg, batch_shape, mesh: Mesh):
    dp = mesh.axis_names if getattr(cfg, "parallel_layout", "tp") == "dp" \
        else dp_axes(mesh)

    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]

    def spec_for(path, leaf):
        nd = len(leaf.shape)
        if leaf.shape and _div(leaf.shape[0], dpn):
            return P(dp, *([None] * (nd - 1)))
        # microbatched (M, mb, ...) batches and long-context (1, seq, ...)
        # inputs: shard the second dim over dp instead.
        if nd >= 2 and _div(leaf.shape[1], dpn):
            return P(None, dp, *([None] * (nd - 2)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def named(tree_of_pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
