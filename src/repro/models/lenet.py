"""LeNet-5 — the model the paper trains on CIFAR-10 (Sec. VI: DL4J LeNet-5).

Used by the paper-faithful federated simulation tier (25 clients, batch 20).
Pure JAX; ~2.5 MB of parameters matching the paper's reported model size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_lenet(key, num_classes: int = 10, in_channels: int = 3):
    ks = jax.random.split(key, 5)

    def conv_init(k, kh, kw, cin, cout):
        std = (kh * kw * cin) ** -0.5
        return std * jax.random.truncated_normal(k, -3, 3, (kh, kw, cin, cout))

    def fc_init(k, din, dout):
        return din ** -0.5 * jax.random.truncated_normal(k, -3, 3, (din, dout))

    return {
        "conv1": {"w": conv_init(ks[0], 5, 5, in_channels, 6), "b": jnp.zeros(6)},
        "conv2": {"w": conv_init(ks[1], 5, 5, 6, 16), "b": jnp.zeros(16)},
        "fc1": {"w": fc_init(ks[2], 16 * 5 * 5, 120), "b": jnp.zeros(120)},
        "fc2": {"w": fc_init(ks[3], 120, 84), "b": jnp.zeros(84)},
        "fc3": {"w": fc_init(ks[4], 84, num_classes), "b": jnp.zeros(num_classes)},
    }


def _conv(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def lenet_logits(params, images):
    """images: (B, 32, 32, C) float32 -> logits (B, num_classes)."""
    x = jax.nn.relu(_conv(images, params["conv1"]))   # (B,28,28,6)
    x = _pool(x)                                      # (B,14,14,6)
    x = jax.nn.relu(_conv(x, params["conv2"]))        # (B,10,10,16)
    x = _pool(x)                                      # (B,5,5,16)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


def lenet_loss(params, batch):
    logits = lenet_logits(params, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, {"loss": nll, "accuracy": acc}


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
