"""Model assembly: embeddings, scanned layer stacks, LM / enc-dec heads.

Every architecture family exposes the same functional API via ``build_model``:

    model.init(key)                          -> params pytree
    model.loss(params, batch)                -> (scalar, metrics)
    model.prefill(params, batch)             -> (last_logits, cache)
    model.decode_step(params, cache, batch)  -> (logits, cache)
    model.init_cache(batch, max_seq)         -> cache pytree (zeros)

Layer stacks are ``lax.scan`` over stacked parameters so the HLO size is
independent of depth — essential for compiling 80-layer configs quickly.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .attention import attention, cross_kv, init_attention
from .common import apply_norm, dense_init, embed_init, norm_params
from .config import ModelConfig
from .moe import init_mlp, init_moe, mlp, moe
from .ssm import init_ssm, init_ssm_state, ssm_block, ssm_decode_step


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]


# ------------------------------------------------------------------ blocks
def transformer_block(x, p, cfg, positions=None, mask=None, kv_cache=None,
                      cache_pos=None, cross=None):
    """Pre-norm residual block. Returns (x, new_kv_cache, aux)."""
    h = apply_norm(x, p["ln1"], cfg)
    a, new_cache = attention(h, p["attn"], cfg, positions=positions, mask=mask,
                             kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + a
    if cross is not None:  # whisper decoder cross-attention
        h = apply_norm(x, p["ln_x"], cfg)
        a, _ = attention(h, p["cross_attn"], cfg, positions=None, mask=None,
                         kv_override=cross)
        x = x + a
    h = apply_norm(x, p["ln2"], cfg)
    aux = jnp.float32(0.0)
    if cfg.family == "moe":
        m, aux = moe(h, p["moe"], cfg)
    else:
        m = mlp(h, p["mlp"], cfg)
    return x + m, new_cache, aux


def init_transformer_block(key, cfg, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {"ln1": norm_params(cfg.d_model, cfg),
         "attn": init_attention(ks[0], cfg),
         "ln2": norm_params(cfg.d_model, cfg)}
    if cross:
        p["ln_x"] = norm_params(cfg.d_model, cfg)
        p["cross_attn"] = init_attention(ks[1], cfg)
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[2], cfg)
    else:
        p["mlp"] = init_mlp(ks[3], cfg)
    return p


def mamba_layer(x, p, cfg, state=None):
    h = apply_norm(x, p["ln1"], cfg)
    out, new_state = ssm_block(h, p["ssm"], cfg, state=state)
    return x + out, new_state


def init_mamba_layer(key, cfg):
    return {"ln1": norm_params(cfg.d_model, cfg), "ssm": init_ssm(key, cfg)}


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _shard_seq(x, cfg):
    """Layer-boundary sharding constraint on the residual stream (B, S, D).

    Two jobs:
    1. PIN GSPMD's propagation: without an anchor at every layer boundary,
       the partitioner may pick different strategies for different depths
       (observed: a 1-layer unrolled variant costing MORE per device than a
       2-layer one) and insert resharding all-gather/permute churn between
       layers. Pinned boundaries make per-layer cost uniform — which the
       dry-run's depth-extrapolation relies on.
    2. Sequence parallelism (cfg.shard_activations): put S on "model"
       between layers — norms are elementwise over D so SP is free, the
       remat stack shrinks by the TP degree, and GSPMD gathers S only in
       front of attention (Megatron-SP pattern).

    No-op outside a mesh context (host tests)."""
    from repro.launch.mesh import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return x
    from jax.sharding import PartitionSpec as P
    if cfg.parallel_layout == "dp":
        dp = tuple(mesh.axis_names)
    else:
        dp = tuple(a for a in mesh.axis_names if a != "model")
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    batch_ax = dp if (x.shape[0] % max(dpn, 1) == 0 and dpn > 1) else None
    seq_ax = "model" if (cfg.shard_activations
                         and x.shape[1] % mesh.shape["model"] == 0) else None
    return jax.lax.with_sharding_constraint(x, P(batch_ax, seq_ax, None))


def _scan(body, init, xs, cfg):
    """lax.scan, or a python-unrolled equivalent when cfg.scan_layers=False.

    The unrolled path consumes the SAME stacked params (slicing the leading
    layer dim) so shardings/init are identical; it exists because XLA's CPU
    cost analysis counts while-loop bodies once — the dry-run lowers small
    unrolled variants to calibrate exact per-layer flop/byte/collective
    counts (launch/dryrun.py)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    if ys and jax.tree.structure(ys[0]).num_leaves:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# ------------------------------------------------------------------ stacks
def _stacked_init(init_one, key, n):
    return jax.vmap(init_one)(jax.random.split(key, n))


def _read_layer(cache, idx):
    return jax.tree.map(
        lambda t: jax.lax.dynamic_index_in_dim(t, idx, 0, keepdims=False),
        cache)


def _write_layer(cache, new_layer, idx):
    return jax.tree.map(
        lambda t, n: jax.lax.dynamic_update_index_in_dim(
            t, n.astype(t.dtype), idx, 0), cache, new_layer)


def dense_stack(x, layers_p, cfg, positions=None, cache=None, cache_pos=None):
    """Scan over transformer layers. cache: None or {"k","v"} with leading L.

    The cache travels in the scan CARRY (updated via dynamic-update-slice at
    the layer index) rather than as scan xs/ys — the while-loop state is
    aliased in place by XLA, so together with jit donation the serving
    cache exists exactly once in HBM."""

    if cache is None:
        def body(carry, p):
            x = _shard_seq(carry, cfg)
            x, _, aux = transformer_block(x, p, cfg, positions=positions)
            return x, aux

        body = _maybe_remat(body, cfg)
        x, auxs = _scan(body, x, layers_p, cfg)
        return x, None, jnp.sum(auxs)

    L = jax.tree.leaves(layers_p)[0].shape[0]

    def body(carry, xs):
        x, cache_all = carry
        x = _shard_seq(x, cfg)
        p, idx = xs
        x, new_layer, aux = transformer_block(
            x, p, cfg, positions=positions,
            kv_cache=_read_layer(cache_all, idx), cache_pos=cache_pos)
        return (x, _write_layer(cache_all, new_layer, idx)), aux

    body = _maybe_remat(body, cfg)
    (x, new_cache), auxs = _scan(body, (x, cache),
                                 (layers_p, jnp.arange(L)), cfg)
    return x, new_cache, jnp.sum(auxs)


def ssm_stack(x, layers_p, cfg, states=None):
    if states is None:
        def body(carry, p):
            x = _shard_seq(carry, cfg)
            x, _ = mamba_layer(x, p, cfg, state=None)
            return x, jnp.float32(0.0)

        body = _maybe_remat(body, cfg)
        x, _ = _scan(body, x, layers_p, cfg)
        return x, None

    L = jax.tree.leaves(layers_p)[0].shape[0]

    def body(carry, xs):
        x, states_all = carry
        p, idx = xs
        x, new_st = mamba_layer(_shard_seq(x, cfg), p, cfg,
                                state=_read_layer(states_all, idx))
        return (x, _write_layer(states_all, new_st, idx)), None

    body = _maybe_remat(body, cfg)
    (x, new_states), _ = _scan(body, (x, states),
                               (layers_p, jnp.arange(L)), cfg)
    return x, new_states


def ssm_decode_stack(x, layers_p, cfg, states):
    L = jax.tree.leaves(layers_p)[0].shape[0]

    def body(carry, xs):
        x, states_all = carry
        x = _shard_seq(x, cfg)
        p, idx = xs
        h = apply_norm(x, p["ln1"], cfg)
        out, new_st = ssm_decode_step(h, p["ssm"], cfg,
                                      _read_layer(states_all, idx))
        return (x + out, _write_layer(states_all, new_st, idx)), None

    (x, new_states), _ = _scan(body, (x, states),
                               (layers_p, jnp.arange(L)), cfg)
    return x, new_states


def hybrid_stack(x, params, cfg, positions=None, ssm_states=None,
                 attn_cache=None, cache_pos=None, decode=False):
    """zamba2-style: groups of `hybrid_period` mamba layers, each followed by
    one of `num_shared_blocks` shared attention blocks (cycled)."""
    L, P = cfg.num_layers, cfg.hybrid_period
    G = L // P
    grp = lambda t: t.reshape((G, P) + t.shape[1:])
    mamba_p = jax.tree.map(grp, params["mamba"])

    ssm_grouped = jax.tree.map(grp, ssm_states) if ssm_states is not None \
        else None

    def group_body(carry, xs):
        x, ssm_all, attn_all = carry
        x = _shard_seq(x, cfg)
        gi = xs["idx"]
        if decode:
            x, new_g = ssm_decode_stack(x, xs["mamba"], cfg,
                                        _read_layer(ssm_all, gi))
            ssm_all = _write_layer(ssm_all, new_g, gi)
        elif ssm_all is not None:
            x, new_g = ssm_stack(x, xs["mamba"], cfg,
                                 states=_read_layer(ssm_all, gi))
            ssm_all = _write_layer(ssm_all, new_g, gi)
        else:
            x, _ = ssm_stack(x, xs["mamba"], cfg, states=None)
        shared_p = jax.tree.map(
            lambda v: jax.lax.dynamic_index_in_dim(v, gi % cfg.num_shared_blocks,
                                                   keepdims=False),
            params["shared"])
        kv = _read_layer(attn_all, gi) if attn_all is not None else None
        x, new_kv, _ = transformer_block(x, shared_p, cfg, positions=positions,
                                         kv_cache=kv, cache_pos=cache_pos)
        if attn_all is not None:
            attn_all = _write_layer(attn_all, new_kv, gi)
        return (x, ssm_all, attn_all), None

    group_body = _maybe_remat(group_body, cfg)
    xs = {"idx": jnp.arange(G), "mamba": mamba_p}
    (x, new_ssm_g, new_attn), _ = _scan(
        group_body, (x, ssm_grouped, attn_cache), xs, cfg)
    new_ssm = None
    if new_ssm_g is not None:
        new_ssm = jax.tree.map(lambda t: t.reshape((G * P,) + t.shape[2:]),
                               new_ssm_g)
    return x, new_ssm, new_attn


# ------------------------------------------------------------------ LM heads
def _lm_logits(x, params, cfg):
    x = apply_norm(x, params["final_norm"], cfg)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return logits
    # (a vocab-sharding constraint on the logits was tried here and REVERTED:
    # the measured HBM term got worse — GSPMD resharding around the pinned
    # logits outweighed the replication it removed; see EXPERIMENTS.md §Perf)


def cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) any dtype; labels (B,S) int. Returns mean NLL (f32).

    take_along_axis (a gather) picks the true logit — materializing a
    (B,S,V) f32 one-hot costs a full extra logits-sized HBM round-trip,
    which dominated the memory roofline of small-model train cells."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - true_logit
    if mask is None:
        mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
