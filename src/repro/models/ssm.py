"""Mamba2 (SSD — state-space duality) block, pure JAX.

Chunked SSD algorithm (arXiv:2405.21060): within a chunk the recurrence is
computed as masked quadratic attention-like matmuls (MXU-friendly); across
chunks a cheap ``lax.scan`` carries the (heads, dstate, head_dim) state.
Decode is an O(1)-per-token recurrence over the same state, which is what
makes the ``long_500k`` shape feasible for the ssm/hybrid archs.

The Pallas TPU kernel (`repro.kernels.ssd_scan`) implements the intra-chunk
portion with VMEM tiling; this module is the XLA reference/default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm


def init_ssm(key, cfg):
    D, di = cfg.d_model, cfg.d_inner
    g, s, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    K = cfg.ssm_conv_kernel
    ks = jax.random.split(key, 7)
    return {
        "w_z": dense_init(ks[0], D, di),
        "w_x": dense_init(ks[1], D, di),
        "w_B": dense_init(ks[2], D, g * s),
        "w_C": dense_init(ks[3], D, g * s),
        "w_dt": dense_init(ks[4], D, nh),
        "conv_w": (K ** -0.5) * jax.random.normal(ks[5], (K, di + 2 * g * s)),
        "conv_b": jnp.zeros((di + 2 * g * s,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),        # A in [-16, -1]
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01))),  # softplus^-1(0.01)
        "norm": jnp.zeros((di,), jnp.float32),
        "w_out": dense_init(ks[6], di, D),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d.  xBC: (B, S, Ch), w: (K, Ch)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    # depthwise conv as sum of shifted scaled copies (K is 4; cheap & fusible)
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :].astype(out.dtype)


def _project(x, p, cfg):
    dt_ = x.dtype
    z = x @ p["w_z"].astype(dt_)
    xin = x @ p["w_x"].astype(dt_)
    Bp = x @ p["w_B"].astype(dt_)
    Cp = x @ p["w_C"].astype(dt_)
    dt_raw = x @ p["w_dt"].astype(dt_)
    return z, xin, Bp, Cp, dt_raw


def ssd_chunked(X, dtv, A, Bh, Ch, chunk: int, init_state=None):
    """Chunked SSD scan.

    X: (B,S,nh,p) inputs; dtv: (B,S,nh) softplus'd dt; A: (nh,) negative;
    Bh/Ch: (B,S,nh,s) per-head (group-broadcast) SSM B/C.
    Returns y: (B,S,nh,p) and final state (B,nh,s,p).
    """
    B_, S, nh, ph = X.shape
    s = Bh.shape[-1]
    S0 = S
    if S % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and increment dt*B*x=0, so
        # padding is state-neutral; padded y rows are sliced off below.
        pad = chunk - S % chunk
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        X, dtv, Bh, Ch = z(X), z(dtv), z(Bh), z(Ch)
        S = S + pad
    nc = S // chunk
    rs = lambda t: t.reshape((B_, nc, chunk) + t.shape[2:])
    Xc, dtc, Bc, Cc = rs(X), rs(dtv), rs(Bh), rs(Ch)

    l = (dtc.astype(jnp.float32) * A)                          # (B,nc,Q,nh) <= 0
    cum = jnp.cumsum(l, axis=2)
    # ---- intra-chunk (quadratic within chunk, MXU matmuls) ----
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,t,u,nh)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bctns,bcuns->bctun", Cc, Bc).astype(jnp.float32) * M
    dX = (dtc[..., None] * Xc).astype(jnp.float32)              # (B,nc,Q,nh,p)
    Y_intra = jnp.einsum("bctun,bcunp->bctnp", scores, dX)

    # ---- chunk states ----
    decay_end = jnp.exp(cum[:, :, -1, :][:, :, None, :] - cum)  # (B,nc,Q,nh)
    S_chunk = jnp.einsum("bcuns,bcunp,bcun->bcnsp", Bc.astype(jnp.float32), dX, decay_end)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,nh)

    # ---- inter-chunk scan ----
    if init_state is None:
        init_state = jnp.zeros((B_, nh, s, ph), jnp.float32)

    def step(carry, inp):
        dec, Sc = inp                                            # (B,nh), (B,nh,s,p)
        prev = carry
        new = dec[:, :, None, None] * prev + Sc
        return new, prev

    final, S_prev = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_chunk, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                          # (B,nc,nh,s,p)
    Y_inter = jnp.einsum("bctns,bcnsp,bctn->bctnp",
                         Cc.astype(jnp.float32), S_prev, jnp.exp(cum))
    y = (Y_intra + Y_inter).reshape(B_, S, nh, ph)[:, :S0]
    return y.astype(X.dtype), final


def ssm_block(x, p, cfg, state=None):
    """Full Mamba2 block (no residual). x: (B,S,D).

    state: None for training; {"conv": (B,K-1,Ch), "ssd": (B,nh,s,p)} for
    prefill-continuation. Returns (out, new_state or None).
    """
    B_, S, D = x.shape
    g, s, nh, ph = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    di = cfg.d_inner
    z, xin, Bp, Cp, dt_raw = _project(x, p, cfg)
    xBC = jnp.concatenate([xin, Bp, Cp], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"]))
    xin, Bp, Cp = jnp.split(xBC, [di, di + g * s], axis=-1)

    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    X = xin.reshape(B_, S, nh, ph)
    hpg = nh // g
    Bh = jnp.repeat(Bp.reshape(B_, S, g, s), hpg, axis=2)
    Ch = jnp.repeat(Cp.reshape(B_, S, g, s), hpg, axis=2)

    init_state = state["ssd"] if state is not None else None
    y, final = ssd_chunked(X, dtv, A, Bh, Ch, cfg.ssm_chunk, init_state)
    y = y + p["D_skip"].astype(x.dtype)[None, None, :, None] * X
    y = y.reshape(B_, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)

    new_state = None
    if state is not None:
        K = cfg.ssm_conv_kernel
        conv_in = jnp.concatenate([x @ p["w_x"].astype(x.dtype),
                                   x @ p["w_B"].astype(x.dtype),
                                   x @ p["w_C"].astype(x.dtype)], axis=-1)
        new_state = {"conv": conv_in[:, -(K - 1):, :], "ssd": final}
    return out, new_state


def ssm_decode_step(x, p, cfg, state):
    """One-token recurrent decode. x: (B,1,D); state: {"conv","ssd"}."""
    B_, _, D = x.shape
    g, s, nh, ph = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_head_dim
    di, K = cfg.d_inner, cfg.ssm_conv_kernel
    dt_ = x.dtype
    z = x @ p["w_z"].astype(dt_)
    new_in = jnp.concatenate([x @ p["w_x"].astype(dt_), x @ p["w_B"].astype(dt_),
                              x @ p["w_C"].astype(dt_)], axis=-1)        # (B,1,Ch)
    window = jnp.concatenate([state["conv"], new_in], axis=1)            # (B,K,Ch)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    xBC = jax.nn.silu(conv)[:, None, :]                                  # (B,1,Ch)
    xin, Bp, Cp = jnp.split(xBC, [di, di + g * s], axis=-1)

    dt_raw = (x @ p["w_dt"].astype(dt_))[:, 0, :]                        # (B,nh)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dtv * A)                                               # (B,nh)
    X = xin.reshape(B_, nh, ph).astype(jnp.float32)
    hpg = nh // g
    Bh = jnp.repeat(Bp.reshape(B_, g, s), hpg, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cp.reshape(B_, g, s), hpg, axis=1).astype(jnp.float32)

    S_new = dec[:, :, None, None] * state["ssd"] + \
        jnp.einsum("bns,bnp,bn->bnsp", Bh, X, dtv)
    y = jnp.einsum("bns,bnsp->bnp", Ch, S_new) + p["D_skip"][None, :, None] * X
    y = y.reshape(B_, 1, di).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(dt_)
    return out, {"conv": window[:, 1:, :], "ssd": S_new}


def init_ssm_state(cfg, batch: int, dtype=jnp.float32):
    g, s = cfg.ssm_ngroups, cfg.ssm_state
    ch = cfg.d_inner + 2 * g * s
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_kernel - 1, ch), dtype),
        "ssd": jnp.zeros((batch, cfg.ssm_nheads, s, cfg.ssm_head_dim), jnp.float32),
    }
