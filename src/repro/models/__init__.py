from .config import ModelConfig
from .zoo import Model, build_model
from . import sharding

__all__ = ["ModelConfig", "Model", "build_model", "sharding"]
