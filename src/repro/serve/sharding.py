"""Parameter partitioning for the serving tier.

The sharded server stores the global model as ONE flat f32 vector split
into ``n_shards`` contiguous, near-equal slices — the classic parameter-
server layout (each shard worker owns a key range). ``ShardSpec`` is the
bijection between that layout and the model's pytree: it remembers the
treedef, per-leaf shapes/dtypes, and the shard boundaries, so
``flatten``/``unflatten`` round-trip exactly and ``split``/``join`` move
between the flat vector and the per-shard slices.

Placement comes from ``launch/mesh.py``: ``shard_placement`` maps each
logical shard onto a device of a 1-D ``("shard",)`` mesh (round-robin
when there are more shards than devices), so shard-local applies run on
the owning device. On the CPU test host that is one device owning every
shard; on a pod it is the real partition.
"""
from __future__ import annotations

import math
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_serving_mesh, shard_placement

__all__ = ["ShardSpec"]


class ShardSpec:
    """Static description of one model's shard partition.

    ``boundaries[i] : boundaries[i+1]`` is shard ``i``'s slice of the
    flat vector; the last shard absorbs the remainder, and shards may be
    empty when ``n_shards`` exceeds the parameter count (valid, applied
    as zero-size ops).
    """

    def __init__(self, params: Any, n_shards: int, *, mesh=None,
                 place: bool = True):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        leaves, self.treedef = jax.tree.flatten(params)
        if not leaves:
            raise ValueError("cannot shard an empty parameter pytree")
        self.shapes = [tuple(l.shape) for l in leaves]
        self.dtypes = [jnp.asarray(l).dtype for l in leaves]
        self.sizes = [math.prod(s) if s else 1 for s in self.shapes]
        self.total = sum(self.sizes)
        self.n_shards = int(n_shards)
        # near-equal contiguous split (np.array_split semantics)
        base, extra = divmod(self.total, self.n_shards)
        bounds = [0]
        for i in range(self.n_shards):
            bounds.append(bounds[-1] + base + (1 if i < extra else 0))
        self.boundaries = tuple(bounds)
        self.mesh = mesh if mesh is not None else (
            make_serving_mesh(self.n_shards) if place else None)
        self.devices: Optional[list] = (
            shard_placement(self.n_shards, self.mesh) if place else None)

    # ------------------------------------------------------------ pytree <-> flat
    def flatten(self, params: Any) -> jnp.ndarray:
        """Pytree -> one flat f32 vector (serving-tier wire layout)."""
        leaves = jax.tree.leaves(params)
        if len(leaves) != len(self.shapes):
            raise ValueError(
                f"pytree has {len(leaves)} leaves, spec built for "
                f"{len(self.shapes)}")
        return jnp.concatenate(
            [jnp.asarray(l).reshape(-1).astype(jnp.float32)
             for l in leaves])

    def unflatten(self, flat: jnp.ndarray) -> Any:
        """Flat f32 vector -> pytree with the original shapes/dtypes."""
        if flat.shape != (self.total,):
            raise ValueError(
                f"flat vector has shape {flat.shape}, expected "
                f"({self.total},)")
        leaves, off = [], 0
        for shape, dtype, size in zip(self.shapes, self.dtypes, self.sizes):
            leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(self.treedef, leaves)

    # ------------------------------------------------------------ flat <-> shards
    def shard_slice(self, i: int) -> slice:
        return slice(self.boundaries[i], self.boundaries[i + 1])

    def shard_size(self, i: int) -> int:
        return self.boundaries[i + 1] - self.boundaries[i]

    def split(self, flat: jnp.ndarray) -> List[jnp.ndarray]:
        """Flat vector -> per-shard slices, device_put to each shard's
        owning device when placement is enabled."""
        out = []
        for i in range(self.n_shards):
            piece = flat[self.shard_slice(i)]
            if self.devices is not None:
                piece = jax.device_put(piece, self.devices[i])
            out.append(piece)
        return out

    def join(self, slices: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Per-shard slices -> flat vector. Slices live on their owning
        devices, so this is a gather: device_get to the host, then one
        concatenate (the reader-side reassembly cost)."""
        if len(slices) != self.n_shards:
            raise ValueError(
                f"got {len(slices)} slices for {self.n_shards} shards")
        return jnp.asarray(np.concatenate(
            [np.asarray(jax.device_get(s)) for s in slices]))

    # ------------------------------------------------------------ convenience
    def zeros_shards(self) -> List[jnp.ndarray]:
        return self.split(jnp.zeros(self.total, jnp.float32))

    def __repr__(self):
        return (f"ShardSpec(total={self.total}, n_shards={self.n_shards}, "
                f"boundaries={self.boundaries})")
