"""Per-shard wire codecs for push ingestion.

A push travels as one packet per shard; the codec decides what the
packet's payload is. Encoding happens client-side (so stateful codecs
keep their accumulators per ``(client, shard)`` key), decoding happens in
the ingestion pipeline before the shard is staged.

- ``none``   raw f32 slice (4 B/param).
- ``int8``   symmetric int8 quantization of the full slice
             (``optim/compression.int8_quantize``): 1 B/param + one
             scale, error bounded by scale/2 per entry, no base needed.
- ``topk``   top-k sparsified DELTA against the base the client pulled,
             with per-(client, shard) error feedback: ~``ratio`` of the
             slice travels; the decoder reconstructs against the same
             base via the server's version history ring, so the wire
             carries the client's ``base_version``. The EF residual makes
             the compressed push stream converge to the uncompressed
             fixed point (tests/test_compression.py pins the property).

Codecs with ``needs_base=True`` require the decoder to resolve the
client's base slice (history ring lookup, ``ShardedAsyncParameterServer.
base_shard``); a ring miss falls back to the current slice — counted,
approximate, never fatal.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.optim.compression import (TopK, int8_dequantize, int8_quantize,
                                     topk_compress, topk_decompress)

__all__ = ["ShardCodec", "NullCodec", "Int8Codec", "TopKDeltaCodec",
           "resolve_codec", "registered_codecs"]


class ShardCodec:
    """Base codec: ``encode`` runs client-side, ``decode`` server-side.

    ``key`` identifies the (client, shard) stream for stateful codecs;
    ``base`` is the client's pulled base slice (encode) / the ring-
    resolved base slice (decode) and is only consulted when
    ``needs_base`` is set."""

    name: str = ""
    needs_base: bool = False

    def encode(self, key: Tuple[int, int], new: jnp.ndarray,
               base: Optional[jnp.ndarray] = None) -> Any:
        raise NotImplementedError

    def decode(self, payload: Any,
               base: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes(self, payload: Any) -> int:
        """Approximate on-the-wire size of one payload (bench column)."""
        raise NotImplementedError

    def reset(self) -> None:
        """Drop any per-stream accumulator state."""


class NullCodec(ShardCodec):
    name = "none"

    def encode(self, key, new, base=None):
        return jnp.asarray(new, jnp.float32)

    def decode(self, payload, base=None):
        return payload

    def wire_bytes(self, payload):
        return 4 * int(payload.size)


class Int8Codec(ShardCodec):
    name = "int8"

    def encode(self, key, new, base=None):
        return int8_quantize(jnp.asarray(new, jnp.float32))

    def decode(self, payload, base=None):
        q, scale = payload
        return int8_dequantize(q, scale)

    def wire_bytes(self, payload):
        q, _ = payload
        return int(q.size) + 4


class TopKDeltaCodec(ShardCodec):
    """Top-k + error feedback on the delta stream ``new - base``."""

    name = "topk"
    needs_base = True

    def __init__(self, ratio: float = 0.01, min_k: int = 1):
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"ratio must be in (0, 1], got {ratio}")
        self.ratio = float(ratio)
        self.min_k = int(min_k)
        self._residual: Dict[Tuple[int, int], jnp.ndarray] = {}

    def encode(self, key, new, base=None):
        if base is None:
            raise ValueError("topk delta codec needs the pulled base slice")
        new = jnp.asarray(new, jnp.float32)
        delta = new - jnp.asarray(base, jnp.float32)
        r = self._residual.get(key)
        corrected = delta if r is None else delta + r
        size = math.prod(corrected.shape) if corrected.shape else 1
        k = max(int(size * self.ratio), self.min_k)
        payload = topk_compress(corrected, k)
        self._residual[key] = corrected - topk_decompress(payload)
        return payload

    def decode(self, payload: TopK, base=None):
        if base is None:
            raise ValueError("topk delta codec needs the base slice to "
                             "reconstruct (history-ring lookup)")
        return jnp.asarray(base, jnp.float32) + topk_decompress(payload)

    def wire_bytes(self, payload: TopK):
        return 8 * int(payload.values.size)    # 4 B value + 4 B index

    def reset(self):
        self._residual.clear()


_CODECS = {cls.name: cls for cls in (NullCodec, Int8Codec, TopKDeltaCodec)}


def registered_codecs() -> Tuple[str, ...]:
    return tuple(_CODECS)


def resolve_codec(codec: Union[str, ShardCodec, None]) -> ShardCodec:
    if codec is None:
        return NullCodec()
    if isinstance(codec, ShardCodec):
        return codec
    if isinstance(codec, str):
        if codec not in _CODECS:
            raise ValueError(f"unknown codec {codec!r}; expected one of "
                             f"{registered_codecs()} or a ShardCodec")
        return _CODECS[codec]()
    raise ValueError(f"codec must be a name or ShardCodec, got "
                     f"{type(codec).__name__}")
