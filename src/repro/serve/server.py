"""Sharded async parameter server: the datacenter serving tier.

Semantically a twin of ``core/server.AsyncParameterServer`` — same pull /
push protocol, same ``AggregationRule`` application, same Eq. (4)
staleness bookkeeping — but the global model lives as ``n_shards``
contiguous slices of one flat f32 vector (``ShardSpec``), each owned by a
device from the serving mesh, and a push is applied SHARD-LOCAL: one
jitted kernel per shard computes the weighted mix, the momentum update,
and the shard's squared momentum norm in a single fused step on the
shard's device.

Consistency contract (pinned by tests/test_serve.py):

- **Replicated bookkeeping.** The version counter, the Eq. (4) gap
  inputs (``v_norm``), and the lag table are scheduler state, not model
  state — every shard carries its own copy of the version and they must
  agree (``assert_consistent``). A reader's snapshot always pairs a
  version with exactly the shard tuple published at that version.
- **Atomic publish.** A push's shard applies are computed first, then
  committed under the publish lock as one swap of the shard tuple +
  version + ``v_norm``. Readers (``pull``/``snapshot_flat``) take the
  same lock for the duration of a tuple read, so no reader ever observes
  a partially applied push — shard arrays are immutable jax values, so a
  snapshot stays valid after the lock drops.
- **Version history ring.** The last ``history_depth`` published shard
  tuples are retained so delta-coded pushes (``serve/codecs.py``) can be
  reconstructed against the exact base the client pulled. A base that
  aged out falls back to the current params (counted, approximate).

The momentum bookkeeping matches the core server leaf-for-leaf:
``s = (theta_old - theta_new) / eta``, ``v <- beta v + (1-beta) s``,
``v_norm = ||v||_2`` — computed per shard and reduced, so the serving
tier's gap estimates agree with the simulator's to float tolerance.

``kernel="pallas"`` swaps the jitted jnp shard apply for the Pallas
single-HBM-pass kernel (``fused_apply_flat`` — shard slices are already
its natural flat-f32 layout); ``"auto"`` picks Pallas on TPU only.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from functools import partial
from typing import Any, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import AggregationRule, configure_aggregation
from repro.core.server import PushResult
from repro.core.staleness import LagTracker, gradient_gap
from repro.kernels.fused_update import (fused_apply_flat, kernel_interpret,
                                        resolve_kernel_mode)

from .sharding import ShardSpec

__all__ = ["ShardedAsyncParameterServer"]


@partial(jax.jit, static_argnums=())
def _apply_shard(cur, mom, new, w, inv_eta, beta):
    """Fused shard-local apply: weighted mix + momentum + sq-norm.

    ``w``/``inv_eta``/``beta`` are traced scalars so every push of a
    given shard shape shares one executable regardless of rule/knobs."""
    mixed = w * new + (1.0 - w) * cur
    s = (cur - mixed) * inv_eta
    mom2 = beta * mom + (1.0 - beta) * s
    return mixed, mom2, jnp.sum(mom2 * mom2)


class _ShardState:
    """One shard's replicated-bookkeeping cell: slice + momentum slice +
    its own copy of the version counter."""

    __slots__ = ("params", "momentum", "version")

    def __init__(self, params, momentum):
        self.params = params
        self.momentum = momentum
        self.version = 0


class ShardedAsyncParameterServer:
    """Drop-in async parameter server with a sharded parameter store.

    Implements the ``AsyncParameterServer`` surface (``pull``/``push``/
    ``lag_estimate``/``params``/``v_norm``/``in_flight``/``lag_tracker``)
    plus the flat serving-tier paths the ingestion pipeline uses
    (``pull_flat``/``push_flat``/``base_shard``/``snapshot_flat``).
    """

    def __init__(self, params: Any, eta: float, beta: float,
                 aggregation: Union[str, AggregationRule] = "replace",
                 n_shards: int = 1, *, mesh=None, history_depth: int = 64,
                 fedasync_alpha: float = 0.6, fedasync_a: float = 0.5,
                 gap_ref: float = 1.0, fleet=None, kernel: str = "auto"):
        if history_depth < 1:
            raise ValueError(
                f"history_depth must be >= 1, got {history_depth}")
        self.eta = float(eta)
        self.beta = float(beta)
        self.rule: AggregationRule = configure_aggregation(
            aggregation, fedasync_alpha=fedasync_alpha,
            fedasync_a=fedasync_a, gap_ref=gap_ref)
        self.aggregation = self.rule.name
        self.fleet_spec = fleet
        # shard slices are already flat contiguous f32 vectors — the Pallas
        # kernel's natural layout; "reference" keeps the jitted jnp apply
        self.kernel = resolve_kernel_mode(kernel)
        self.spec = ShardSpec(params, n_shards, mesh=mesh)
        flat = self.spec.flatten(params)
        self._shards: List[_ShardState] = [
            _ShardState(p, jnp.zeros_like(p))
            for p in self.spec.split(flat)]
        self.lag_tracker = LagTracker()
        self.v_norm = 0.0
        self.in_flight: set = set()
        self.history_depth = int(history_depth)
        self._history: "OrderedDict[int, Tuple[jnp.ndarray, ...]]" = \
            OrderedDict()
        self._push_lock = threading.Lock()   # serializes appliers
        self._pub_lock = threading.Lock()    # guards reader snapshots
        self.ring_misses = 0
        self._publish(bump=False)

    # ------------------------------------------------------------ publish
    def _publish(self, bump: bool) -> None:
        """Commit the current shard tuple as one atomic version step."""
        snap = tuple(s.params for s in self._shards)
        with self._pub_lock:
            if bump:
                for s in self._shards:
                    s.version += 1
            self._published = snap
            self._history[self.version] = snap
            while len(self._history) > self.history_depth:
                self._history.popitem(last=False)

    @property
    def version(self) -> int:
        return self.lag_tracker.version

    @property
    def n_shards(self) -> int:
        return self.spec.n_shards

    @property
    def params(self) -> Any:
        """Assembled pytree view of the latest published snapshot."""
        flat, _ = self.snapshot_flat()
        return self.spec.unflatten(self.spec.join(flat))

    @params.setter
    def params(self, value: Any) -> None:
        """Wholesale replacement (checkpoint restore): re-split over the
        shards and republish at the current version. Momentum is kept —
        restore-then-continue matches the core server, which also leaves
        ``_v`` untouched on assignment."""
        flat = self.spec.flatten(value)
        with self._push_lock:
            for st, piece in zip(self._shards, self.spec.split(flat)):
                st.params = piece
            self._publish(bump=False)

    def assert_consistent(self) -> None:
        """Replication invariant: every shard's version copy equals the
        lag tracker's global counter, and the published snapshot is the
        current shard tuple."""
        vs = {s.version for s in self._shards}
        if vs != {self.lag_tracker.version}:
            raise AssertionError(
                f"shard versions {sorted(vs)} diverged from global "
                f"version {self.lag_tracker.version}")
        with self._pub_lock:
            cur = tuple(s.params for s in self._shards)
            if any(a is not b for a, b in zip(cur, self._published)):
                raise AssertionError(
                    "published snapshot is not the current shard tuple")

    # ------------------------------------------------------------ readers
    def snapshot_flat(self) -> Tuple[Tuple[jnp.ndarray, ...], int]:
        """(shard tuple, version) — atomic, zero-copy (immutable jax
        arrays)."""
        with self._pub_lock:
            return self._published, self.lag_tracker.version

    def pull(self, client_id) -> Tuple[Any, int]:
        shards, version = self.snapshot_flat()
        self.lag_tracker.on_pull(client_id)
        self.in_flight.add(client_id)
        return self.spec.unflatten(self.spec.join(shards)), version

    def pull_flat(self, client_id) -> Tuple[Tuple[jnp.ndarray, ...], int]:
        """Serving-tier pull: the per-shard tuple, no reassembly."""
        shards, version = self.snapshot_flat()
        self.lag_tracker.on_pull(client_id)
        self.in_flight.add(client_id)
        return shards, version

    def base_shard(self, version: int, shard: int) -> Optional[jnp.ndarray]:
        """Shard slice as published at ``version``, or None when that
        version aged out of the history ring (the caller counts the miss
        and falls back to the current slice)."""
        with self._pub_lock:
            snap = self._history.get(int(version))
            if snap is None:
                self.ring_misses += 1
                return None
            return snap[shard]

    def lag_estimate(self, client_id) -> int:
        """Alg. 2 line 4: server-side lag estimate = concurrent tasks."""
        return max(len(self.in_flight)
                   - (1 if client_id in self.in_flight else 0), 0)

    # ------------------------------------------------------------ push
    def push(self, client_id, new_params: Any) -> PushResult:
        """Full-pytree push (AsyncParameterServer-compatible path)."""
        flat = self.spec.flatten(new_params)
        return self.push_flat(client_id, self.spec.split(flat))

    def push_flat(self, client_id,
                  new_slices: Sequence[jnp.ndarray]) -> PushResult:
        """Apply one complete push given per-shard slices: the ingestion
        pipeline's commit path. Shard applies run on each shard's owning
        device; the version/bookkeeping commit is one atomic publish."""
        if len(new_slices) != self.spec.n_shards:
            raise ValueError(
                f"push carries {len(new_slices)} slices for "
                f"{self.spec.n_shards} shards")
        with self._push_lock:
            lag = self.lag_tracker.lag(client_id)
            # Eq. (4) gap at push arrival, shared by rule weight and result
            gap = gradient_gap(self.v_norm, lag, self.eta, self.beta)
            weight = float(self.rule.weight(lag, gap, self.v_norm,
                                            fleet=self.fleet_spec,
                                            users=client_id))
            w = jnp.float32(weight)
            inv_eta = jnp.float32(1.0 / max(self.eta, 1e-12))
            beta = jnp.float32(self.beta)
            sqs = []
            for i, (st, new) in enumerate(zip(self._shards, new_slices)):
                new = jnp.asarray(new, jnp.float32)
                if self.spec.devices is not None:
                    new = jax.device_put(new, self.spec.devices[i])
                if self.kernel == "pallas":
                    mixed, mom2, sq = fused_apply_flat(
                        st.params, st.momentum, new, w, inv_eta, beta,
                        interpret=kernel_interpret())
                else:
                    mixed, mom2, sq = _apply_shard(st.params, st.momentum,
                                                   new, w, inv_eta, beta)
                st.params, st.momentum = mixed, mom2
                sqs.append(sq)
            # cross-shard norm reduction on the host: the per-shard sq
            # scalars live on their owning devices
            self.v_norm = float(np.sqrt(np.sum(
                np.asarray(jax.device_get(sqs), np.float32))))
            returned_lag = self.lag_tracker.on_push(client_id)
            self.in_flight.discard(client_id)
            self._publish(bump=True)
        return PushResult(lag=returned_lag, gap_estimate=gap,
                          applied_weight=weight,
                          version=self.lag_tracker.version)
