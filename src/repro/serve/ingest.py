"""Push ingestion pipeline: bounded queue, staging, faults, exactly-once.

The wire unit is a ``ShardPacket`` — one shard's payload of one client
push. Packets flow through a bounded ``PushQueue`` (backpressure: a full
queue REJECTS, the client retries — sheds load instead of buffering
unboundedly), are decoded per-shard by the configured codec, and staged
until every shard of the push has arrived; only then does the push commit
through ``ShardedAsyncParameterServer.push_flat`` as ONE atomic apply.
Readers can never observe a partial push: incomplete pushes live in the
staging area, not in the published params.

Fault model (``fault/monitor.py`` wired in live):

- every packet is a liveness heartbeat (``FleetMonitor.observe_heartbeat``)
  and every COMMITTED push a cadence sample (``observe_push``);
- ``sweep(slot)`` evicts islands whose last packet aged past the monitor
  timeout — a death MID-PUSH leaves staged shards and maybe queued
  packets behind: both are parked under the island's id (the in-flight
  shards are re-queued on recovery, so no push is lost);
- an evicted island's next packet RE-REGISTERS it: parked packets go
  back on the queue (front — they are oldest), parked staging is
  restored, and the push completes and commits exactly once;
- exactly-once: per-client ``push_id``s are monotone; a packet whose
  push already committed is counted a duplicate and dropped, and a
  re-delivered shard of an in-flight push overwrites its staged twin.

``ServeClient`` is the client-side half used by tests and the benchmark:
it pulls a base snapshot, encodes per shard (stateful codecs key on
``(client, shard)``), and can deliberately send only a subset of shards —
the fault-injection hook for island-death-mid-push scenarios.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple, Union

import jax.numpy as jnp

from repro.fault.monitor import FleetMonitor

from .codecs import ShardCodec, resolve_codec
from .server import ShardedAsyncParameterServer

__all__ = ["ShardPacket", "PushQueue", "IngestStats", "IngestPipeline",
           "ServeClient"]


@dataclasses.dataclass
class ShardPacket:
    client: int
    push_id: int
    shard: int
    n_shards: int
    base_version: int
    payload: Any
    slot: int


@dataclasses.dataclass
class IngestStats:
    enqueued: int = 0
    rejected: int = 0          # backpressure: queue full at offer
    applied: int = 0           # pushes committed (atomic, whole-push)
    duplicates: int = 0        # packets for already-committed pushes
    evicted: int = 0           # island evictions (monitor sweep)
    reregistered: int = 0      # evicted islands that came back
    parked_packets: int = 0    # queued packets parked by an eviction
    requeued_packets: int = 0  # parked packets put back on the queue
    ring_misses: int = 0       # delta decode against an aged-out base

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class PushQueue:
    """Bounded FIFO of ``ShardPacket``s with reject-on-full semantics."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._q: deque = deque()

    def __len__(self) -> int:
        return len(self._q)

    def offer(self, pkt: ShardPacket) -> bool:
        if len(self._q) >= self.capacity:
            return False
        self._q.append(pkt)
        return True

    def pop(self) -> Optional[ShardPacket]:
        return self._q.popleft() if self._q else None

    def requeue_front(self, pkts: Sequence[ShardPacket]) -> None:
        """Parked packets return ahead of newer traffic (they are the
        oldest in-flight work)."""
        for pkt in reversed(list(pkts)):
            self._q.appendleft(pkt)

    def extract_client(self, client: int) -> List[ShardPacket]:
        """Remove and return every queued packet of ``client`` (eviction
        parking), preserving order."""
        mine = [p for p in self._q if p.client == client]
        if mine:
            self._q = deque(p for p in self._q if p.client != client)
        return mine


class IngestPipeline:
    """Server-side ingestion: queue -> decode -> stage -> atomic commit."""

    def __init__(self, server: ShardedAsyncParameterServer, *,
                 capacity: int = 4096,
                 codec: Union[str, ShardCodec, None] = None,
                 monitor: Optional[FleetMonitor] = None):
        self.server = server
        self.queue = PushQueue(capacity)
        self.codec = resolve_codec(codec)
        self.monitor = monitor
        self.stats = IngestStats()
        self.latencies: List[float] = []        # seconds, per committed push
        # (client, push_id) -> {shard -> decoded f32 slice}
        self._staging: "OrderedDict[Tuple[int, int], Dict[int, jnp.ndarray]]" = OrderedDict()
        self._parked_staging: Dict[int, Dict[Tuple[int, int], Dict[int, jnp.ndarray]]] = {}
        self._parked_packets: Dict[int, List[ShardPacket]] = {}
        self._last_committed: Dict[int, int] = {}
        self._first_seen: Dict[Tuple[int, int], float] = {}

    # ------------------------------------------------------------ intake
    def submit(self, pkt: ShardPacket) -> bool:
        """Offer one packet; False = backpressure (client should retry)."""
        if not self.queue.offer(pkt):
            self.stats.rejected += 1
            return False
        self.stats.enqueued += 1
        self._first_seen.setdefault((pkt.client, pkt.push_id),
                                    time.perf_counter())
        return True

    # ------------------------------------------------------------ processing
    def step(self, max_packets: Optional[int] = None) -> int:
        """Process up to ``max_packets`` queued packets (all by default);
        returns the number processed."""
        done = 0
        while max_packets is None or done < max_packets:
            pkt = self.queue.pop()
            if pkt is None:
                break
            self._process(pkt)
            done += 1
        return done

    def drain(self) -> int:
        return self.step(None)

    def _obs_slot(self, slot: int) -> int:
        """Monitor time is forward-only; a re-queued packet minted before
        an eviction carries an old slot — it is observed NOW, at the
        clock's current position."""
        return max(int(slot), self.monitor.clock.slot)

    def _process(self, pkt: ShardPacket) -> None:
        if pkt.client in self._parked_packets or \
                pkt.client in self._parked_staging:
            self._reregister(pkt.client)
        if self.monitor is not None:
            self.monitor.observe_heartbeat(self._obs_slot(pkt.slot),
                                           pkt.client)
        if self._last_committed.get(pkt.client, -1) >= pkt.push_id:
            self.stats.duplicates += 1
            return
        base = None
        if self.codec.needs_base:
            base = self.server.base_shard(pkt.base_version, pkt.shard)
            if base is None:        # aged out of the ring: approximate
                self.stats.ring_misses += 1
                base = self.server.snapshot_flat()[0][pkt.shard]
        decoded = self.codec.decode(pkt.payload, base)
        key = (pkt.client, pkt.push_id)
        shards = self._staging.setdefault(key, {})
        if pkt.shard in shards:
            self.stats.duplicates += 1      # re-delivered shard: overwrite
        shards[pkt.shard] = decoded
        if len(shards) == pkt.n_shards:
            self._commit(key, shards, pkt.slot)

    def _commit(self, key: Tuple[int, int],
                shards: Dict[int, jnp.ndarray], slot: int) -> None:
        client, push_id = key
        slices = [shards[i] for i in range(len(shards))]
        self.server.push_flat(client, slices)
        del self._staging[key]
        self._last_committed[client] = push_id
        self.stats.applied += 1
        t0 = self._first_seen.pop(key, None)
        if t0 is not None:
            self.latencies.append(time.perf_counter() - t0)
        if self.monitor is not None:
            self.monitor.observe_push(self._obs_slot(slot), client)

    # ------------------------------------------------------------ faults
    def sweep(self, slot: int) -> Set[int]:
        """Advance the monitor and evict dead islands: their staged
        partial pushes and queued packets are PARKED (not dropped) so the
        push survives the outage and completes on recovery."""
        if self.monitor is None:
            return set()
        dead = self.monitor.sweep(slot)
        for uid in dead:
            self.stats.evicted += 1
            mine = {k: v for k, v in self._staging.items() if k[0] == uid}
            for k in mine:
                del self._staging[k]
            if mine:
                self._parked_staging.setdefault(uid, {}).update(mine)
            pkts = self.queue.extract_client(uid)
            if pkts:
                self.stats.parked_packets += len(pkts)
                self._parked_packets.setdefault(uid, []).extend(pkts)
        return dead

    def _reregister(self, client: int) -> None:
        """An evicted island spoke again: restore its parked state. Its
        in-flight shards are re-queued ahead of new traffic; the next
        ``observe_heartbeat`` re-registers it with the monitor."""
        self.stats.reregistered += 1
        staged = self._parked_staging.pop(client, None)
        if staged:
            for k, v in staged.items():
                self._staging.setdefault(k, {}).update(v)
        pkts = self._parked_packets.pop(client, None)
        if pkts:
            self.stats.requeued_packets += len(pkts)
            self.queue.requeue_front(pkts)

    # ------------------------------------------------------------ inspection
    @property
    def pending_pushes(self) -> int:
        return len(self._staging)

    @property
    def parked_clients(self) -> Set[int]:
        return set(self._parked_staging) | set(self._parked_packets)


class ServeClient:
    """Client-side half of the wire: pull a base, encode per shard,
    submit packets. ``shards=`` restricts a push to a subset of shard
    packets — the island-death-mid-push fault hook; ``resume_push``
    sends the missing remainder after recovery."""

    def __init__(self, client_id: int, pipeline: IngestPipeline):
        self.client_id = int(client_id)
        self.pipeline = pipeline
        self.server = pipeline.server
        self.codec = pipeline.codec
        self._next_push_id = 0
        self.base: Optional[Tuple[jnp.ndarray, ...]] = None
        self.base_version = 0
        self._sent: Dict[int, Set[int]] = {}    # push_id -> shards sent

    def pull(self) -> Tuple[jnp.ndarray, int]:
        self.base, self.base_version = self.server.pull_flat(self.client_id)
        return self.server.spec.join(self.base), self.base_version

    def push(self, new_flat: jnp.ndarray, slot: int,
             shards: Optional[Sequence[int]] = None) -> Tuple[int, int]:
        """Encode + submit one push; returns ``(push_id, n_accepted)``.
        Rejected (backpressured) packets are NOT retried here — the
        caller decides (the bench retries after a drain)."""
        if self.base is None:
            raise RuntimeError("push before pull: no base snapshot")
        push_id = self._next_push_id
        self._next_push_id += 1
        accepted = self._send(push_id, new_flat, slot, shards)
        return push_id, accepted

    def resume_push(self, push_id: int, new_flat: jnp.ndarray,
                    slot: int) -> int:
        """Re-send the shards of ``push_id`` that were never submitted
        (recovery after dying mid-push)."""
        spec = self.server.spec
        missing = [i for i in range(spec.n_shards)
                   if i not in self._sent.get(push_id, set())]
        return self._send(push_id, new_flat, slot, missing)

    def _send(self, push_id: int, new_flat: jnp.ndarray, slot: int,
              shards: Optional[Sequence[int]]) -> int:
        spec = self.server.spec
        todo = range(spec.n_shards) if shards is None else shards
        accepted = 0
        sent = self._sent.setdefault(push_id, set())
        new_flat = jnp.asarray(new_flat, jnp.float32)
        for i in todo:
            sl = spec.shard_slice(i)
            payload = self.codec.encode((self.client_id, i), new_flat[sl],
                                        self.base[i] if self.base is not None
                                        else None)
            pkt = ShardPacket(client=self.client_id, push_id=push_id,
                              shard=i, n_shards=spec.n_shards,
                              base_version=self.base_version,
                              payload=payload, slot=int(slot))
            if self.pipeline.submit(pkt):
                accepted += 1
                sent.add(i)
        return accepted
