"""Serving tier: sharded async parameter server + push ingestion.

The simulator (core/) answers WHEN devices should train and push; this
package is the datacenter half that absorbs those pushes at fleet scale:
a ``ShardedAsyncParameterServer`` partitioning the global model over a
serving mesh, an ``IngestPipeline`` with a bounded backpressured queue,
per-shard wire codecs, and live ``fault/monitor.py`` membership so dead
islands are evicted mid-push and recovered without losing a push.
"""
from .codecs import (Int8Codec, NullCodec, ShardCodec, TopKDeltaCodec,
                     registered_codecs, resolve_codec)
from .ingest import (IngestPipeline, IngestStats, PushQueue, ServeClient,
                     ShardPacket)
from .server import ShardedAsyncParameterServer
from .sharding import ShardSpec

__all__ = [
    "ShardCodec", "NullCodec", "Int8Codec", "TopKDeltaCodec",
    "registered_codecs", "resolve_codec",
    "IngestPipeline", "IngestStats", "PushQueue", "ServeClient",
    "ShardPacket",
    "ShardedAsyncParameterServer", "ShardSpec",
]
