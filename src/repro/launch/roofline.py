"""Roofline analysis over the dry-run JSON artifacts.

Hardware model (TPU v5e, per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI link bandwidth  ~50 GB/s per link

Terms per (arch, shape) on the single-pod 16x16 mesh, from the CALIBRATED
per-device counts (dryrun.py):

    compute    = flops_per_device / 197e12
    memory     = hbm_bytes_per_device / 819e9
    collective = effective_link_bytes_per_device / 50e9

effective link bytes apply ring-algorithm factors per op type with the
parsed mean group size k:
    all-reduce        2 * B * (k-1)/k
    all-gather            B * (k-1)/k     (B = gathered output bytes)
    reduce-scatter        B * (k-1)       (B = scattered output bytes)
    all-to-all            B * (k-1)/k
    collective-permute    B

The dominant term is the bottleneck; step time ~ max(terms) under perfect
overlap, sum(terms) with none. MODEL_FLOPS / (HLO_FLOPs * chips) measures
useful-compute fraction; roofline fraction = compute / max(terms).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Iterable, Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_RING_FACTORS = {
    "all-reduce": lambda b, k: 2.0 * b * (k - 1.0) / k,
    "all-gather": lambda b, k: b * (k - 1.0) / k,
    "reduce-scatter": lambda b, k: b * (k - 1.0),
    "all-to-all": lambda b, k: b * (k - 1.0) / k,
    "collective-permute": lambda b, k: b,
}


def effective_link_bytes(collectives: Dict[str, float],
                         group_sizes: Dict[str, float],
                         default_k: float = 16.0) -> float:
    total = 0.0
    for op, b in collectives.items():
        if op == "total" or op not in _RING_FACTORS:
            continue
        k = max(group_sizes.get(op, default_k), 2.0)
        total += _RING_FACTORS[op](b, k)
    return total


def cell_terms(rec: dict, *, source: str = "calibrated",
               flash: bool = False) -> Optional[dict]:
    """The three roofline terms (seconds) for one dry-run record.

    flash=True models the Pallas-kernel variant: subtracts the parsed
    attention/SSD quadratic HBM traffic (kept in VMEM by the kernels)."""
    src = rec.get(source) or rec.get("real")
    if not src or rec.get("status") != "ok":
        return None
    flops = src["flops"]
    hbm = src.get("hbm_bytes", src.get("bytes_accessed", 0.0))
    if flash:
        hbm = hbm - src.get("attn_quad_bytes", 0.0) \
                  - src.get("ssd_quad_bytes", 0.0)
    link = effective_link_bytes(src.get("collectives", {}),
                                src.get("collective_group_sizes", {}))
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm / HBM_BW,
        "collective_s": link / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    mf = rec.get("model_flops", 0.0)
    n_dev = rec.get("n_devices", 256)
    hlo_global = flops * n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **terms,
        "dominant": dom.replace("_s", ""),
        "bound_s": max(terms.values()),
        "roofline_fraction": terms["compute_s"] / max(terms.values()),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "mfu_upper_bound": (mf / n_dev / PEAK_FLOPS) / max(terms.values())
        if max(terms.values()) else 0.0,
    }


def load_records(art_dir: str, mesh: str = "single") -> Iterable[dict]:
    for path in sorted(glob.glob(os.path.join(art_dir, f"*__{mesh}.json"))):
        with open(path) as f:
            yield json.load(f)


def table(art_dir: str, mesh: str = "single", flash: bool = False):
    rows = []
    for rec in load_records(art_dir, mesh):
        t = cell_terms(rec, flash=flash)
        if t:
            rows.append(t)
    return rows


def format_table(rows, *, md: bool = False) -> str:
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "roofline%", "useful%", "MFU-bound%"]
    lines = []
    if md:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "|".join("---" for _ in hdr) + "|")
    else:
        lines.append(",".join(hdr))
    for r in rows:
        vals = [r["arch"], r["shape"], f"{r['compute_s']:.4f}",
                f"{r['memory_s']:.4f}", f"{r['collective_s']:.4f}",
                r["dominant"], f"{100 * r['roofline_fraction']:.1f}",
                f"{100 * r['useful_ratio']:.1f}",
                f"{100 * r['mfu_upper_bound']:.1f}"]
        lines.append(("| " + " | ".join(vals) + " |") if md
                     else ",".join(vals))
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--flash", action="store_true",
                    help="model the Pallas flash/SSD kernel variant")
    args = ap.parse_args()
    print(format_table(table(args.art, args.mesh, args.flash), md=args.md))


if __name__ == "__main__":
    main()
