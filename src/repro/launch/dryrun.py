import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits — and extract exact roofline inputs.

Per cell, three artifacts go into one JSON:

1. REAL compile (scan-based stacks, production microbatching):
   ``.lower().compile()`` on the target mesh + ``memory_analysis()``
   (per-device argument/output/temp bytes — the HBM-fit proof) +
   ``cost_analysis()`` + trip-count-weighted collective bytes parsed from
   ``compiled.as_text()``.

2. CALIBRATION compiles (single-pod only): XLA's CPU cost model counts
   while-loop bodies ONCE, so scan-based flop counts are not per-step
   totals. We therefore lower python-UNROLLED variants with 2 and 3 layer
   units (unit = layer; hybrid = one mamba group + shared block; whisper =
   one enc + one dec layer) and extrapolate linearly in depth — exact for
   homogeneous stacks (k=1 avoided: GSPMD partitions single-layer graphs
   differently; from k>=2 increments are verified linear):

       F_step(L) = F(2) + (L - 2) * (F(3) - F(2))

   For train the microbatch loop is also removed (1 microbatch of B/M
   sequences lowered; the fused-update epilogue F_upd is compiled separately
   on the full config):

       F_total = M * F_step(L) - (M - 1) * F_upd

3. Analytic MODEL_FLOPS (6ND / 2ND) for the usefulness ratio.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
        --mesh single --out artifacts/dryrun
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp


def _cells(archs, shapes):
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, applicable
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            if applicable(cfg, s):
                yield a, s


def _layer_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_period
    return cfg.num_layers


def _variant(cfg, k: int):
    """Unrolled k-layer-unit variant of cfg (identical per-unit compute)."""
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, num_layers=k * cfg.hybrid_period,
                                   scan_layers=False)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, num_layers=k, encoder_layers=k,
                                   scan_layers=False)
    return dataclasses.replace(cfg, num_layers=k, scan_layers=False)


def _analyse(compiled, cfg=None):
    from repro.launch.hlo import (collective_group_sizes, collective_summary,
                                  hbm_bytes, quadratic_traffic)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    # Attention-score tensors are identified by their trailing (.., bq, Sk)
    # dims; logits / MLP-hidden / residual tensors are rank-2/3 lookalikes
    # after XLA flattening, so every model width (and its shard extents) is
    # excluded from the last-dim match.
    ex = set()
    if cfg is not None:
        for w in (cfg.vocab_size, cfg.d_ff, cfg.d_model,
                  getattr(cfg, "d_inner", 0)):
            for d in (1, 2, 4, 8, 16, 32):
                if w and w % d == 0:
                    ex.add(w // d)
    ex = frozenset(ex)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "hbm_bytes": float(hbm_bytes(text)),
        "attn_quad_bytes": float(quadratic_traffic(text, 2048, (-2, -1),
                                                   second_min=256,
                                                   exclude_last=ex)),
        "ssd_quad_bytes": float(quadratic_traffic(text, 256, (-3, -2),
                                                  rank_min=4)),
        "collectives": collective_summary(text),
        "collective_group_sizes": collective_group_sizes(text),
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        },
    }


def _lower_compile(cfg, shape, mesh, **kw):
    from repro.launch.steps import lower_cell
    t0 = time.time()
    lowered, kind = lower_cell(cfg, shape, mesh, **kw)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    out = _analyse(compiled, cfg=cfg)
    out.update({"kind": kind, "lower_s": t1 - t0, "compile_s": t2 - t1})
    return out


def _calibrate(cfg, shape, mesh, *, microbatches, fsdp):
    """Unrolled 2/3-unit compiles -> exact per-step totals.

    k=1 is deliberately avoided: GSPMD picks a different partitioning for a
    single-layer graph (observed 2x per-device flops vs the per-layer cost
    in deeper graphs); from k>=2 the per-unit increments are exactly linear
    (verified: F(3)-F(2) == F(4)-F(3) to 5 digits)."""
    from repro.launch.shapes import SHAPES
    from repro.launch.steps import make_update_step, param_shardings

    spec = SHAPES[shape]
    L = _layer_units(cfg)
    K1, K2 = 2, 3
    pts = {}
    for k in (K1, K2):
        kw = dict(microbatches=1) if spec.kind == "train" else {}
        pts[k] = _lower_compile(_variant(cfg, k), shape, mesh,
                                fsdp=fsdp,
                                batch_div=(microbatches if spec.kind == "train"
                                           else 1), **kw)

    def extrap(get):
        f1, f2 = get(pts[K1]), get(pts[K2])
        return f1 + (L - K1) * (f2 - f1)

    out = {
        "flops": extrap(lambda p: p["flops"]),
        "bytes_accessed": extrap(lambda p: p["bytes_accessed"]),
        "hbm_bytes": extrap(lambda p: p["hbm_bytes"]),
        "attn_quad_bytes": extrap(lambda p: p["attn_quad_bytes"]),
        "ssd_quad_bytes": extrap(lambda p: p["ssd_quad_bytes"]),
        "collectives": {},
        "collective_group_sizes": pts[K2]["collective_group_sizes"],
        "layer_units": L,
        "points": {k: {kk: pts[k][kk] for kk in
                       ("flops", "bytes_accessed", "hbm_bytes",
                        "collectives")}
                   for k in (K1, K2)},
    }
    keys = set(pts[K1]["collectives"]) | set(pts[K2]["collectives"])
    for key in keys:
        out["collectives"][key] = extrap(
            lambda p, key=key: p["collectives"].get(key, 0.0))

    if spec.kind == "train" and microbatches > 1:
        # F_total = M * F_step - (M-1) * F_upd (fused update compiled once)
        upd = make_update_step(cfg)
        pshape, pshard = param_shardings(cfg, mesh, fsdp=fsdp)
        vshape = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), pshape)
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import set_mesh
        repl = NamedSharding(mesh, P())
        with set_mesh(mesh):
            c = jax.jit(upd, in_shardings=(pshard, pshard, pshard, repl)) \
                .lower(pshape, vshape, vshape,
                       jax.ShapeDtypeStruct((), jnp.int32)).compile()
        u = _analyse(c, cfg=cfg)
        out["update_epilogue"] = {k: u[k] for k in
                                  ("flops", "bytes_accessed", "hbm_bytes",
                                   "collectives")}
        M = microbatches
        for k in ("flops", "bytes_accessed", "hbm_bytes", "attn_quad_bytes",
                  "ssd_quad_bytes"):
            out[k] = M * out[k] - (M - 1) * u.get(k, 0.0)
        for key in list(out["collectives"]):
            out["collectives"][key] = (
                M * out["collectives"][key]
                - (M - 1) * u["collectives"].get(key, 0.0))
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str,
             calibrate: bool = True, overrides: dict | None = None,
             microbatches: int | None = None, fsdp: bool | None = None,
             suffix: str = "") -> dict:
    from repro.configs import get_config
    from repro.launch.flops import model_flops
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import (SHAPES, TRAIN_MICROBATCHES,
                                     production_config)

    cfg = get_config(arch)
    if overrides is None:
        cfg, applied = production_config(cfg, shape)
    else:
        applied = overrides
        cfg = dataclasses.replace(cfg, **overrides) if overrides else cfg
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    spec = SHAPES[shape]
    if spec.kind != "train":
        M = 1
    elif microbatches is not None:
        M = microbatches
    else:
        M = TRAIN_MICROBATCHES

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "config_overrides": applied,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [mesh.shape[a] for a in mesh.axis_names])),
        "n_devices": mesh.size,
        "microbatches": M,
        "param_count": int(cfg.param_count()),
        "active_param_count": int(cfg.active_param_count()),
        "model_flops": model_flops(cfg, shape),
        "status": "ok",
    }
    try:
        rec["real"] = _lower_compile(cfg, shape, mesh, microbatches=M,
                                     fsdp=fsdp)
        if calibrate and mesh_kind == "single":
            rec["calibrated"] = _calibrate(cfg, shape, mesh,
                                           microbatches=M, fsdp=fsdp)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch}__{shape}__{mesh_kind}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    from repro.configs import ALIASES, ARCHS
    from repro.launch.shapes import SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ([a.replace("_", "-") if ALIASES.get(a) is None else a
              for a in ([args.arch] if args.arch else
                        [x.replace('_', '-') for x in ARCHS])])
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not (args.all or args.arch):
        ap.error("pass --arch or --all")

    results = []
    for mesh_kind in meshes:
        for arch, shape in _cells(archs, shapes):
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {arch} {shape} {mesh_kind}")
                continue
            t0 = time.time()
            rec = run_cell(arch, shape, mesh_kind, args.out,
                           calibrate=not args.no_calibrate)
            dt = time.time() - t0
            mem = rec.get("real", {}).get("memory", {})
            print(f"[{rec['status']:5s}] {arch:22s} {shape:12s} {mesh_kind:6s}"
                  f" {dt:7.1f}s  temp/dev="
                  f"{mem.get('temp_bytes', 0) / 2**30:7.2f}GiB "
                  f"args/dev={mem.get('argument_bytes', 0) / 2**30:7.2f}GiB",
                  flush=True)
            if rec["status"] == "error":
                print(rec["error"], flush=True)
            results.append(rec)
    n_err = sum(r["status"] != "ok" for r in results)
    print(f"done: {len(results) - n_err}/{len(results)} cells ok")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
