"""Batched serving driver: continuous-batching decode loop with KV cache.

Prefill a batch of prompts, then greedy-decode with the jitted decode step.
At production scale the same prefill/decode steps lower on the 16x16 mesh
(dry-run shapes prefill_32k / decode_32k / long_500k); this driver runs the
smoke configs end-to-end on the host and reports tokens/s.

    python -m repro.launch.serve --arch qwen3-0.6b --batch 4 --prompt-len 32 \
        --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import synthetic_tokens
from repro.models import build_model


class BatchedServer:
    """Greedy batched decode over a fixed cohort of requests."""

    def __init__(self, cfg, params=None, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(seed))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _extra_inputs(self, batch_size: int):
        extra = {}
        if self.cfg.family == "audio":
            extra["audio_embeds"] = jnp.zeros(
                (batch_size, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.float32)
        if self.cfg.family == "vlm":
            extra["vision_embeds"] = jnp.zeros(
                (batch_size, self.cfg.num_vision_tokens, self.cfg.d_model),
                jnp.float32)
        return extra

    def generate(self, prompts: np.ndarray, max_new_tokens: int):
        """prompts: (B, S) int32. Returns (B, max_new_tokens) int32."""
        B, S = prompts.shape
        cache = self.model.init_cache(B, S + max_new_tokens)
        batch = {"tokens": jnp.asarray(prompts), **self._extra_inputs(B)}
        logits, cache = self._prefill(self.params, batch, cache)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out = [tok]
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, cache, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))


def main():
    from repro.configs import get_smoke_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    srv = BatchedServer(cfg)
    stream = synthetic_tokens(args.batch * args.prompt_len + 1,
                              cfg.vocab_size, seed=3)
    prompts = stream[:args.batch * args.prompt_len].reshape(
        args.batch, args.prompt_len)

    t0 = time.time()
    toks = srv.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: {toks.size / dt:.1f} tok/s  "
          f"first row: {toks[0][:10].tolist()}")


if __name__ == "__main__":
    main()
