"""Production meshes.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an extra pure-DP dimension over the slower inter-pod (DCN)
links; within the paper's system each pod is one *island* whose updates the
async parameter server applies (launch/train.py).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (tests / small-scale drivers)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Whatever this host offers (CPU tests: 1 device -> (1,1) mesh)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))
