"""Production meshes.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an extra pure-DP dimension over the slower inter-pod (DCN)
links; within the paper's system each pod is one *island* whose updates the
async parameter server applies (launch/train.py).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).

Compat: ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist on newer JAX releases; on older versions we
fall back to a plain ``jax.make_mesh`` — every mesh axis defaults to the
same (auto) partitioning behaviour there. ``AbstractMesh`` likewise changed
its constructor signature between releases; ``make_abstract_mesh`` accepts
(shape, axes) and adapts.
"""
from __future__ import annotations

import jax

try:  # JAX >= 0.5-ish exposes explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - exercised on older JAX only
    AxisType = None


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (tests / small-scale drivers)."""
    shape, axes = tuple(shape), tuple(axes)
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """Device-less mesh for lowering-only tests, across AbstractMesh APIs."""
    from jax.sharding import AbstractMesh

    shape, axes = tuple(shape), tuple(axes)
    try:
        return AbstractMesh(shape, axes)
    except TypeError:  # older signature: tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axes, shape)))


def make_host_mesh(model: int = 1):
    """Whatever this host offers (CPU tests: 1 device -> (1,1) mesh)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))
