"""Production meshes.

Single pod: 16 x 16 = 256 chips (TPU v5e pod), axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is an extra pure-DP dimension over the slower inter-pod (DCN)
links; within the paper's system each pod is one *island* whose updates the
async parameter server applies (launch/train.py).

Functions, not module constants: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).

Compat: ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
``jax.make_mesh``) only exist on newer JAX releases; on older versions we
fall back to a plain ``jax.make_mesh`` — every mesh axis defaults to the
same (auto) partitioning behaviour there. ``AbstractMesh`` likewise changed
its constructor signature between releases; ``make_abstract_mesh`` accepts
(shape, axes) and adapts. ``set_mesh`` / ``get_abstract_mesh`` below shim
the newer ``jax.set_mesh`` context and ``jax.sharding.get_abstract_mesh``
lookup onto the pinned jax 0.4.37, where neither exists — model code must
import them from here, never from jax directly.
"""
from __future__ import annotations

import contextlib
import threading

import jax

try:  # JAX >= 0.5-ish exposes explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - exercised on older JAX only
    AxisType = None

# Mesh contexts our set_mesh shim has entered (old-JAX path only); the
# newer-JAX path delegates the bookkeeping to jax.set_mesh itself.
# Thread-local, like the jax resource env it emulates — concurrent
# dry-run calibrations must not see each other's meshes.
_LOCAL = threading.local()


def _mesh_stack() -> list:
    stack = getattr(_LOCAL, "stack", None)
    if stack is None:
        stack = _LOCAL.stack = []
    return stack


@contextlib.contextmanager
def set_mesh(mesh):
    """Compat twin of ``jax.set_mesh(mesh)`` (a context manager there).

    Newer JAX: delegate. Older JAX (the pinned 0.4.37): enter the mesh's
    resource-env context — pjit/GSPMD resolve bare PartitionSpec axis names
    against it exactly as the newer API does — and record it so
    ``get_abstract_mesh`` can answer inside the block."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    stack = _mesh_stack()
    stack.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        stack.pop()


def get_abstract_mesh():
    """Compat twin of ``jax.sharding.get_abstract_mesh()``.

    Returns the mesh of the innermost active ``set_mesh`` context, or None
    when there is none — callers treat None as "no sharding constraint"
    (host tests run meshless). On old JAX the returned object is the
    concrete Mesh, which exposes the same ``.axis_names`` / ``.shape``
    mapping the callers consult; a mesh entered via a plain ``with mesh:``
    block is also honored through jax's thread resource env."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        m = fn()
        if m is None or not getattr(m, "axis_names", ()):
            return None     # empty sentinel mesh -> meshless semantics
        return m
    stack = _mesh_stack()
    if stack:
        return stack[-1]
    try:  # plain `with mesh:` contexts (old-JAX resource env)
        env_mesh = jax._src.mesh.thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except AttributeError:  # pragma: no cover - layout drift across versions
        pass
    return None


def make_mesh(shape, axes):
    """Arbitrary mesh with Auto axis types (tests / small-scale drivers)."""
    shape, axes = tuple(shape), tuple(axes)
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """Device-less mesh for lowering-only tests, across AbstractMesh APIs."""
    from jax.sharding import AbstractMesh

    shape, axes = tuple(shape), tuple(axes)
    try:
        return AbstractMesh(shape, axes)
    except TypeError:  # older signature: tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axes, shape)))


def make_host_mesh(model: int = 1):
    """Whatever this host offers (CPU tests: 1 device -> (1,1) mesh)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))


def make_serving_mesh(n_shards: int):
    """1-D ``("shard",)`` mesh for the serving tier's parameter
    partition: sized to ``min(n_shards, n_devices)`` so a host with fewer
    devices than shards still gets a valid mesh (shards wrap around it —
    see ``shard_placement``). A 256-chip pod serves 256 true shards; the
    CPU test host serves them all from one device."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n = len(jax.devices())
    return make_mesh((min(int(n_shards), n),), ("shard",))


def make_sim_mesh(n_devices: int = 0):
    """1-D ``("users",)`` mesh for the simulator's sharded chunked scan
    (``core/vector_engine.py``): the per-user ``EngineState`` axis is
    partitioned over it while the scheduler scalars stay replicated.
    Sized to ``min(n_devices, available)`` like :func:`make_serving_mesh`
    so an over-asked host still gets a valid mesh; ``n_devices=0`` (the
    ``SimConfig`` default's sentinel) means "all local devices". On a
    CPU-only host, force multiple devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` *before* the
    first jax import — the scan's collectives then run as host memcpys,
    and the measured partitioning transfers directly to accelerator
    meshes."""
    if n_devices < 0:
        raise ValueError(f"n_devices must be >= 0, got {n_devices}")
    n = len(jax.devices())
    d = n if n_devices == 0 else min(int(n_devices), n)
    return make_mesh((d,), ("users",))


def shard_placement(n_shards: int, mesh=None) -> list:
    """Device owning each of ``n_shards`` logical shards: round-robin
    over the mesh's ``shard`` axis (or all host devices when ``mesh`` is
    None). More shards than devices is fine — a device then owns several
    shards, the degenerate single-host case being all of them."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if mesh is not None:
        devs = list(mesh.devices.reshape(-1))
    else:
        devs = list(jax.devices())
    return [devs[i % len(devs)] for i in range(int(n_shards))]
