import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS") or
                           "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing harness: compile named variants of one
(arch x shape) cell and print the roofline-term deltas.

Each variant = config-field overrides (+ microbatch / fsdp knobs); results
land in artifacts/hillclimb/<arch>__<shape>__<variant>.json and the table
prints hypothesis-testing numbers for EXPERIMENTS.md §Perf.

    python -m repro.launch.hillclimb --arch qwen3-0.6b --shape train_4k \
        --variants baseline_xla,prod,M2,M2_flash
"""
import argparse
import json

# named variants: (config overrides, microbatches, fsdp, flash-modeled)
VARIANTS = {
    # paper-naive starting point: einsum attention, no SP, default M
    "baseline_xla": (dict(attention_impl="xla", shard_activations=False),
                     None, None, False),
    # production defaults (chunked attention / SP / FSDP where configured)
    "prod": (None, None, None, False),
    # fewer grad-accum microbatches => fewer per-microbatch collectives
    "M2": (None, 2, None, False),
    "M4": (None, 4, None, False),
    "M1": (None, 1, None, False),
    "M4_flash": (None, 4, None, True),
    "M2_qb256_flash": (dict(attn_q_block=256), 2, None, True),
    "M1_flash": (None, 1, None, True),
    # no per-layer remat (memory allows on small archs): -25% compute
    "noremat": (dict(remat="none"), None, None, False),
    "M2_noremat": (dict(remat="none"), 2, None, False),
    # FSDP toggles
    "fsdp": (None, None, True, False),
    "nofsdp": (None, None, False, False),
    # sequence parallel toggles
    "sp": (dict(attention_impl="chunked", shard_activations=True),
           None, None, False),
    "nosp": (dict(attention_impl="chunked", shard_activations=False),
             None, None, False),
    # Pallas-kernel modeling: same compile as prod, memory term subtracts
    # the parsed attention/SSD quadratic HBM traffic (kernels keep those
    # tiles in VMEM; kernels validated in tests/test_kernels.py)
    "flash": (None, None, None, True),
    "M2_flash": (None, 2, None, True),
    "M2_noremat_flash": (dict(remat="none"), 2, None, True),
    # parallelism-layout change: replicate weights, batch over ALL 256
    # chips (per-device batch 1 seq, M=1) — kills the TP activation psums
    # that dominate small-model train cells. dp_zero = ZeRO-shard the
    # optimizer state over "data" on top.
    "dp": (dict(parallel_layout="dp", attention_impl="chunked"),
           1, False, False),
    "dp_flash": (dict(parallel_layout="dp", attention_impl="chunked"),
                 1, False, True),
    "dp_zero_flash": (dict(parallel_layout="dp", attention_impl="chunked"),
                      1, True, True),
    "dp_noremat_flash": (dict(parallel_layout="dp", attention_impl="chunked",
                              remat="none"), 1, False, True),
    # vocab padding (granite: 49155 -> 49168 = 16*3073) so embeddings /
    # logits shard over "model"; pad logits are never labeled, CE unchanged
    "padvocab": (dict(vocab_size=49168), None, None, False),
    "padvocab_flash": (dict(vocab_size=49168), None, None, True),
}


def run_variant(arch, shape, name, out_dir="artifacts/hillclimb"):
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell
    from repro.launch.roofline import cell_terms
    from repro.launch.shapes import production_config

    overrides, mb, fsdp, flash = VARIANTS[name]
    if overrides is not None:
        # variant overrides stack ON TOP of the cell's production settings
        _, prod_over = production_config(get_config(arch), shape)
        overrides = {**prod_over, **overrides}
    rec = run_cell(arch, shape, "single", out_dir, overrides=overrides,
                   microbatches=mb, fsdp=fsdp, suffix=f"__{name}")
    terms = cell_terms(rec, flash=flash)
    if terms:
        terms["variant"] = name
    return rec, terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline_xla,prod,flash")
    ap.add_argument("--out", default="artifacts/hillclimb")
    args = ap.parse_args()

    print(f"{'variant':18s} {'compute_s':>10s} {'memory_s':>10s} "
          f"{'collect_s':>10s} {'bound_s':>9s} {'dominant':>10s} "
          f"{'roofl%':>7s} {'MFU%':>6s}")
    for name in args.variants.split(","):
        rec, t = run_variant(args.arch, args.shape, name.strip(), args.out)
        if t is None:
            print(f"{name:18s} ERROR: {rec.get('error')}")
            continue
        print(f"{name:18s} {t['compute_s']:10.4f} {t['memory_s']:10.4f} "
              f"{t['collective_s']:10.4f} {t['bound_s']:9.4f} "
              f"{t['dominant']:>10s} {100 * t['roofline_fraction']:7.1f} "
              f"{100 * t['mfu_upper_bound']:6.1f}", flush=True)


if __name__ == "__main__":
    main()
