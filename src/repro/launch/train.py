"""End-to-end async federated LM training driver (datacenter tier).

The paper's system mapped onto pods: each *island* (a DP group / pod slice)
plays the role of a battery device. Islands pull the global model from the
AsyncParameterServer, run `local_steps` sharded momentum-SGD steps on their
own data shard, and push back — scheduled per slot by the paper's Lyapunov
controller against a per-island power profile (the co-running discount
models low-price windows: co-tenant capacity / off-peak power). Pushes can
be compressed (top-k + error feedback) and are applied with the configured
staleness rule (replace / fedasync_poly / gap_aware).

Runs at any scale; the default config is CPU-sized (smoke LM, a few
islands) and is exercised end-to-end by examples/federated_lm.py and the
integration tests. Fault tolerance: periodic async checkpoints + elastic
island membership (an island can die and rejoin; the queue re-absorbs it).

    python -m repro.launch.train --arch qwen3-0.6b --smoke --islands 4 \
        --slots 300 --steps-per-epoch 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.energy import APPS, DEVICE_NAMES, TESTBED
from repro.core.lyapunov import OnlineScheduler, UserSlotState
from repro.core.server import AsyncParameterServer
from repro.core.staleness import gradient_gap
from repro.data.synthetic import synthetic_tokens, token_batches
from repro.fault.monitor import HeartbeatMonitor, StragglerDetector
from repro.models import build_model
from repro.optim.compression import ErrorFeedback

from .mesh import make_host_mesh
from .steps import make_train_step, param_shardings


@dataclasses.dataclass
class IslandConfig:
    n_islands: int = 4
    slots: int = 300                 # scheduler slots
    slot_seconds: float = 1.0
    local_steps: int = 4             # train steps per local epoch
    batch: int = 8
    seq: int = 64
    eta: float = 0.05
    beta: float = 0.9
    # V scales with the queue-backlog magnitude: the paper's knee V~4e3 is
    # for 25 devices x 3 h; a few-island driver run needs Q-threshold
    # V*(P^b - P^d) reachable within Q <= n_islands.
    V: float = 5.0
    L_b: float = 50.0
    epsilon: float = 0.05
    app_arrival_p: float = 0.02      # low-price-window arrival probability
    train_slots: int = 8             # slots one local epoch occupies
    compress_ratio: float = 0.0      # 0 = off; else top-k ratio w/ EF
    aggregation: str = "replace"
    kernel: str = "auto"             # push-apply impl: pallas|reference|auto
    n_shards: int = 0                # >0: sharded serving-tier server
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50             # slots
    eval_every: int = 50
    resume: bool = False             # restore server params from ckpt_dir
    fail_p: float = 0.0              # per-island per-slot failure probability
    down_slots: int = 30             # slots a failed island stays dark
    heartbeat_timeout: float = 5.0   # slots without a beat => evicted
    seed: int = 0


class Island:
    """One DP island: sharded train step + local data shard + power profile."""

    def __init__(self, uid: int, cfg_model, icfg: IslandConfig, mesh):
        self.uid = uid
        self.icfg = icfg
        self.device = TESTBED[DEVICE_NAMES[uid % len(DEVICE_NAMES)]]
        self.mesh = mesh
        self.step_fn = jax.jit(make_train_step(
            cfg_model, eta=icfg.eta, beta=icfg.beta))
        stream = synthetic_tokens(200_000, cfg_model.vocab_size,
                                  seed=1000 + uid)
        self._batches = token_batches(stream, icfg.batch, icfg.seq,
                                      n_batches=10 ** 9, seed=uid)
        self.ef = (ErrorFeedback(icfg.compress_ratio)
                   if icfg.compress_ratio > 0 else None)
        self.energy_j = 0.0
        self.updates = 0
        self.busy_slots = 0
        self.app: Optional[str] = None

    def local_epoch(self, params, v, lag: int):
        batch = None
        for _ in range(self.icfg.local_steps):
            batch = next(self._batches)
            batch = {k: jnp.asarray(val) for k, val in batch.items()}
            params, v, metrics = self.step_fn(params, v,
                                              batch, jnp.int32(lag))
        self.updates += 1
        return params, v, metrics


def run(cfg_model, icfg: IslandConfig, *, log=print):
    mesh = make_host_mesh()
    model = build_model(cfg_model)
    params = model.init(jax.random.PRNGKey(icfg.seed))
    if icfg.n_shards > 0:
        # serving-tier store: params partitioned over the shard mesh,
        # pushes applied shard-local (same pull/push protocol)
        from repro.serve import ShardedAsyncParameterServer
        server = ShardedAsyncParameterServer(
            params, eta=icfg.eta, beta=icfg.beta,
            aggregation=icfg.aggregation, n_shards=icfg.n_shards,
            kernel=icfg.kernel)
    else:
        server = AsyncParameterServer(params, eta=icfg.eta, beta=icfg.beta,
                                      aggregation=icfg.aggregation,
                                      kernel=icfg.kernel)
    sched = OnlineScheduler(icfg.V, icfg.L_b, icfg.eta, icfg.beta,
                            icfg.epsilon, icfg.slot_seconds)
    islands = [Island(i, cfg_model, icfg, mesh)
               for i in range(icfg.n_islands)]
    ckpt = Checkpointer(icfg.ckpt_dir) if icfg.ckpt_dir else None
    rng = np.random.default_rng(icfg.seed)
    start_slot = 0
    if ckpt and icfg.resume and ckpt.latest_step() is not None:
        restored, step = ckpt.restore({"params": params,
                                       "slot": jnp.int32(0)})
        server.params = restored["params"]
        start_slot = int(restored["slot"])
        log(f"resumed from checkpoint at slot {start_slot}")

    # fault tolerance: islands heartbeat once per slot while alive; a
    # crashed island stops beating, gets evicted after the timeout, and
    # re-enters the queue when it comes back (elastic membership — the
    # queue re-absorbs it, Def. 3 arrivals).
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(icfg.heartbeat_timeout, clock=lambda: clock["t"])
    straggle = StragglerDetector(clock=lambda: clock["t"])
    downtime = {i.uid: 0 for i in islands}
    failures = evictions = 0

    # evaluation stream (held out)
    eval_stream = synthetic_tokens(20_000, cfg_model.vocab_size, seed=7)
    eval_batches = [b for _, b in zip(range(4), token_batches(
        eval_stream, icfg.batch, icfg.seq, 4, seed=7))]
    eval_loss = jax.jit(lambda p, b: model.loss(p, b)[0])

    def evaluate(p):
        return float(np.mean([
            eval_loss(p, {k: jnp.asarray(x) for k, x in b.items()})
            for b in eval_batches]))

    state = {i.uid: {"mode": "waiting", "left": 0, "pull": None}
             for i in islands}
    history = []
    for t in range(start_slot, start_slot + icfg.slots):
        clock["t"] = float(t)
        # initial cohort enters the task queue at t=0 (Def. 3: A(0) = n)
        arrivals = len(islands) if t == start_slot else 0
        served = 0
        gap_sum = 0.0
        for isl in islands:
            # --- failure injection / recovery ---------------------------
            if downtime[isl.uid] > 0:
                downtime[isl.uid] -= 1
                if downtime[isl.uid] == 0:
                    state[isl.uid] = {"mode": "waiting", "left": 0,
                                      "pull": None}
                    arrivals += 1          # re-absorbed by the queue
                    hb.beat(isl.uid)
                continue
            if icfg.fail_p and rng.random() < icfg.fail_p:
                failures += 1
                downtime[isl.uid] = icfg.down_slots
                if state[isl.uid]["mode"] == "training":
                    server.in_flight.discard(isl.uid)   # lost island
                state[isl.uid]["mode"] = "dead"
                continue
            hb.beat(isl.uid)
            # low-price window (the "app") arrival / expiry
            if isl.app is None and rng.random() < icfg.app_arrival_p:
                isl.app = APPS[rng.integers(0, len(APPS))]
                isl._app_left = icfg.train_slots
            elif isl.app is not None:
                isl._app_left -= 1
                if isl._app_left <= 0:
                    isl.app = None

            st = state[isl.uid]
            if st["mode"] == "training":
                st["left"] -= 1
                isl.busy_slots += 1
                if st["left"] <= 0:
                    pulled_params, pulled_v, lag_est = st["pull"]
                    new_p, new_v, m = isl.local_epoch(pulled_params, pulled_v,
                                                      lag_est)
                    straggle.on_update(isl.uid)
                    if isl.ef is not None:
                        delta = jax.tree.map(lambda a, b: a - b, new_p,
                                             pulled_params)
                        payload = isl.ef.compress(delta)
                        delta = ErrorFeedback.decompress(payload)
                        new_p = jax.tree.map(
                            lambda b, d: (b.astype(jnp.float32) + d).astype(b.dtype),
                            pulled_params, delta)
                    server.push(isl.uid, new_p)
                    st["mode"] = "waiting"
                    arrivals += 1
                continue

            # waiting: Lyapunov per-slot decision (paper Alg. 2)
            a = isl.app is not None
            ap = isl.device.apps[isl.app] if a else None
            u = UserSlotState(
                p_corun=ap.p_corun if a else 0.0,
                p_app=ap.p_app if a else 0.0,
                p_train=isl.device.p_train, p_idle=isl.device.p_idle,
                app_running=a,
                lag_estimate=server.lag_estimate(isl.uid),
                idle_gap=st.get("idle_gap", 0.0))
            d = sched.decide(u, server.v_norm)
            gap_sum += d.gap
            if d.schedule:
                g_params, _ = server.pull(isl.uid)
                v0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  g_params)
                st["pull"] = (g_params, v0, u.lag_estimate)
                st["mode"] = "training"
                st["left"] = icfg.train_slots
                st["idle_gap"] = 0.0
                served += 1
            else:
                st["idle_gap"] = st.get("idle_gap", 0.0) + icfg.epsilon

        # energy accounting (Eq. 10) — dead islands draw nothing
        for isl in islands:
            if state[isl.uid]["mode"] == "dead":
                continue
            training = state[isl.uid]["mode"] == "training"
            p = isl.device.power(training, isl.app is not None, isl.app)
            isl.energy_j += p * icfg.slot_seconds
        sched.update_queues(arrivals, served, gap_sum)

        if ckpt and t and t % icfg.ckpt_every == 0:
            ckpt.save({"params": server.params, "slot": jnp.int32(t)}, t)
        if t and t % icfg.eval_every == 0:
            l = evaluate(server.params)
            history.append((t, l, sum(i.energy_j for i in islands)))
            log(f"slot {t:5d}  eval_loss {l:.4f}  "
                f"E {sum(i.energy_j for i in islands) / 1e3:.2f} kJ  "
                f"updates {server.lag_tracker.version}  "
                f"Q {sched.Q:.0f} H {sched.H:.1f}")

    if ckpt:
        ckpt.save({"params": server.params,
                   "slot": jnp.int32(icfg.slots)}, icfg.slots)
        ckpt.wait()
    return {
        "final_loss": evaluate(server.params),
        "energy_j": sum(i.energy_j for i in islands),
        "updates": server.lag_tracker.version,
        "history": history,
        "params": server.params,
        "failures": failures,
        "stragglers": sorted(straggle.stragglers()),
        "final_slot": start_slot + icfg.slots,
    }


def main():
    from repro.configs import get_config, get_smoke_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--islands", type=int, default=4)
    ap.add_argument("--slots", type=int, default=300)
    ap.add_argument("--steps-per-epoch", type=int, default=4)
    ap.add_argument("--compress", type=float, default=0.0)
    ap.add_argument("--aggregation", default="replace",
                    choices=["replace", "fedasync_poly", "gap_aware"])
    ap.add_argument("--shards", type=int, default=0,
                    help=">0: serve from the sharded parameter store")
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "pallas", "reference"],
                    help="push-apply implementation (Pallas fused vs "
                         "reference; auto = Pallas on TPU)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    icfg = IslandConfig(n_islands=args.islands, slots=args.slots,
                        local_steps=args.steps_per_epoch,
                        compress_ratio=args.compress,
                        aggregation=args.aggregation,
                        kernel=args.kernel,
                        n_shards=args.shards,
                        ckpt_dir=args.ckpt_dir)
    t0 = time.time()
    out = run(cfg, icfg)
    print(f"done in {time.time() - t0:.1f}s  final_loss={out['final_loss']:.4f}"
          f"  energy={out['energy_j'] / 1e3:.2f} kJ  updates={out['updates']}")


if __name__ == "__main__":
    main()
