"""Analytic MODEL_FLOPS per (arch, shape) — the 'useful compute' yardstick.

Convention (system prompt):
    train:   6 * N * D      (N = params; N_active for MoE; D = tokens)
    prefill: 2 * N * D
    decode:  2 * N * D      (D = global_batch tokens per step)

The MODEL_FLOPS / HLO_FLOPs ratio in the roofline table measures how much of
the compiled compute is useful (remat recompute, attention quadratic work,
router/dispatch overhead, dead padding all push it down).
"""
from __future__ import annotations

from .shapes import SHAPES


def model_flops(cfg, shape: str) -> float:
    spec = SHAPES[shape]
    n = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    if spec.kind == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n * tokens
    if spec.kind == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n * tokens
    tokens = spec.global_batch  # one new token per sequence
    return 2.0 * n * tokens
