"""Assigned input-shape sets and ShapeDtypeStruct stand-ins per (arch, shape).

Shapes (LM family, seq_len x global_batch):
    train_4k     4,096 x 256   -> train_step
    prefill_32k  32,768 x 32   -> prefill_step (serve)
    decode_32k   32,768 x 128  -> decode_step (1 new token, KV cache of 32k)
    long_500k    524,288 x 1   -> decode_step; ONLY for sub-quadratic archs
                                  (mamba2, zamba2) — skipped for the 8 pure
                                  full-attention archs (DESIGN.md §6).

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs — no
device allocation; the dry-run lowers against them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# train_4k microbatch count per arch family size: keeps per-microbatch
# activations within HBM (DESIGN.md §4). global_batch 256 / M must stay
# divisible by the dp-axis product (32 on the multi-pod mesh) -> M=8.
TRAIN_MICROBATCHES = 8

# archs whose params+opt state (or MoE dispatch buffers, or model-axis-
# indivisible replicated attention weights) exceed single-axis sharding:
# FSDP on for TRAIN cells. Serving keeps TP-only params (per-token FSDP
# gathers would dominate decode).
FSDP_ARCHS = {"qwen3-moe-30b-a3b", "internlm2-20b", "internvl2-76b",
              "granite-moe-1b-a400m", "phi4-mini-3.8b", "whisper-large-v3"}

# >=20B archs whose bf16 weights + 32k KV cache cannot share one v5e chip
# under TP-only sharding: serve with weight-sharded (FSDP-style) params too —
# the per-layer all-gather amortizes over the 128-sequence decode batch.
FSDP_SERVE_ARCHS = {"internvl2-76b", "internlm2-20b"}


def applicable(cfg, shape: str) -> bool:
    """long_500k only for sub-quadratic (O(1)-state decode) archs."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def production_config(cfg, shape: str):
    """Per-cell production overrides that make the cell fit HBM (recorded in
    the dry-run JSON): chunked attention for 4k+ sequence work (einsum
    attention materializes (Sq, Sk) scores — 100s of GB/device at 32k), and
    sequence-parallel activations for the wide (d_model >= 3k) train cells
    (the remat stack L x (B, S, D) dominates otherwise)."""
    spec = SHAPES[shape]
    over = {}
    if cfg.num_heads and spec.kind in ("train", "prefill") \
            and spec.seq_len >= 4096:
        over["attention_impl"] = "chunked"
    if spec.kind == "train" and cfg.d_model >= 3072:
        over["shard_activations"] = True
    return (dataclasses.replace(cfg, **over) if over else cfg), over


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg, spec: ShapeSpec, *, microbatches: int = 1) -> Dict[str, Any]:
    """The token batch a step consumes (train/prefill); decode uses 1 token."""
    B, S = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        M = microbatches
        assert B % M == 0
        lead = (M, B // M) if M > 1 else (B,)
    else:
        lead = (B,)
    if spec.kind == "decode":
        batch = {"tokens": _sds(lead + (1,), jnp.int32)}
    else:
        batch = {"tokens": _sds(lead + (S,), jnp.int32)}
        if spec.kind == "train":
            batch["labels"] = _sds(lead + (S,), jnp.int32)
    # modality frontends are STUBS: precomputed frame/patch embeddings
    if cfg.family == "audio" and spec.kind != "decode":
        batch["audio_embeds"] = _sds(lead + (cfg.encoder_seq, cfg.d_model),
                                     jnp.float32)
    if cfg.family == "vlm" and spec.kind != "decode":
        batch["vision_embeds"] = _sds(lead + (cfg.num_vision_tokens, cfg.d_model),
                                      jnp.float32)
    return batch


def cache_specs(cfg, spec: ShapeSpec):
    """ShapeDtypeStructs of the decode cache (KV / SSM state) at seq_len."""
    from repro.models import build_model
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(spec.global_batch, spec.seq_len))


def input_specs(cfg, shape, *, microbatches: int | None = None):
    """Returns (kind, kwargs-dict of ShapeDtypeStructs) for the step fn.
    ``shape`` is a shape name or a ShapeSpec."""
    spec = SHAPES[shape] if isinstance(shape, str) else shape
    if spec.kind == "train":
        M = TRAIN_MICROBATCHES if microbatches is None else microbatches
        return "train", {"batch": batch_specs(cfg, spec, microbatches=M)}
    if spec.kind == "prefill":
        return "prefill", {"batch": batch_specs(cfg, spec),
                           "cache": cache_specs(cfg, spec)}
    return "decode", {"cache": cache_specs(cfg, spec),
                      "batch": batch_specs(cfg, spec)}
