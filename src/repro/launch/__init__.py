"""Distribution/launch layer: production meshes, sharded train/serve steps,
the multi-pod dry-run driver, and the roofline analyser."""
