"""Sharded step functions: the units the dry-run lowers and the drivers jit.

``make_train_step(cfg)``  -> (params, v, batch, lag) -> (params', v', metrics)
    One island-local update of the paper's system at LM scale: microbatched
    grad accumulation (f32, param-sharded) + the paper's fused momentum
    update (Eq. 1) + gradient-gap norm (Eq. 4) — the scalar each island
    reports to the Lyapunov scheduler.

``make_prefill_step(cfg)`` -> (params, batch, cache) -> (logits, cache')
``make_decode_step(cfg)``  -> (params, cache, batch) -> (logits, cache')

``step_shardings``: NamedShardings for every argument/output, built from the
models.sharding rules (+FSDP post-pass for the >=20B archs).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import build_model, sharding
from repro.optim.gap import fused_momentum_gap_update

from .shapes import (FSDP_ARCHS, FSDP_SERVE_ARCHS, SHAPES, batch_specs,
                     cache_specs, input_specs)


# ------------------------------------------------------------------- steps
def make_train_step(cfg, *, eta: float = 1e-2, beta: float = 0.9,
                    microbatches: int = 1, unroll_microbatches: bool = False):
    """Microbatched momentum-SGD train step with the paper's gap norm.

    unroll_microbatches: python-loop the grad-accumulation instead of
    lax.scan — used only by the dry-run flop calibration (see dryrun.py)."""
    model = build_model(cfg)

    def loss_grads(params, mb):
        (l, met), grads = jax.value_and_grad(model.loss, has_aux=True)(params, mb)
        return grads, l, met

    def train_step(params, v, batch, lag):
        if microbatches == 1:
            grads, loss, _ = loss_grads(params, batch)
        else:
            accum0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                grads, l, _ = loss_grads(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   acc, grads)
                return acc, l

            if unroll_microbatches:
                accum, ls = accum0, []
                for i in range(microbatches):
                    accum, l = body(accum, jax.tree.map(lambda t: t[i], batch))
                    ls.append(l)
                losses = jnp.stack(ls)
            else:
                accum, losses = jax.lax.scan(body, accum0, batch)
            grads = jax.tree.map(lambda a: a / microbatches, accum)
            loss = jnp.mean(losses)
        new_params, new_v, gap = fused_momentum_gap_update(
            params, v, grads, eta=eta, beta=beta, lag=lag)
        return new_params, new_v, {"loss": loss, "gap": gap}

    return train_step


def make_update_step(cfg, *, eta: float = 1e-2, beta: float = 0.9):
    """The fused-update epilogue alone (dry-run calibration baseline)."""

    def upd_step(params, v, grads, lag):
        return fused_momentum_gap_update(params, v, grads, eta=eta, beta=beta,
                                         lag=lag)

    return upd_step


def make_prefill_step(cfg):
    model = build_model(cfg)

    def prefill_step(params, batch, cache):
        logits, new_cache = model.prefill(params, batch, cache)
        return logits, new_cache

    return prefill_step


def make_decode_step(cfg, *, greedy: bool = True):
    model = build_model(cfg)

    def decode_step(params, cache, batch):
        logits, new_cache = model.decode_step(params, cache, batch)
        out = jnp.argmax(logits, axis=-1) if greedy else logits
        return out, new_cache

    return decode_step


# --------------------------------------------------------------- shardings
def param_shardings(cfg, mesh, *, fsdp: bool | None = None):
    """cfg.parallel_layout == "tp": weights sharded over "model" (+optional
    FSDP). "dp": weights replicated (or ZeRO-sharded over every axis with
    fsdp=True), batch over EVERY mesh axis — the right layout for models
    whose TP activation psums dominate (sub-1B archs on a 256-chip pod)."""
    from jax.sharding import PartitionSpec as P

    model = build_model(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if cfg.parallel_layout == "dp":
        specs = jax.tree.map(lambda l: P(*([None] * len(l.shape))), pshape)
        # embedding/lm_head stay vocab-sharded over "model": the (B,S,V)
        # logits and the tied-embedding grads are vocab-wide tensors whose
        # replication dominated the dp layout's memory roofline.
        m = mesh.shape["model"]
        if isinstance(specs, dict) and "embed" in specs \
                and pshape["embed"].shape[0] % m == 0:
            specs = dict(specs)
            specs["embed"] = P("model", None)
            if "lm_head" in specs and pshape["lm_head"].shape[1] % m == 0:
                specs["lm_head"] = P(None, "model")
        if fsdp:
            specs = sharding.apply_fsdp(specs, pshape, mesh)
        return pshape, sharding.named(specs, mesh)
    specs = sharding.param_pspecs(cfg, pshape, mesh)
    if fsdp is None:
        fsdp = cfg.name in FSDP_ARCHS
    if fsdp:
        specs = sharding.apply_fsdp(specs, pshape, mesh)
    return pshape, sharding.named(specs, mesh)


def step_shardings(cfg, shape, mesh, *, fsdp: bool | None = None,
                   microbatches: int | None = None):
    """(kind, kwargs_specs, in_shardings tuple, out_shardings) for the cell."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    kind, kwargs = input_specs(cfg, shape, microbatches=microbatches)
    if fsdp is None:
        fsdp = (cfg.name in FSDP_ARCHS if kind == "train"
                else cfg.name in FSDP_SERVE_ARCHS)
    pshape, pshard = param_shardings(cfg, mesh, fsdp=fsdp)
    repl = NamedSharding(mesh, P())

    bspec = sharding.named(
        sharding.batch_pspecs(cfg, kwargs["batch"], mesh), mesh)
    if kind == "train":
        vshard = pshard  # momentum tree mirrors the params
        in_sh = (pshard, vshard, bspec, repl)
        out_sh = (pshard, vshard, jax.tree.map(lambda _: repl,
                                               {"loss": 0, "gap": 0}))
    else:
        cshard = sharding.named(
            sharding.cache_pspecs(cfg, kwargs["cache"], mesh), mesh)
        if kind == "prefill":
            in_sh = (pshard, bspec, cshard)
        else:
            in_sh = (pshard, cshard, bspec)
        out_sh = None  # let GSPMD choose logits/cache output layout
    return kind, kwargs, pshape, in_sh, out_sh


def lower_cell(cfg, shape: str, mesh, *, eta: float = 1e-2, beta: float = 0.9,
               fsdp: bool | None = None, microbatches: int | None = None,
               batch_div: int = 1):
    """jit().lower() the step for one (arch x shape x mesh) cell.

    batch_div scales the global batch down (dry-run calibration lowers a
    single microbatch of global_batch / TRAIN_MICROBATCHES sequences)."""
    import dataclasses

    from . import shapes as shapes_mod
    from .shapes import TRAIN_MICROBATCHES

    spec = SHAPES[shape]
    M = (TRAIN_MICROBATCHES if microbatches is None else microbatches) \
        if spec.kind == "train" else 1
    if batch_div > 1:
        spec = dataclasses.replace(spec,
                                   global_batch=spec.global_batch // batch_div)
    kind, kwargs, pshape, in_sh, out_sh = step_shardings(
        cfg, spec, mesh, fsdp=fsdp, microbatches=M)

    if kind == "train":
        step = make_train_step(cfg, eta=eta, beta=beta, microbatches=M)
        vshape = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), pshape)
        args = (pshape, vshape, kwargs["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
    else:
        # serving holds bf16 weights (the model casts per-use anyway);
        # f32 serving params would double the per-device HBM footprint.
        pshape = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(
                p.shape, jnp.bfloat16 if p.dtype == jnp.float32 else p.dtype),
            pshape)
        if kind == "prefill":
            step = make_prefill_step(cfg)
            args = (pshape, kwargs["batch"], kwargs["cache"])
        else:
            step = make_decode_step(cfg)
            args = (pshape, kwargs["cache"], kwargs["batch"])

    # donation: params/momentum update in place for train; KV/SSM cache in
    # place for serving — without it XLA allocates a second copy of the
    # largest state (31 GiB/dev observed for the 76B decode cell).
    donate = {"train": (0, 1), "prefill": (2,), "decode": (1,)}[kind]
    from .mesh import set_mesh
    with set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
    return lowered, kind
