"""HLO-text analysis: collective-bytes accounting for the roofline.

Parses ``compiled.as_text()`` (post-SPMD, per-device module) and sums the
bytes of every collective op:

    all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute

Bytes = the op's OUTPUT shape bytes (operand bytes for these ops equal the
output except all-gather, where the output is the gathered extent — the
amount that actually crosses links per device; ring-algorithm per-link
traffic factors are applied later in roofline.py).

While-loop bodies (lax.scan over layers / microbatches) appear ONCE in the
text but execute trip-count times; ``collective_bytes`` walks the
computation call graph and multiplies each computation's bytes by the
product of enclosing while trip counts, read from the loop's
``backend_config={"known_trip_count":{"n":...}}`` annotation.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Tuple

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=)%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_COLL_RE = re.compile(
    r"=\s*(.*?)\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples):
    'f32[16,128]{1,0}' -> 8192; '(f32[2], bf16[4])' -> 16."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    """-> ({computation name: instruction lines}, entry computation name)."""
    comps: Dict[str, List[str]] = {}
    cur, entry = None, None
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m and cur is None:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None and line.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Per-collective-type bytes (per device, trip-count weighted)."""
    comps, entry = split_computations(hlo)

    direct: Dict[str, Dict[str, float]] = {}
    children: Dict[str, List[Tuple[str, float]]] = {}
    for name, lines in comps.items():
        d: Dict[str, float] = defaultdict(float)
        ch: List[Tuple[str, float]] = []
        for line in lines:
            s = line.strip()
            cm = _COLL_RE.search(s)
            if cm and ("-done(" not in s):   # count start/plain once, not done
                d[cm.group(2)] += shape_bytes(cm.group(1))
            if " while(" in s or s.startswith("while("):
                tm = _TRIP_RE.search(s)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%?([\w\.\-]+)", s)
                if bm:
                    ch.append((bm.group(1), trips))
                continue
            for m in _CALL_RE.finditer(s):
                ch.append((m.group(1), 1.0))
            bm = _BRANCH_RE.search(s)
            if bm:
                for b in bm.group(1).split(","):
                    ch.append((b.strip().lstrip("%"), 1.0))
        direct[name] = dict(d)
        children[name] = ch

    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, stack=frozenset()) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {}
        out = defaultdict(float, direct.get(name, {}))
        for child, mult in children.get(name, []):
            for k, v in total(child, stack | {name}).items():
                out[k] += mult * v
        memo[name] = dict(out)
        return memo[name]

    if entry is None:
        out: Dict[str, float] = defaultdict(float)
        for d in direct.values():
            for k, v in d.items():
                out[k] += v
        return dict(out)
    return dict(total(entry))


def collective_summary(hlo: str) -> Dict[str, float]:
    d = dict(collective_bytes(hlo))
    d["total"] = float(sum(d.values()))
    return d


def count_ops(hlo: str, opname: str) -> int:
    return len(re.findall(rf"=\s*[^=]*\b{opname}\(", hlo))


# --------------------------------------------------------------- HBM bytes
# Ops that move no data (aliases / bookkeeping).
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast", "constant",
             "after-all", "opt-barrier", "partition-id", "replica-id"}

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^((?:\([^=]*?\)|[\w\[\]\{\},\d\.]+)\s+)?([\w\-]+)\(")
_REF_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\]\{\},\d]+))")
_GROUPSZ_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPSET_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _header_params(header: str) -> Dict[str, int]:
    """'%f (p0: f32[2,4], p1: (f32[2], s32[]))' -> {name: bytes}."""
    out = {}
    inner = header[header.find("(") + 1:]
    for name, shape in _PARAM_RE.findall(inner):
        out[name] = shape_bytes(shape)
    return out


def hbm_bytes(hlo: str) -> float:
    """Fusion-aware per-device HBM traffic estimate.

    Sums (output + operand) bytes over TOP-LEVEL instructions of the entry
    computation and control-flow computations (while bodies x trip count,
    conditional branches). Fusion-internal instructions are excluded — a
    fusion op's operands/outputs at the call site are the real traffic —
    which is what XLA's own fusion-naive 'bytes accessed' on CPU overstates.
    Alias-only ops (bitcast/tuple/gte/parameter/constant) are free."""
    comps, entry = split_computations(hlo)
    headers: Dict[str, Dict[str, int]] = {}
    # recover headers (split_computations drops them): re-scan text
    for line in hlo.splitlines():
        m = _COMP_HDR_RE.match(line.strip())
        if m:
            headers[m.group(2)] = _header_params(line)

    def comp_bytes(name: str, stack=frozenset()) -> float:
        if name not in comps or name in stack:
            return 0.0
        shape_of: Dict[str, int] = dict(headers.get(name, {}))
        # first pass: record each instruction's output bytes
        parsed = []
        for line in comps[name]:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, rhs = m.group(1), m.group(2)
            om = _OPNAME_RE.match(rhs)
            if not om:
                continue
            out_shape = om.group(1) or ""
            op = om.group(2)
            shape_of[iname] = shape_bytes(out_shape)
            parsed.append((iname, op, rhs))
        total = 0.0
        for iname, op, rhs in parsed:
            if op in _FREE_OPS:
                continue
            if op == "while":
                tm = _TRIP_RE.search(rhs)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                if bm:
                    total += trips * comp_bytes(bm.group(1), stack | {name})
                continue
            if op == "conditional":
                bm = _BRANCH_RE.search(rhs)
                if bm:
                    for b in bm.group(1).split(","):
                        total += comp_bytes(b.strip().lstrip("%"),
                                            stack | {name})
                continue
            # operand refs up to metadata junk: take the call parens content
            body = rhs[rhs.find("("):]
            cut = body.find("), ")
            operands = body if cut < 0 else body[:cut + 1]
            b = shape_of.get(iname, 0)
            for ref in _REF_RE.findall(operands):
                b += shape_of.get(ref, 0)
            total += b
        return total

    if entry is None:
        return 0.0
    return comp_bytes(entry)


def quadratic_traffic(hlo: str, min_dim: int = 2048,
                      pair: tuple = (-2, -1), second_min: int | None = None,
                      rank_min: int = 0,
                      exclude_last: frozenset = frozenset()) -> float:
    """HBM traffic attributable to attention-score-like tensors: operands/
    outputs whose dims at positions `pair` are >= (second_min, min_dim)
    ((..., Sq|bq, Sk) score matrices — second_min < min_dim catches the
    chunked-attention (..., bq, Sk) blocks too; pair=(-3,-2) catches the
    SSD intra-chunk (..., Q, Q, nh) masks). rank_min excludes rank-2/3
    lookalikes (logits, MLP activations).

    Used to model the Pallas kernel variants in the roofline: the kernels
    keep these tiles in VMEM, so kernel_hbm = hbm_bytes -
    quadratic_traffic (q/k/v/o and everything else unchanged)."""
    comps, entry = split_computations(hlo)
    lo = min_dim if second_min is None else second_min

    def is_quadratic(shape_str: str) -> bool:
        m = _SHAPE_RE.search(shape_str or "")
        if not m:
            return False
        dims = [int(d) for d in m.group(2).split(",") if d]
        i, j = pair
        return (len(dims) >= max(rank_min, -i, -j)
                and dims[i] >= lo and dims[j] >= min_dim
                and dims[-1] not in exclude_last)

    def comp_traffic(name: str, stack=frozenset()) -> float:
        if name not in comps or name in stack:
            return 0.0
        qbytes: Dict[str, int] = {}
        parsed = []
        for line in comps[name]:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, rhs = m.group(1), m.group(2)
            om = _OPNAME_RE.match(rhs)
            if not om:
                continue
            out_shape, op = om.group(1) or "", om.group(2)
            qbytes[iname] = shape_bytes(out_shape) if is_quadratic(out_shape) \
                else 0
            parsed.append((iname, op, rhs))
        total = 0.0
        for iname, op, rhs in parsed:
            if op in _FREE_OPS:
                continue
            if op == "while":
                tm = _TRIP_RE.search(rhs)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                if bm:
                    total += trips * comp_traffic(bm.group(1), stack | {name})
                continue
            body = rhs[rhs.find("("):]
            cut = body.find("), ")
            operands = body if cut < 0 else body[:cut + 1]
            b = qbytes.get(iname, 0)
            for ref in _REF_RE.findall(operands):
                b += qbytes.get(ref, 0)
            total += b
        return total

    return comp_traffic(entry) if entry else 0.0


def collective_group_sizes(hlo: str) -> Dict[str, float]:
    """Mean collective group size per op type (for ring-traffic factors)."""
    out: Dict[str, list] = defaultdict(list)
    for line in hlo.splitlines():
        cm = _COLL_RE.search(line)
        if not cm or "-done(" in line:
            continue
        k = None
        g = _GROUPSZ_RE.search(line)
        if g:
            k = int(g.group(2))
        else:
            g = _GROUPSET_RE.search(line)
            if g:
                k = len(g.group(1).split(","))
        if k:
            out[cm.group(2)].append(k)
    return {t: sum(v) / len(v) for t, v in out.items() if v}
