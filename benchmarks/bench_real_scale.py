"""Real-ML engine scaling: loop oracle vs batched vectorized engine with
actual LeNet training coupled to the schedule (Fig. 5 workload at fleet
scale).

The loop engine dispatches one Python callback chain per user event —
jitted per-client local train (one sync per call), then ~20 eager jnp ops
of parameter-server push — while the vectorized engine runs the slot loop
on struct-of-arrays state and handles each slot's finisher cohort with a
single fused vmap-epoch + ordered-push-scan dispatch
(core/realml.LeNetBackend). The headline number is the steady-state
vectorized-vs-loop speedup at n_users=64 (acceptance floor 5x).

Methodology (matches bench_sim_scale's jax treatment): each engine gets a
WARMUP run first, so jit compilation — a handful of stable shapes for the
vectorized engine, one per distinct shard size for the loop's per-client
epochs — is excluded from the timed run, which is what a convergence
sweep amortizes to.

fast mode isolates ENGINE cost: a homogeneous fleet (every user the
Pixel2 row, so device classes finish in lock-step, full-width cohorts)
with uniform 1-sample shards — real gradients and momentum, minimal
per-update FLOPs, the regime the batched engine exists for. ``--full``
uses the paper's setup (Table II fleet, Dirichlet shards, batch 20,
400 samples/client, app arrivals), where wall-clock converges toward the
training FLOPs both engines share and the speedup compresses — that
floor is documented, not hidden.

An aggregation-rule sweep (vectorized engine at n=64) rides along: the
four registered rules of core/aggregation.py on the same workload, with
``slowdown_vs_replace`` reporting what the staleness-aware weighted push
scan costs over the paper's replace rule (the rule's ``scan_weight``
runs INSIDE the fused train+push program, so the expected answer is
~1.0x).

A kernel on/off pair (vectorized engine at n=64) rides along too: the
same workload with ``kernel="reference"`` (jnp traversals) vs
``kernel="pallas"`` (the fused_update apply kernel in the push scan),
with ``slowdown_vs_reference`` as the tracked column. Off-TPU the Pallas
path runs in interpret mode, so on CI hosts this pins
overhead-neutrality rather than claiming a hardware speedup.

Besides the CSV stream every run persists ``BENCH_real_scale.json`` (see
``common.write_json``) so the real-mode scaling trajectory is
machine-readable across PRs.
"""
from __future__ import annotations

import time

from repro.core.energy import TESTBED
from repro.core.fleet import CustomCatalogFleet
from repro.core.realml import LeNetBackend
from repro.core.simulator import FederatedSim, SimConfig

SIZES = (8, 64, 256)
# aggregation-rule sweep (vectorized engine, mid fleet size): what the
# staleness-aware weighted push scan costs relative to replace —
# core/aggregation.py runs the rule's scan_weight INSIDE the fused
# train+push program, so the answer should be ~nothing
AGG_RULES = ("replace", "fedasync_poly", "gap_aware", "hetero_aware")
AGG_N = 64
JSON_PATH = "BENCH_real_scale.json"


def _run(engine: str, n: int, horizon: int, fast: bool, seed: int = 0,
         aggregation: str = "replace", kernel: str = "reference"):
    if fast:
        backend = LeNetBackend(n, sync=False, n_train=n, n_test=256,
                               seed=seed, eval_every=1200, batch_size=1,
                               partition="uniform", cohort_pad=64,
                               aggregation=aggregation, kernel=kernel)
        fleet = CustomCatalogFleet([TESTBED["Pixel2"]])
        arrival_p = 0.0
    else:
        backend = LeNetBackend(n, sync=False, n_train=400 * n, n_test=1000,
                               seed=seed, eval_every=1200, batch_size=20,
                               aggregation=aggregation, kernel=kernel)
        fleet = None                     # Table II round-robin
        arrival_p = 0.004
    cfg = SimConfig(policy="immediate", n_users=n, horizon_s=horizon,
                    engine=engine, seed=seed, ml_mode="real",
                    app_arrival_p=arrival_p, collect_push_log=False,
                    aggregation=aggregation)
    sim = FederatedSim(cfg, ml_backend=backend, fleet=fleet)
    t0 = time.perf_counter()
    r = sim.run()
    return time.perf_counter() - t0, r


def run(fast: bool = True):
    horizon = 2400 if fast else 3600
    warmup_horizon = 500          # first finish wave lands at ~220 s
    rows = []
    for n in SIZES:
        loop_wall = None
        for engine in ("loop", "vectorized"):
            warmup_s, _ = _run(engine, n, warmup_horizon, fast)
            wall, r = _run(engine, n, horizon, fast)
            rows.append({
                "bench": "real_scale", "engine": engine, "n_users": n,
                "aggregation": "replace", "kernel": "reference",
                "horizon_s": horizon, "fast": fast,
                "wall_s": round(wall, 3),
                "warmup_s": round(warmup_s, 3),
                "updates": r.updates,
                "updates_per_s": round(r.updates / wall, 1),
                "final_acc": round(r.accuracy[-1][1], 4) if r.accuracy
                else "",
                "energy_kj": round(r.energy_j / 1e3, 2),
                "speedup_vs_loop":
                    round(loop_wall / wall, 2) if loop_wall else "",
                "slowdown_vs_replace": "",
                "slowdown_vs_reference": "",
            })
            if engine == "loop":
                loop_wall = wall

    # aggregation-rule sweep: same workload, vectorized engine, the four
    # registered rules (replace is the baseline row above repeated here
    # so the sweep is self-contained)
    replace_wall = None
    for agg in AGG_RULES:
        warmup_s, _ = _run("vectorized", AGG_N, warmup_horizon, fast,
                           aggregation=agg)
        wall, r = _run("vectorized", AGG_N, horizon, fast, aggregation=agg)
        rows.append({
            "bench": "real_scale", "engine": "vectorized",
            "n_users": AGG_N, "aggregation": agg, "kernel": "reference",
            "horizon_s": horizon, "fast": fast,
            "wall_s": round(wall, 3),
            "warmup_s": round(warmup_s, 3),
            "updates": r.updates,
            "updates_per_s": round(r.updates / wall, 1),
            "final_acc": round(r.accuracy[-1][1], 4) if r.accuracy
            else "",
            "energy_kj": round(r.energy_j / 1e3, 2),
            "speedup_vs_loop": "",
            "slowdown_vs_replace":
                round(wall / replace_wall, 2) if replace_wall else "",
            "slowdown_vs_reference": "",
        })
        if agg == "replace":
            replace_wall = wall

    # kernel on/off pair: the fused-apply push scan vs the reference
    # traversals on the same workload. Off-TPU the Pallas path runs
    # interpret mode, so the column tracks overhead-neutrality there,
    # not a hardware speedup.
    ref_wall = None
    for kernel in ("reference", "pallas"):
        warmup_s, _ = _run("vectorized", AGG_N, warmup_horizon, fast,
                           kernel=kernel)
        wall, r = _run("vectorized", AGG_N, horizon, fast, kernel=kernel)
        rows.append({
            "bench": "real_scale", "engine": "vectorized",
            "n_users": AGG_N, "aggregation": "replace", "kernel": kernel,
            "horizon_s": horizon, "fast": fast,
            "wall_s": round(wall, 3),
            "warmup_s": round(warmup_s, 3),
            "updates": r.updates,
            "updates_per_s": round(r.updates / wall, 1),
            "final_acc": round(r.accuracy[-1][1], 4) if r.accuracy
            else "",
            "energy_kj": round(r.energy_j / 1e3, 2),
            "speedup_vs_loop": "",
            "slowdown_vs_replace": "",
            "slowdown_vs_reference":
                round(wall / ref_wall, 2) if ref_wall else "",
        })
        if kernel == "reference":
            ref_wall = wall

    from benchmarks.common import write_json
    import jax
    write_json(rows, JSON_PATH,
               meta={"bench": "real_scale", "fast": fast,
                     "policy": "immediate", "ml": "lenet",
                     "backend": jax.default_backend()})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
