"""Kernel micro-bench: wall time of the XLA oracle paths on host (the
Pallas kernels themselves target TPU; interpret mode is not a timing
proxy) + the analytic HBM-traffic ratios the kernels buy.

fused_update: 7 passes naive / 5 fused = 1.4x traffic cut.
flash_attention: removes the (Sq x Sk) f32 score tensor round-trips.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.gap import fused_momentum_gap_update


def _time(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(fast: bool = True):
    n = 1 << 20 if fast else 1 << 24
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    p = {"w": jax.random.normal(ks[0], (n,))}
    v = {"w": jax.random.normal(ks[1], (n,))}
    g = {"w": jax.random.normal(ks[2], (n,))}

    fused = jax.jit(lambda p_, v_, g_: fused_momentum_gap_update(
        p_, v_, g_, eta=0.01, beta=0.9, lag=jnp.int32(3)))

    @jax.jit
    def three_pass(p_, v_, g_):
        v2 = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, v_, g_)
        p2 = jax.tree.map(lambda a, b: a - 0.01 * b, p_, v2)
        sq = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(v2))
        return p2, v2, jnp.sqrt(sq)

    t_fused = _time(fused, p, v, g)
    t_three = _time(three_pass, p, v, g)
    return [{
        "bench": "kernels", "kernel": "fused_update",
        "n_params": n,
        "fused_ms": round(1e3 * t_fused, 3),
        "unfused_ms": round(1e3 * t_three, 3),
        "speedup_host": round(t_three / t_fused, 3),
        "traffic_ratio_model": round(7 / 5, 3),
    }]


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
