"""Kernel micro-bench: fused-vs-naive wall time for both hot-path
contracts (CLIENT update and SERVER apply) at both entry granularities
(flat f32 vector and realml-style pytree), sizes 64k and 1M params.

Per (entry, form, n) row:

* ``naive_ms``  — the multi-traversal jnp path the fused kernels replace
  (separate momentum, parameter, and norm passes over HBM),
* ``fused_ms``  — the single-jit XLA oracle (``optim/gap.py``),
* ``pallas_ms`` — the Pallas entry itself. Only a hardware timing on
  TPU; off-TPU it runs interpret mode (``pallas_mode`` column says
  which), recorded for trend tracking, not as a speedup claim.

Traffic model: update reads theta/v/g and writes theta'/v' + a scalar
(5 fused passes vs 7 naive); apply reads cur/v/new and writes
mixed/v' + a scalar (5 vs 8 — the naive path re-reads mixed for the
server step and v' for the norm).

Every run persists ``BENCH_kernels.json`` (see ``common.write_json``)
so the kernel trajectory is machine-readable across PRs.

    PYTHONPATH=src python -m benchmarks.bench_kernels --fast
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels.fused_update import (fused_apply_flat,
                                        fused_momentum_gap_update_pallas,
                                        fused_update_flat,
                                        fused_weighted_apply_pallas,
                                        kernel_interpret)
from repro.optim.gap import fused_momentum_gap_update, fused_weighted_apply

JSON_PATH = "BENCH_kernels.json"
SIZES = (65_536, 1_048_576)
ETA, BETA, W = 0.05, 0.9, 0.6


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e3 * (time.perf_counter() - t0) / reps


def _tree(n: int):
    """A realml-shaped pytree (mixed leaf sizes) totalling n params."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    d = 64
    rows = (n - d * d - d) // d
    return {"embed": jax.random.normal(ks[0], (rows, d)),
            "head": {"w": jax.random.normal(ks[1], (d, d)),
                     "b": jax.random.normal(ks[2], (n - rows * d - d * d,))}}


def _flat(n: int, seed: int):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,))


def _variants(n: int):
    """(entry, form) -> (naive_fn, fused_fn, pallas_fn, args)."""
    interp = kernel_interpret()
    inv_eta = 1.0 / ETA

    @jax.jit
    def update_naive_flat(th, v, g):
        v2 = BETA * v + (1 - BETA) * g
        th2 = th - ETA * v2
        return th2, v2, jnp.sqrt(jnp.sum(jnp.square(v2)))

    @jax.jit
    def update_fused_flat(th, v, g):
        v2 = BETA * v + (1 - BETA) * g
        return th - ETA * v2, v2, jnp.sqrt(jnp.sum(v2 * v2))

    @jax.jit
    def apply_naive_flat(cur, v, new):
        mixed = W * new + (1 - W) * cur
        s = (cur - mixed) * inv_eta
        v2 = BETA * v + (1 - BETA) * s
        return mixed, v2, jnp.sqrt(jnp.sum(jnp.square(v2)))

    @jax.jit
    def apply_fused_flat(cur, v, new):
        mixed = W * new + (1 - W) * cur
        v2 = BETA * v + (1 - BETA) * ((cur - mixed) * inv_eta)
        return mixed, v2, jnp.sqrt(jnp.sum(v2 * v2))

    @jax.jit
    def update_naive_tree(p, v, g):
        v2 = jax.tree.map(lambda a, b: BETA * a + (1 - BETA) * b, v, g)
        p2 = jax.tree.map(lambda a, b: a - ETA * b, p, v2)
        sq = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(v2))
        return p2, v2, jnp.sqrt(sq)

    @jax.jit
    def apply_naive_tree(cur, v, new):
        mixed = jax.tree.map(lambda a, b: W * b + (1 - W) * a, cur, new)
        s = jax.tree.map(lambda a, b: (a - b) * inv_eta, cur, mixed)
        v2 = jax.tree.map(lambda a, b: BETA * a + (1 - BETA) * b, v, s)
        sq = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(v2))
        return mixed, v2, jnp.sqrt(sq)

    update_fused_tree = jax.jit(
        lambda p, v, g: fused_momentum_gap_update(
            p, v, g, eta=ETA, beta=BETA, lag=jnp.int32(3)))
    apply_fused_tree = jax.jit(
        lambda c, v, nw: fused_weighted_apply(c, v, nw, w=W, eta=ETA,
                                              beta=BETA))

    flat = tuple(_flat(n, s) for s in range(3))
    tree = (_tree(n),
            jax.tree.map(lambda a: 0.1 * a, _tree(n)),
            jax.tree.map(lambda a: -a, _tree(n)))
    return {
        ("update", "flat"): (
            update_naive_flat, update_fused_flat,
            lambda th, v, g: fused_update_flat(th, v, g, ETA, BETA,
                                               interpret=interp), flat),
        ("apply", "flat"): (
            apply_naive_flat, apply_fused_flat,
            lambda c, v, nw: fused_apply_flat(c, v, nw, W, inv_eta, BETA,
                                              interpret=interp), flat),
        ("update", "pytree"): (
            update_naive_tree, update_fused_tree,
            lambda p, v, g: fused_momentum_gap_update_pallas(
                p, v, g, eta=ETA, beta=BETA, lag=jnp.int32(3),
                interpret=interp), tree),
        ("apply", "pytree"): (
            apply_naive_tree, apply_fused_tree,
            lambda c, v, nw: fused_weighted_apply_pallas(
                c, v, nw, w=W, eta=ETA, beta=BETA, interpret=interp),
            tree),
    }


TRAFFIC = {"update": 7 / 5, "apply": 8 / 5}


def run(fast: bool = True):
    reps = 3 if fast else 10
    on_tpu = jax.default_backend() == "tpu"
    rows = []
    for n in SIZES:
        for (entry, form), (naive, fused, pallas, args) in \
                _variants(n).items():
            t_naive = _time(naive, *args, reps=reps)
            t_fused = _time(fused, *args, reps=reps)
            t_pallas = _time(pallas, *args, reps=reps)
            rows.append({
                "bench": "kernels", "entry": entry, "form": form,
                "n_params": n,
                "naive_ms": round(t_naive, 3),
                "fused_ms": round(t_fused, 3),
                "pallas_ms": round(t_pallas, 3),
                "pallas_mode": "tpu" if on_tpu else "interpret",
                "speedup_host": round(t_naive / t_fused, 3),
                "traffic_ratio_model": round(TRAFFIC[entry], 3),
            })

    from benchmarks.common import write_json
    write_json(rows, JSON_PATH,
               meta={"bench": "kernels", "fast": fast,
                     "backend": jax.default_backend()})
    return rows


def main():
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    emit(run(fast=args.fast))


if __name__ == "__main__":
    main()
