"""Shared benchmark plumbing: CSV emission + default horizons.

Every bench_* module exposes ``run(fast: bool) -> list[dict]`` rows; the
``benchmarks.run`` driver aggregates them into one CSV stream. fast=True
(default in CI) shrinks horizons; pass --full for the paper's 3-hour
settings.
"""
from __future__ import annotations

import csv
import io
import sys
from typing import Iterable


def emit(rows: Iterable[dict], header_done=set()) -> None:
    rows = list(rows)
    if not rows:
        return
    w = csv.DictWriter(sys.stdout, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    sys.stdout.flush()
