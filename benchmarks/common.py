"""Shared benchmark plumbing: CSV emission + default horizons.

Every bench_* module exposes ``run(fast: bool) -> list[dict]`` rows; the
``benchmarks.run`` driver aggregates them into one CSV stream. fast=True
(default in CI) shrinks horizons; pass --full for the paper's 3-hour
settings.
"""
from __future__ import annotations

import csv
import io
import json
import platform
import sys
import time
from typing import Iterable, Optional


def emit(rows: Iterable[dict], header_done=set()) -> None:
    rows = list(rows)
    if not rows:
        return
    w = csv.DictWriter(sys.stdout, fieldnames=list(rows[0].keys()))
    w.writeheader()
    for r in rows:
        w.writerow(r)
    sys.stdout.flush()


def write_json(rows: Iterable[dict], path: str,
               meta: Optional[dict] = None) -> str:
    """Persist bench rows as a machine-readable artifact.

    The perf trajectory across PRs is diffed from these files (e.g.
    ``BENCH_sim_scale.json``), so the schema stays flat: a ``meta`` header
    (timestamp, host) plus the same row dicts the CSV stream carries."""
    doc = {
        "meta": {
            "generated_unix": int(time.time()),
            "generated": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "host": platform.node(),
            "python": platform.python_version(),
            **(meta or {}),
        },
        "rows": list(rows),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    # stderr: stdout is a pure CSV stream consumers may redirect
    print(f"# wrote {path}", file=sys.stderr, flush=True)
    return path
