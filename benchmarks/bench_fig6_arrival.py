"""Fig. 6: impact of the application arrival rate (1e-4 .. 0.2 per slot)
on energy and the online scheme's degradation to immediate. Arrival
processes are Scenario-API objects; besides the paper's Bernoulli sweep a
bursty (Markov-modulated) row shows the non-i.i.d. regime the paper never
measured."""
from __future__ import annotations

from repro.core import MarkovModulatedArrivals, Scenario, run_experiment


def run(fast: bool = True):
    horizon = 3000 if fast else 10800
    rates = [1e-4, 1e-3, 1e-2, 0.2] if fast else \
        [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 0.05, 0.2]
    rows = []
    for p in rates:
        for pol in ("immediate", "online", "offline"):
            # default arrivals = Bernoulli at app_arrival_p: the rate is
            # single-sourced between the simulation and the CSV label
            r = run_experiment(Scenario(
                policy=pol, app_arrival_p=p, horizon_s=horizon, n_users=25,
                seed=1, engine="vectorized"))
            rows.append({
                "bench": "fig6_arrival", "arrivals": "bernoulli",
                "policy": pol, "arrival_p": p,
                "energy_kj": round(r.energy_j / 1e3, 2),
                "updates": r.updates,
                "corun_frac": round(r.corun_fraction, 3),
            })
    # beyond the paper: bursty sessions at a matched mean rate
    for pol in ("immediate", "online", "offline"):
        r = run_experiment(Scenario(
            policy=pol,
            arrivals=MarkovModulatedArrivals(p_calm=2e-4, p_burst=5e-2,
                                             burst_start=1e-3,
                                             burst_stop=1e-2),
            horizon_s=horizon, n_users=25, seed=1, engine="vectorized"))
        rows.append({
            "bench": "fig6_arrival", "arrivals": "bursty",
            "policy": pol, "arrival_p": "",
            "energy_kj": round(r.energy_j / 1e3, 2),
            "updates": r.updates,
            "corun_frac": round(r.corun_fraction, 3),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
