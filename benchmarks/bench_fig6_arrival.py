"""Fig. 6: impact of the application arrival rate (1e-4 .. 0.2 per slot)
on energy and the online scheme's degradation to immediate. Arrival
processes are Scenario-API objects; besides the paper's Bernoulli sweep a
bursty (Markov-modulated) row shows the non-i.i.d. regime the paper never
measured.

Built on the batched sweep path (``core.scenario.run_sweep``): arrival
draws are host-sampled into traced operands, so ALL rate variants of a
policy — Bernoulli grid and the bursty process alike — stack under one
vmapped jitted program per policy (offline falls back per point: host
knapsack planning is vmap-ineligible). The bursty rows carry
``arrival_p=None`` (not ``""``) so the column stays singly-typed; rows
also persist to ``BENCH_fig6_arrival.json``."""
from __future__ import annotations

from typing import Optional

from repro.core import MarkovModulatedArrivals, Scenario, run_sweep

JSON_PATH = "BENCH_fig6_arrival.json"

POLICIES = ("immediate", "online", "offline")


def run(fast: bool = True, json_path: Optional[str] = JSON_PATH):
    horizon = 3000 if fast else 10800
    rates = [1e-4, 1e-3, 1e-2, 0.2] if fast else \
        [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 0.05, 0.2]
    base = dict(horizon_s=horizon, n_users=25, seed=1)

    scenarios, labels = [], []
    for p in rates:
        for pol in POLICIES:
            # default arrivals = Bernoulli at app_arrival_p: the rate is
            # single-sourced between the simulation and the CSV label
            scenarios.append(Scenario(policy=pol, app_arrival_p=p, **base))
            labels.append(("bernoulli", pol, p))
    # beyond the paper: bursty sessions at a matched mean rate
    for pol in POLICIES:
        scenarios.append(Scenario(
            policy=pol,
            arrivals=MarkovModulatedArrivals(p_calm=2e-4, p_burst=5e-2,
                                             burst_start=1e-3,
                                             burst_stop=1e-2), **base))
        labels.append(("bursty", pol, None))

    results = run_sweep(scenarios)
    rows = [{
        "bench": "fig6_arrival", "arrivals": arrivals,
        "policy": pol, "arrival_p": p,
        "energy_kj": round(r.energy_j / 1e3, 2),
        "updates": r.updates,
        "corun_frac": round(r.corun_fraction, 3),
    } for (arrivals, pol, p), r in zip(labels, results)]

    if json_path:
        from benchmarks.common import write_json
        write_json(rows, json_path,
                   meta={"bench": "fig6_arrival", "fast": fast})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
