"""Fig. 6: impact of the application arrival rate (1e-4 .. 0.2 per slot)
on energy and the online scheme's degradation to immediate."""
from __future__ import annotations

from repro.core.simulator import FederatedSim, SimConfig


def run(fast: bool = True):
    horizon = 3000 if fast else 10800
    rates = [1e-4, 1e-3, 1e-2, 0.2] if fast else \
        [1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 0.05, 0.2]
    rows = []
    for p in rates:
        for pol in ("immediate", "online", "offline"):
            r = FederatedSim(SimConfig(policy=pol, app_arrival_p=p,
                                       horizon_s=horizon, n_users=25,
                                       seed=1, engine="vectorized")).run()
            rows.append({
                "bench": "fig6_arrival", "policy": pol, "arrival_p": p,
                "energy_kj": round(r.energy_j / 1e3, 2),
                "updates": r.updates,
                "corun_frac": round(r.corun_fraction, 3),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
