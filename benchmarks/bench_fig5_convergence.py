"""Fig. 5: convergence speed + gradient-staleness traces with REAL JAX
training (LeNet-5 on cifarlike) under the four schedules."""
from __future__ import annotations

import numpy as np

from repro.core import Scenario
from repro.core.realml import make_ml_hooks


def run(fast: bool = True):
    horizon = 2400 if fast else 10800
    n_users = 8 if fast else 25
    n_train = 4000 if fast else 10000
    # L_b must scale with cohort size: H pressure engages once the summed
    # idle gaps (~ n * eps * t) cross L_b (Sec. V.B) — the paper's 1000 is
    # calibrated for 25 users x 3 h.
    L_b = 120.0 if fast else 1000.0
    rows = []
    for pol in ("immediate", "online", "offline", "sync"):
        hooks, state = make_ml_hooks(n_users, sync=(pol == "sync"),
                                     n_train=n_train,
                                     n_test=1000 if fast else 2000)
        # real-ML mode drives per-user JAX training through hooks -> needs
        # the loop engine (engine="auto" resolves to it; pin for clarity)
        sc = Scenario(policy=pol, horizon_s=horizon, n_users=n_users,
                      ml_mode="real", seed=0, L_b=L_b, engine="loop",
                      app_arrival_p=0.004 if fast else 0.001)
        r = sc.run(ml_hooks=hooks)
        final_acc = r.accuracy[-1][1] if r.accuracy else float("nan")
        # wall-clock to reach accuracy thresholds (Fig. 5c)
        t_to = {}
        for thr in (0.30, 0.40, 0.45, 0.50):
            hit = [t for t, a in r.accuracy if a >= thr]
            t_to[thr] = hit[0] if hit else -1
        lags = [e["lag"] for e in r.push_log]
        gaps = [e["gap"] for e in r.push_log]
        corr = float(np.corrcoef(lags, gaps)[0, 1]) \
            if len(set(lags)) > 1 else 0.0
        rows.append({
            "bench": "fig5_convergence", "policy": pol,
            "final_acc": round(final_acc, 4),
            "updates": r.updates,
            "energy_kj": round(r.energy_j / 1e3, 2),
            "t_acc30_s": t_to[0.30], "t_acc40_s": t_to[0.40],
            "t_acc45_s": t_to[0.45], "t_acc50_s": t_to[0.50],
            "mean_lag": round(float(np.mean(lags)) if lags else 0, 2),
            "mean_gap": round(float(np.mean(gaps)) if gaps else 0, 4),
            "gap_var": round(float(np.var(gaps)) if gaps else 0, 5),
            "lag_gap_corr": round(corr, 3),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
