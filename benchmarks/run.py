"""Benchmark driver: one module per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run            # fast settings
    PYTHONPATH=src python -m benchmarks.run --full     # paper horizons
    PYTHONPATH=src python -m benchmarks.run --only fig4
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_fig4_tradeoff, bench_fig5_convergence,
                        bench_fig6_arrival, bench_kernels,
                        bench_real_scale, bench_roofline,
                        bench_serve_ingest, bench_sim_scale,
                        bench_table2_energy, bench_table3_overhead)
from benchmarks.common import emit

BENCHES = [
    ("table2", bench_table2_energy),
    ("table3", bench_table3_overhead),
    ("fig4", bench_fig4_tradeoff),
    ("fig6", bench_fig6_arrival),
    ("fig5", bench_fig5_convergence),
    ("sim_scale", bench_sim_scale),
    ("real_scale", bench_real_scale),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
    ("serve_ingest", bench_serve_ingest),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons (3 h sim, 25 users)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = 0
    for name, mod in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            emit(mod.run(fast=not args.full))
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
