"""Table II: per-(device, app) co-running energy saving percentages,
reproduced from the measured power/time catalog."""
from __future__ import annotations

from repro.core.energy import APPS, TESTBED


def run(fast: bool = True):
    rows = []
    for dev, prof in TESTBED.items():
        for app in APPS:
            a = prof.apps[app]
            rows.append({
                "bench": "table2_energy",
                "device": dev,
                "app": app,
                "p_app_w": a.p_app,
                "p_corun_w": a.p_corun,
                "p_train_w": prof.p_train,
                "t_corun_s": a.t_corun,
                "saving_pct": round(100 * prof.saving_percent(app), 1),
                "saving_rate_w": round(prof.energy_saving_rate(app), 3),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
