"""Table III: the online decision's compute overhead — microseconds per
Eq. (21) evaluation and the implied power overhead per device."""
from __future__ import annotations

import time

from repro.core.energy import TESTBED
from repro.core.lyapunov import OnlineScheduler, UserSlotState


def run(fast: bool = True):
    sched = OnlineScheduler(V=4000, L_b=1000, eta=0.01, beta=0.9)
    sched.Q, sched.H = 10.0, 5.0
    u = UserSlotState(p_corun=2.5, p_app=2.0, p_train=1.35, p_idle=0.689,
                      app_running=True, lag_estimate=3, idle_gap=0.4)
    n = 20000 if fast else 200000
    t0 = time.perf_counter()
    for _ in range(n):
        sched.decide(u, 1.0)
    us = (time.perf_counter() - t0) / n * 1e6

    rows = [{"bench": "table3_overhead", "device": "decision_us",
             "p_idle_w": "", "p_sched_w": "", "overhead_pct": "",
             "us_per_decision": round(us, 3)}]
    for dev, prof in TESTBED.items():
        if prof.p_sched <= prof.p_idle:
            continue
        rows.append({
            "bench": "table3_overhead", "device": dev,
            "p_idle_w": prof.p_idle, "p_sched_w": prof.p_sched,
            "overhead_pct": round(100 * (prof.p_sched - prof.p_idle)
                                  / prof.p_idle, 1),
            "us_per_decision": round(us, 3),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
