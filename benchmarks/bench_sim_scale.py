"""Simulator engine scaling: slots/sec and wall-clock per engine, per
policy (trace mode).

Tracks the perf trajectory of the struct-of-arrays engine across PRs; the
headline number is the vectorized-vs-loop speedup at n_users=400 (the
acceptance floor is 10x). Two sweeps:

* cohort-size sweep (online policy) over SIZES — the scaling headline;
* policy sweep at n_users=400 over every registry policy x engine pair the
  policy supports (jax rows appear only for jax-capable policies);
* fleet-scale sweep: the jax engine at n_users=100k, push-log collection
  ON vs OFF — the streamed fixed-width event buffer must keep fleet-scale
  logging feasible (memory stays O(jax_chunk), never O(T * n); the rows
  record the push count so the log-on overhead is attributable);
* device-dynamics sweep: vectorized and jax engines at n_users=400 with
  the Markov churn layer (core/dynamics.py) on vs off — prices the
  in-scan availability/battery/network transition (the ``dynamics``
  column makes the overhead attributable across PRs);
* sweep-throughput: a SWEEP_POINTS-point V-grid at n_users=25 run three
  ways — the batched path (``core.scenario.run_sweep``: all points
  vmapped under ONE compiled program), the per-point loop a sweep ran as
  before run_sweep existed (``engine="auto"`` resolves to vectorized),
  and a warmed per-point jax loop. ``sweep_speedup`` on the batched row
  is scenarios/sec vs the per-point loop (the status quo); the
  per-point-jax row's own ``scenarios_per_s`` prices the
  warm-jax-vs-warm-jax ratio (compile measured separately, as usual);
* sharded sweep: the chunked scan partitioned over the ``("users",)``
  device mesh (``SimConfig.n_devices``, forced host devices locally via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N``) vs the same
  auto-tuned run on one device, at n=100k and n=1M (10M in full mode).
  Rows carry ``n_devices`` and the tuner's modeled ``mem_per_device_mb``
  (``core.autotune.estimate_device_bytes``) so CPU-host numbers transfer
  to accelerator meshes by arithmetic. ``--sharded-smoke`` runs ONLY the
  n=100k sharded rows — the CI job's fast regression gate.

The loop engine is skipped at cohort sizes where it would dominate the
suite's wall-clock; the jax engine reports compile and steady-state times
separately (one compile per (config shape, policy) — scalar knobs are
traced, so sweeps reuse the executable).

Besides the CSV stream every run persists ``BENCH_sim_scale.json`` (see
``common.write_json``) so the slots/sec trajectory is machine-readable
across PRs.
"""
from __future__ import annotations

import time

from repro.core.policies import registered_policies, resolve_policy
from repro.core.simulator import FederatedSim, SimConfig

SIZES = (25, 400, 2500, 10000)
POLICY_SWEEP_N = 400
FLEET_N = 100_000
SWEEP_POINTS = 16
JSON_PATH = "BENCH_sim_scale.json"


def _time_run(policy: str, engine: str, n: int, horizon: int, seed: int = 0,
              collect_push_log: bool = False, dynamics="none",
              n_devices: int = 0, jax_chunk=None):
    # push-log collection off by default so the engine comparison measures
    # engine speed, not log-building; the fleet sweep flips it on to price
    # the streamed event buffer
    kw = {} if jax_chunk is None else {"jax_chunk": jax_chunk}
    cfg = SimConfig(policy=policy, n_users=n, horizon_s=horizon,
                    engine=engine, seed=seed,
                    collect_push_log=collect_push_log, dynamics=dynamics,
                    n_devices=n_devices, **kw)
    sim = FederatedSim(cfg)
    t0 = time.perf_counter()
    r = sim.run()
    return time.perf_counter() - t0, r


def _row(sweep, policy, engine, n, horizon, wall, r, compile_s, loop_wall,
         push_log=False, dynamics="none", scenarios=None,
         scenarios_per_s=None, sweep_speedup=None, n_devices=None,
         mem_per_device_mb=None):
    # absent knobs are None, never "" — every column stays singly-typed
    # for JSON/CSV consumers
    return {
        "bench": "sim_scale", "sweep": sweep, "policy": policy,
        "engine": engine, "n_users": n, "horizon_s": horizon,
        "push_log": push_log, "dynamics": dynamics,
        "wall_s": round(wall, 3),
        "slots_per_s": round(horizon / wall, 1),
        "user_slots_per_s": round(n * horizon / wall, 0),
        "compile_s": compile_s,
        "speedup_vs_loop": round(loop_wall / wall, 1) if loop_wall else None,
        "scenarios": scenarios,
        "scenarios_per_s": scenarios_per_s,
        "sweep_speedup": sweep_speedup,
        "n_devices": n_devices,
        "mem_per_device_mb": mem_per_device_mb,
        "updates": r.updates,
        "n_push": len(r.push_log),
        "energy_kj": round(r.energy_j / 1e3, 2),
    }


def _engines_for(policy: str):
    pol = resolve_policy(policy)
    engines = ["loop"]
    if pol.supports_vectorized:
        engines.append("vectorized")
    if pol.supports_jax:
        engines.append("jax")
    return engines


def _sharded_rows(fast: bool, smoke: bool = False):
    """The sharded sweep: auto-tuned (``jax_chunk=0``) runs on the full
    ``("users",)`` mesh vs one device, same seed/horizon, so the row pair
    prices the mesh itself. ``smoke`` keeps only the n=100k pair — the CI
    regression gate under 8 forced host devices."""
    import jax

    from repro.core.autotune import (autotune_scan_params,
                                     estimate_device_bytes)
    from repro.core.simulator import n_slots

    D = len(jax.devices())
    rows = []
    sizes = [FLEET_N] if smoke else \
        ([FLEET_N, 1_000_000] if fast else
         [FLEET_N, 1_000_000, 10_000_000])
    for n in sizes:
        # the paper fleet's fastest training cycle is ~250 slots, so
        # anything shorter logs zero updates; <=1M keeps a full cycle,
        # the 10M row is a throughput/memory-partitioning probe only
        horizon = 300 if n <= 1_000_000 else 60
        # both arms auto-tune (jax_chunk=0): the single-device arm is the
        # oracle baseline, not a straw man with a mistuned chunk
        for nd in ([1, D] if D > 1 else [1]):
            t_first, _ = _time_run("online", "jax", n, horizon,
                                   n_devices=nd, jax_chunk=0)
            wall, r = _time_run("online", "jax", n, horizon,
                                n_devices=nd, jax_chunk=0)
            cfg = SimConfig(policy="online", n_users=n, horizon_s=horizon,
                            engine="jax", n_devices=nd, jax_chunk=0)
            tune = autotune_scan_params(FederatedSim(cfg), n_devices=nd)
            est = estimate_device_bytes(n, n_slots(cfg), tune.jax_chunk, 0,
                                        n_devices=nd)
            rows.append(_row("sharded", "online", "jax", n, horizon, wall,
                             r, round(t_first - wall, 2), None,
                             n_devices=nd,
                             mem_per_device_mb=round(est / 2 ** 20, 1)))
    return rows


def run(fast: bool = True, sharded_smoke: bool = False):
    if sharded_smoke:       # CI gate: only the n=100k sharded pair
        rows = _sharded_rows(fast=True, smoke=True)
        from benchmarks.common import write_json
        write_json(rows, JSON_PATH,
                   meta={"bench": "sim_scale", "fast": True,
                         "sharded_smoke": True})
        return rows
    horizon = 600 if fast else 3600
    loop_cap = 2500 if fast else max(SIZES)
    rows = []

    def bench(sweep, policy, engine, n, loop_wall):
        compile_s = None
        if engine == "jax":
            t_first, _ = _time_run(policy, engine, n, horizon)
            wall, r = _time_run(policy, engine, n, horizon)
            compile_s = round(t_first - wall, 2)
        else:
            wall, r = _time_run(policy, engine, n, horizon)
        rows.append(_row(sweep, policy, engine, n, horizon, wall, r,
                         compile_s, loop_wall))
        return wall

    # --- cohort-size sweep, online policy (the scaling headline) ---------
    for n in SIZES:
        loop_wall = None
        for engine in _engines_for("online"):
            if engine == "loop" and n > loop_cap:
                continue
            wall = bench("size", "online", engine, n, loop_wall)
            if engine == "loop":
                loop_wall = wall

    # --- policy sweep at the acceptance shape: every registered policy ---
    for policy in registered_policies():
        if policy == "online" and POLICY_SWEEP_N in SIZES:
            # already measured in the size sweep; relabel those rows
            # instead of burning duplicate wall-clock on identical runs
            reused = [{**r, "sweep": "policy"} for r in rows
                      if r["sweep"] == "size"
                      and r["n_users"] == POLICY_SWEEP_N]
            rows.extend(reused)
            continue
        loop_wall = None
        for engine in _engines_for(policy):
            wall = bench("policy", policy, engine, POLICY_SWEEP_N, loop_wall)
            if engine == "loop":
                loop_wall = wall

    # --- fleet-scale sweep: jax engine, n=100k, push-log on vs off -------
    fleet_horizon = 300 if fast else 1800
    for collect in (False, True):
        t_first, _ = _time_run("online", "jax", FLEET_N, fleet_horizon,
                               collect_push_log=collect)
        wall, r = _time_run("online", "jax", FLEET_N, fleet_horizon,
                            collect_push_log=collect)
        rows.append(_row("fleet", "online", "jax", FLEET_N, fleet_horizon,
                         wall, r, round(t_first - wall, 2), None,
                         push_log=collect))

    # --- device-dynamics sweep: churn layer on vs off ---------------------
    from repro.core.dynamics import MarkovChurnDynamics
    churn = MarkovChurnDynamics(p_off=0.01, p_on=0.05)
    for engine in ("vectorized", "jax"):
        for dyn, label in (("none", "none"), (churn, "markov")):
            if engine == "jax":
                t_first, _ = _time_run("online", engine, POLICY_SWEEP_N,
                                       horizon, dynamics=dyn)
                wall, r = _time_run("online", engine, POLICY_SWEEP_N,
                                    horizon, dynamics=dyn)
                compile_s = round(t_first - wall, 2)
            else:
                compile_s = None
                wall, r = _time_run("online", engine, POLICY_SWEEP_N,
                                    horizon, dynamics=dyn)
            rows.append(_row("dynamics", "online", engine, POLICY_SWEEP_N,
                             horizon, wall, r, compile_s, None,
                             dynamics=label))

    # --- sweep throughput: a V-grid batched under ONE program vs the
    # --- per-point loop a sweep used to be (engine auto -> vectorized),
    # --- plus a warmed per-point jax loop for attribution ----------------
    from repro.core import Scenario, run_sweep
    vgrid = [float(10 ** (2 + 4 * k / (SWEEP_POINTS - 1)))
             for k in range(SWEEP_POINTS)]
    grid = Scenario(policy="online", n_users=25, horizon_s=horizon,
                    seed=0).grid(V=vgrid)
    t0 = time.perf_counter()
    run_sweep(grid)                      # cold: one compile for the grid
    cold_b = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_b = run_sweep(grid)
    wall_b = time.perf_counter() - t0

    loop = Scenario(policy="online", n_users=25, horizon_s=horizon,
                    seed=0).grid(V=vgrid)   # engine auto -> vectorized
    t0 = time.perf_counter()
    for sc in loop:
        r_loop = sc.run()
    wall_l = time.perf_counter() - t0

    pp = Scenario(policy="online", n_users=25, horizon_s=horizon,
                  seed=0, engine="jax").grid(V=vgrid)
    t0 = time.perf_counter()
    for sc in pp:                        # cold: V is traced — one compile
        sc.run()
    cold_p = time.perf_counter() - t0
    t0 = time.perf_counter()
    for sc in pp:
        r_pp = sc.run()
    wall_p = time.perf_counter() - t0

    B = len(vgrid)
    rows.append(_row("sweep", "online", "jax(batched)", 25, horizon,
                     wall_b, res_b[0], round(cold_b - wall_b, 2), None,
                     scenarios=B, scenarios_per_s=round(B / wall_b, 1),
                     sweep_speedup=round(wall_l / wall_b, 1)))
    rows.append(_row("sweep", "online", "vectorized(per-point)", 25,
                     horizon, wall_l, r_loop, None, None,
                     scenarios=B, scenarios_per_s=round(B / wall_l, 1)))
    rows.append(_row("sweep", "online", "jax(per-point)", 25, horizon,
                     wall_p, r_pp, round(cold_p - wall_p, 2), None,
                     scenarios=B, scenarios_per_s=round(B / wall_p, 1)))

    # --- sharded sweep: the ("users",) mesh at fleet scale ----------------
    rows.extend(_sharded_rows(fast))

    from benchmarks.common import write_json
    write_json(rows, JSON_PATH,
               meta={"bench": "sim_scale", "fast": fast,
                     "policies": list(registered_policies())})
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import emit
    emit(run(sharded_smoke="--sharded-smoke" in sys.argv[1:]))
