"""Simulator engine scaling: slots/sec and wall-clock vs n_users for the
loop / vectorized / jax engines (online policy, trace mode).

Tracks the perf trajectory of the struct-of-arrays engine across PRs; the
headline number is the vectorized-vs-loop speedup at n_users=400 (the
acceptance floor is 10x). The loop engine is skipped at cohort sizes where
it would dominate the suite's wall-clock; the jax engine reports compile
and steady-state times separately (one compile per config shape — scalar
knobs are traced, so sweeps reuse the executable).
"""
from __future__ import annotations

import time

from repro.core.simulator import FederatedSim, SimConfig

SIZES = (25, 400, 2500, 10000)


def _time_run(engine: str, n: int, horizon: int, seed: int = 0):
    # push-log collection off for every engine so the comparison measures
    # engine speed, not log-building (jax cannot collect one regardless)
    cfg = SimConfig(policy="online", n_users=n, horizon_s=horizon,
                    engine=engine, seed=seed, collect_push_log=False)
    sim = FederatedSim(cfg)
    t0 = time.perf_counter()
    r = sim.run()
    return time.perf_counter() - t0, r


def run(fast: bool = True):
    horizon = 600 if fast else 3600
    loop_cap = 2500 if fast else max(SIZES)
    rows = []
    for n in SIZES:
        loop_wall = None
        for engine in ("loop", "vectorized", "jax"):
            if engine == "loop" and n > loop_cap:
                continue
            compile_s = ""
            if engine == "jax":
                t_first, _ = _time_run(engine, n, horizon)
                wall, r = _time_run(engine, n, horizon)
                compile_s = round(t_first - wall, 2)
            else:
                wall, r = _time_run(engine, n, horizon)
            if engine == "loop":
                loop_wall = wall
            T = int(horizon)
            rows.append({
                "bench": "sim_scale", "engine": engine, "n_users": n,
                "horizon_s": horizon,
                "wall_s": round(wall, 3),
                "slots_per_s": round(T / wall, 1),
                "user_slots_per_s": round(n * T / wall, 0),
                "compile_s": compile_s,
                "speedup_vs_loop": round(loop_wall / wall, 1)
                if loop_wall else "",
                "updates": r.updates,
                "energy_kj": round(r.energy_j / 1e3, 2),
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
