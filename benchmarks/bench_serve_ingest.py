"""Serving-tier ingestion: sustained pushes/sec and apply latency.

Drives the sharded async parameter server through the full ingestion
pipeline (bounded queue -> per-shard decode -> staged atomic commit)
with a cohort of synthetic clients pushing continuously, and measures

* sustained **pushes/sec** (committed pushes over the timed window),
* **p50/p99 apply latency** (first shard packet enqueued -> atomic
  commit, from the pipeline's per-push latency log),
* the **wire size** per push under the configured codec,

over the matrix shard count x model size x compression codec, with a
``--kernel`` flag selecting the per-shard apply path (reference jnp vs
the fused Pallas entry; a single on/off pair row for the other mode
rides along so the JSON always carries both columns). The
monitor rides along (every packet heartbeats, every commit is a cadence
sample, a periodic sweep runs) so the measured path is the production
one, fault machinery included.

A contended-applier sweep follows the matrix: N threads, each with its
own pipeline and client, commit against ONE shared server, so every
``push_flat`` serializes on the applier lock. The ``threads`` column
(1 on the single-pipeline matrix rows) makes lock contention a tracked
quantity across PRs.

Fast mode (CI) runs the small model; ``--full`` adds the ~1M-param model
and a deeper shard sweep. Every run persists ``BENCH_serve_ingest.json``
(see ``common.write_json``) so the ingest-throughput trajectory is
machine-readable across PRs.

    PYTHONPATH=src python -m benchmarks.bench_serve_ingest --fast
"""
from __future__ import annotations

import argparse
import time

import numpy as np

JSON_PATH = "BENCH_serve_ingest.json"

SIZES_FAST = (65_536,)
SIZES_FULL = (65_536, 1_048_576)
SHARDS_FAST = (1, 4)
SHARDS_FULL = (1, 4, 8)
CODECS = ("none", "int8", "topk")
N_CLIENTS = 8
SWEEP_EVERY = 16


def _params(n_params: int):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    d = 64
    rows = n_params // d
    return {"embed": jnp.asarray(rng.normal(0, 0.1, (rows, d))
                                 .astype(np.float32)),
            "head": jnp.asarray(rng.normal(0, 0.1, n_params - rows * d)
                                .astype(np.float32))}


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def _bench_one(n_params: int, n_shards: int, codec: str, n_pushes: int,
               warmup: int, kernel: str = "reference"):
    from repro.fault.monitor import FleetMonitor
    from repro.serve import (IngestPipeline, ServeClient,
                             ShardedAsyncParameterServer)

    server = ShardedAsyncParameterServer(_params(n_params), eta=0.05,
                                         beta=0.9, n_shards=n_shards,
                                         history_depth=4 * N_CLIENTS,
                                         kernel=kernel)
    pipe = IngestPipeline(server, capacity=8 * n_shards * N_CLIENTS,
                          codec=codec,
                          monitor=FleetMonitor(timeout_slots=10 ** 6))
    clients = [ServeClient(i, pipe) for i in range(N_CLIENTS)]
    rng = np.random.default_rng(1)
    delta = rng.normal(0, 0.01, server.spec.total).astype(np.float32)

    def one_push(t):
        c = clients[t % N_CLIENTS]
        base, _ = c.pull()
        sign = 1.0 if t % 2 == 0 else -1.0
        _, accepted = c.push(np.asarray(base) + sign * delta, slot=t)
        assert accepted == n_shards, "bench must not shed its own load"
        pipe.drain()
        if t % SWEEP_EVERY == 0:
            pipe.sweep(t)

    for t in range(warmup):
        one_push(t)
    pipe.latencies.clear()
    applied0 = pipe.stats.applied

    t0 = time.perf_counter()
    for t in range(warmup, warmup + n_pushes):
        one_push(t)
    wall = time.perf_counter() - t0

    # wire size of one representative push (encode only, off the clock)
    import jax.numpy as jnp
    c = clients[0]
    flat_base, _ = c.pull()
    flat = np.asarray(flat_base) + delta
    wire_bytes = sum(
        pipe.codec.wire_bytes(pipe.codec.encode(
            (c.client_id, i), jnp.asarray(flat[server.spec.shard_slice(i)]),
            c.base[i]))
        for i in range(n_shards))

    committed = pipe.stats.applied - applied0
    lat_ms = [1e3 * l for l in pipe.latencies]
    return {
        "bench": "serve_ingest",
        "model_params": n_params,
        "n_shards": n_shards,
        "codec": codec,
        "kernel": kernel,
        "threads": 1,
        "n_pushes": committed,
        "pushes_per_sec": round(committed / wall, 2),
        "apply_p50_ms": round(_percentile(lat_ms, 50), 3),
        "apply_p99_ms": round(_percentile(lat_ms, 99), 3),
        "wire_kb_per_push": round(wire_bytes / 1024.0, 1),
        "raw_kb_per_push": round(4.0 * n_params / 1024.0, 1),
        "rejected": pipe.stats.rejected,
        "evicted": pipe.stats.evicted,
    }


def _bench_contended(n_params: int, n_shards: int, codec: str,
                     n_pushes: int, warmup: int, kernel: str,
                     threads: int):
    """Contended appliers: ``threads`` ingest pipelines share ONE server,
    so every commit serializes on the server's applier lock
    (``push_flat``). Each thread owns its pipeline and client (the
    pipeline is single-threaded by design; the SERVER is the shared
    resource), pushes ``n_pushes`` times and drains inline — aggregate
    committed pushes/sec across the fleet of appliers is the headline,
    and the thread sweep prices the lock + GIL against the 1-thread
    baseline."""
    import threading as _threading

    from repro.fault.monitor import FleetMonitor
    from repro.serve import (IngestPipeline, ServeClient,
                             ShardedAsyncParameterServer)

    server = ShardedAsyncParameterServer(
        _params(n_params), eta=0.05, beta=0.9, n_shards=n_shards,
        history_depth=4 * max(threads, N_CLIENTS), kernel=kernel)
    pipes = [IngestPipeline(server, capacity=8 * n_shards * N_CLIENTS,
                            codec=codec,
                            monitor=FleetMonitor(timeout_slots=10 ** 6))
             for _ in range(threads)]
    clients = [ServeClient(tid, pipes[tid]) for tid in range(threads)]
    rng = np.random.default_rng(1)
    delta = rng.normal(0, 0.01, server.spec.total).astype(np.float32)

    def pushes(tid: int, count: int, t_base: int) -> None:
        c, pipe = clients[tid], pipes[tid]
        for t in range(t_base, t_base + count):
            base, _ = c.pull()
            sign = 1.0 if t % 2 == 0 else -1.0
            _, accepted = c.push(np.asarray(base) + sign * delta, slot=t)
            assert accepted == n_shards, "bench must not shed its own load"
            pipe.drain()

    for tid in range(threads):          # warm caches + compile per pipe
        pushes(tid, warmup, 0)
    for p in pipes:
        p.latencies.clear()
    applied0 = sum(p.stats.applied for p in pipes)

    barrier = _threading.Barrier(threads + 1)

    def timed(tid: int) -> None:
        barrier.wait()
        pushes(tid, n_pushes, warmup)

    workers = [_threading.Thread(target=timed, args=(tid,))
               for tid in range(threads)]
    for w in workers:
        w.start()
    barrier.wait()
    t0 = time.perf_counter()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0

    committed = sum(p.stats.applied for p in pipes) - applied0
    lat_ms = [1e3 * l for p in pipes for l in p.latencies]
    return {
        "bench": "serve_ingest",
        "model_params": n_params,
        "n_shards": n_shards,
        "codec": codec,
        "kernel": kernel,
        "threads": threads,
        "n_pushes": committed,
        "pushes_per_sec": round(committed / wall, 2),
        "apply_p50_ms": round(_percentile(lat_ms, 50), 3),
        "apply_p99_ms": round(_percentile(lat_ms, 99), 3),
        "wire_kb_per_push": None,       # matrix rows price the codecs
        "raw_kb_per_push": round(4.0 * n_params / 1024.0, 1),
        "rejected": sum(p.stats.rejected for p in pipes),
        "evicted": sum(p.stats.evicted for p in pipes),
    }


def run(fast: bool = True, kernel: str = "reference"):
    sizes = SIZES_FAST if fast else SIZES_FULL
    shard_counts = SHARDS_FAST if fast else SHARDS_FULL
    n_pushes = 60 if fast else 300
    warmup = 8
    rows = []
    for n_params in sizes:
        for n_shards in shard_counts:
            for codec in CODECS:
                rows.append(_bench_one(n_params, n_shards, codec,
                                       n_pushes, warmup, kernel=kernel))
    # kernel on/off pair at the uncompressed corner: the per-shard
    # fused-apply kernel vs the jitted jnp apply. Off-TPU the Pallas
    # entry runs interpret mode — the pair pins overhead there, not a
    # hardware speedup.
    other = "pallas" if kernel == "reference" else "reference"
    rows.append(_bench_one(sizes[0], shard_counts[-1], "none", n_pushes,
                           warmup, kernel=other))

    # contended appliers: the same commit path from N threads against ONE
    # server — the thread sweep prices the applier lock (threads=1 is the
    # like-for-like baseline; the single-pipeline matrix rows above keep
    # their historical numbers)
    for threads in ((1, 2, 4) if fast else (1, 2, 4, 8)):
        rows.append(_bench_contended(sizes[0], shard_counts[-1], "none",
                                     n_pushes // 2, warmup, kernel,
                                     threads))

    from benchmarks.common import write_json
    write_json(rows, JSON_PATH,
               meta={"bench": "serve_ingest", "fast": fast,
                     "n_clients": N_CLIENTS, "kernel": kernel})
    return rows


def main():
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--kernel", default="reference",
                    choices=("auto", "pallas", "reference"),
                    help="apply-kernel mode for the matrix rows; the "
                         "on/off pair row always runs the other mode")
    args = ap.parse_args()
    emit(run(fast=args.fast, kernel=args.kernel))


if __name__ == "__main__":
    main()
