"""Fig. 4: V sweep of energy / Q / H plus the L_b energy-staleness
trade-off, against the immediate / offline / sync baselines (Scenario API)."""
from __future__ import annotations

import numpy as np

from repro.core import Scenario, run_experiment


def run(fast: bool = True):
    horizon = 3600 if fast else 10800
    n_users = 25
    rows = []

    # trace mode -> the vectorized SoA engine replays the loop engine
    # exactly (tests/test_sim_engines.py) at a fraction of the wall-clock
    base = dict(horizon_s=horizon, n_users=n_users, seed=0,
                engine="vectorized")
    for pol in ("immediate", "offline", "sync"):
        r = run_experiment(Scenario(policy=pol, **base))
        rows.append({"bench": "fig4_tradeoff", "policy": pol, "V": "",
                     "L_b": 1000.0, "energy_kj": round(r.energy_j / 1e3, 2),
                     "mean_Q": round(r.mean_Q, 2),
                     "mean_H": round(r.mean_H, 2),
                     "updates": r.updates,
                     "corun_frac": round(r.corun_fraction, 3)})

    vs = [1e2, 1e3, 4e3, 1e4, 1e5] if fast else \
        [1e2, 3e2, 1e3, 4e3, 1e4, 3e4, 1e5, 1e6]
    for V in vs:
        r = run_experiment(Scenario(policy="online", V=V, **base))
        rows.append({"bench": "fig4_tradeoff", "policy": "online", "V": V,
                     "L_b": 1000.0, "energy_kj": round(r.energy_j / 1e3, 2),
                     "mean_Q": round(r.mean_Q, 2),
                     "mean_H": round(r.mean_H, 2),
                     "updates": r.updates,
                     "corun_frac": round(r.corun_fraction, 3)})

    # Fig. 4d: staleness bound sweep
    for L_b in ([100.0, 1000.0] if fast else [50.0, 100.0, 500.0, 1000.0]):
        r = run_experiment(Scenario(policy="online", V=4000.0, L_b=L_b,
                                    **base))
        rows.append({"bench": "fig4_tradeoff", "policy": "online_Lb",
                     "V": 4000.0, "L_b": L_b,
                     "energy_kj": round(r.energy_j / 1e3, 2),
                     "mean_Q": round(r.mean_Q, 2),
                     "mean_H": round(r.mean_H, 2),
                     "updates": r.updates,
                     "corun_frac": round(r.corun_fraction, 3)})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
