"""Fig. 4: V sweep of energy / Q / H plus the L_b energy-staleness
trade-off, against the immediate / offline / sync baselines.

Built on the batched sweep path (``core.scenario.run_sweep``): the
online V-grid and L_b-grid share static shapes, so BOTH run under one
vmapped jitted program — a sweep point costs a stacked row, not a
compile. The baselines bucket separately and fall back per point
(offline's host knapsack planning is vmap-ineligible). Absent knobs are
``None`` (not ``""``) so every column stays singly-typed for JSON/CSV
consumers; rows also persist to ``BENCH_fig4_tradeoff.json``."""
from __future__ import annotations

from typing import Optional

from repro.core import Scenario, run_sweep

JSON_PATH = "BENCH_fig4_tradeoff.json"

BASELINES = ("immediate", "offline", "sync")


def _row(policy, V, L_b, r):
    return {"bench": "fig4_tradeoff", "policy": policy, "V": V,
            "L_b": L_b, "energy_kj": round(r.energy_j / 1e3, 2),
            "mean_Q": round(r.mean_Q, 2), "mean_H": round(r.mean_H, 2),
            "updates": r.updates, "corun_frac": round(r.corun_fraction, 3)}


def run(fast: bool = True, json_path: Optional[str] = JSON_PATH):
    horizon = 3600 if fast else 10800
    base = dict(horizon_s=horizon, n_users=25, seed=0)

    vs = [1e2, 1e3, 4e3, 1e4, 1e5] if fast else \
        [1e2, 3e2, 1e3, 4e3, 1e4, 3e4, 1e5, 1e6]
    # Fig. 4d: staleness bound sweep
    lbs = [100.0, 1000.0] if fast else [50.0, 100.0, 500.0, 1000.0]

    # ONE run_sweep call for the whole figure: the online V- and
    # L_b-grids batch into a single compiled program, the baselines run
    # per point on whatever engine resolves for them
    scenarios = (
        [Scenario(policy=pol, **base) for pol in BASELINES]
        + Scenario(policy="online", **base).grid(V=vs)
        + Scenario(policy="online", V=4000.0, **base).grid(L_b=lbs))
    results = run_sweep(scenarios)

    rows = []
    for pol, r in zip(BASELINES, results[: len(BASELINES)]):
        rows.append(_row(pol, None, 1000.0, r))
    off = len(BASELINES)
    for V, r in zip(vs, results[off: off + len(vs)]):
        rows.append(_row("online", V, 1000.0, r))
    for L_b, r in zip(lbs, results[off + len(vs):]):
        rows.append(_row("online_Lb", 4000.0, L_b, r))

    if json_path:
        from benchmarks.common import write_json
        write_json(rows, json_path,
                   meta={"bench": "fig4_tradeoff", "fast": fast})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
