"""§Roofline: the three terms per (arch x shape) from the dry-run JSONs
(artifacts/dryrun). Requires the dry-run sweep to have been run; emits
nothing (with a notice row) if artifacts are absent."""
from __future__ import annotations

import os

from repro.launch.roofline import cell_terms, load_records

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def run(fast: bool = True):
    rows = []
    if not os.path.isdir(ART):
        return [{"bench": "roofline", "arch": "(run launch.dryrun first)",
                 "shape": "", "compute_s": "", "memory_s": "",
                 "collective_s": "", "dominant": "", "roofline_pct": "",
                 "useful_pct": ""}]
    for rec in load_records(ART, "single"):
        t = cell_terms(rec)
        if t is None:
            rows.append({"bench": "roofline", "arch": rec["arch"],
                         "shape": rec["shape"], "compute_s": "ERR",
                         "memory_s": "", "collective_s": "", "dominant": "",
                         "roofline_pct": "", "useful_pct": ""})
            continue
        rows.append({
            "bench": "roofline", "arch": t["arch"], "shape": t["shape"],
            "compute_s": round(t["compute_s"], 4),
            "memory_s": round(t["memory_s"], 4),
            "collective_s": round(t["collective_s"], 4),
            "dominant": t["dominant"],
            "roofline_pct": round(100 * t["roofline_fraction"], 1),
            "useful_pct": round(100 * t["useful_ratio"], 1),
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
