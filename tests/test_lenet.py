"""The paper's workload: LeNet-5 learns cifarlike; real-ML hooks wire up."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import Client
from repro.core.realml import make_ml_hooks
from repro.data.synthetic import cifarlike_dataset
from repro.models.lenet import init_lenet, lenet_logits, lenet_loss, \
    param_count


class TestLeNet:
    def test_param_count_about_2_5mb(self):
        """Paper Sec. VI: the pushed model is ~2.5 MB (f32)."""
        p = init_lenet(jax.random.PRNGKey(0))
        mb = param_count(p) * 4 / 1e6
        assert 0.1 < mb < 3.0   # LeNet-5 on 32x32x3: ~0.25 MB — same order

    def test_learns_above_chance_fast(self):
        x, y = cifarlike_dataset(1000, seed=0, noise=4.0)
        tx, ty = cifarlike_dataset(300, seed=1, noise=4.0)
        c = Client(0, jnp.asarray(x), jnp.asarray(y), lenet_loss,
                   batch_size=20, eta=0.01, beta=0.9)
        p = init_lenet(jax.random.PRNGKey(0))
        for _ in range(2):
            p, v, loss = c.local_train(p)
        acc = float((np.asarray(lenet_logits(p, jnp.asarray(tx))).argmax(-1)
                     == ty).mean())
        assert acc > 0.5

    def test_local_train_returns_momentum(self):
        x, y = cifarlike_dataset(200, seed=0)
        c = Client(0, jnp.asarray(x), jnp.asarray(y), lenet_loss)
        p = init_lenet(jax.random.PRNGKey(0))
        p2, v, loss = c.local_train(p)
        assert np.isfinite(loss)
        v_norm = float(jnp.sqrt(sum(jnp.sum(jnp.square(l))
                                    for l in jax.tree.leaves(v))))
        assert v_norm > 0


class TestRealMLHooks:
    def test_async_hooks_train_and_eval(self):
        hooks, state = make_ml_hooks(2, n_train=600, n_test=200, noise=4.0)
        p = hooks["pull"](0)
        p2 = hooks["local_train"](0, p)
        hooks["push"](0, p2)
        acc = hooks["evaluate"]()
        assert 0.0 <= acc <= 1.0
        assert hooks["v_norm"]() > 0   # momentum norm set after first push

    def test_sync_hooks_aggregate(self):
        hooks, state = make_ml_hooks(2, sync=True, n_train=600, n_test=200,
                                     noise=4.0)
        p = hooks["pull"](0)
        hooks["sync_submit"](hooks["local_train"](0, p))
        hooks["sync_submit"](hooks["local_train"](1, p))
        hooks["sync_aggregate"]()
        assert state["server"].round == 1
