"""Fault-tolerance integration: island failures, elastic re-absorption, and
checkpoint/restart resume in the federated-LM driver."""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import IslandConfig, run


@pytest.fixture(scope="module")
def smoke_cfg():
    return get_smoke_config("qwen3-0.6b")


def test_failures_dont_stall_training(smoke_cfg, tmp_path):
    """Islands crash mid-run; the async design keeps making global updates
    (the paper's no-barrier property at fleet scale)."""
    icfg = IslandConfig(n_islands=3, slots=160, local_steps=2, batch=4,
                        seq=32, eval_every=160, fail_p=0.02, down_slots=15,
                        app_arrival_p=0.05, seed=3)
    out = run(smoke_cfg, icfg, log=lambda *a: None)
    assert out["failures"] > 0          # failures actually happened
    assert out["updates"] > 0           # and training still progressed
    assert np.isfinite(out["final_loss"])


def test_checkpoint_resume_continues(smoke_cfg, tmp_path):
    icfg = IslandConfig(n_islands=2, slots=120, local_steps=2, batch=4,
                        seq=32, eval_every=120, ckpt_dir=str(tmp_path),
                        ckpt_every=50, app_arrival_p=0.05)
    out1 = run(smoke_cfg, icfg, log=lambda *a: None)
    icfg2 = IslandConfig(n_islands=2, slots=40, local_steps=2, batch=4,
                         seq=32, eval_every=40, ckpt_dir=str(tmp_path),
                         resume=True, app_arrival_p=0.05)
    out2 = run(smoke_cfg, icfg2, log=lambda *a: None)
    assert out2["final_slot"] > 120     # continued past the first horizon
    assert np.isfinite(out2["final_loss"])


def test_no_failures_when_fail_p_zero(smoke_cfg):
    icfg = IslandConfig(n_islands=2, slots=80, local_steps=1, batch=4,
                        seq=32, eval_every=80, fail_p=0.0,
                        app_arrival_p=0.05)
    out = run(smoke_cfg, icfg, log=lambda *a: None)
    assert out["failures"] == 0
