"""Online Lyapunov scheduler (Sec. V): decisions, queues, V trade-off."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lyapunov import OnlineScheduler, UserSlotState, \
    schedule_threshold


def _user(app=False, lag=2, idle_gap=0.0):
    return UserSlotState(p_corun=2.5, p_app=2.0, p_train=1.35, p_idle=0.689,
                         app_running=app, lag_estimate=lag, idle_gap=idle_gap)


class TestDecision:
    def test_idle_when_no_backlog(self):
        """Sec. V.B: Q = H = 0 -> always idle (wait for co-running)."""
        s = OnlineScheduler(V=100, L_b=10, eta=0.01, beta=0.9)
        for app in (False, True):
            d = s.decide(_user(app=app), v_norm=1.0)
            assert not d.schedule

    def test_schedules_above_threshold(self):
        """Eq. (22): schedule iff Q >= V * t_d * (P_sched - P_idle)."""
        s = OnlineScheduler(V=10, L_b=1e9, eta=0.01, beta=0.9)
        u = _user(app=True)
        thr = schedule_threshold(10, 1.0, u.p_corun, u.p_app)
        s.Q = thr + 1e-6
        assert s.decide(u, v_norm=0.0).schedule
        s.Q = thr - 1e-3
        assert not s.decide(u, v_norm=0.0).schedule

    def test_corun_cheaper_than_separate(self):
        """Co-running threshold is lower than background-alone threshold
        for any device with positive energy discount."""
        u = _user(app=True)
        thr_corun = schedule_threshold(10, 1.0, u.p_corun, u.p_app)
        thr_sep = schedule_threshold(10, 1.0, u.p_train, u.p_idle)
        assert thr_corun < thr_sep

    def test_staleness_pressure_forces_schedule(self):
        """With a large virtual queue H and growing idle gap, scheduling
        becomes preferable even at Q below the energy threshold."""
        s = OnlineScheduler(V=1000, L_b=1.0, eta=0.01, beta=0.9)
        u = _user(app=False, lag=0, idle_gap=50.0)
        s.Q, s.H = 0.0, 1e4
        d = s.decide(u, v_norm=0.0)   # gap_sched = 0, gap_idle huge
        assert d.schedule

    @given(st.floats(1, 1e5), st.floats(0.0, 10.0), st.floats(0.0, 1e4),
           st.floats(0.0, 1e4))
    @settings(max_examples=100, deadline=None)
    def test_decision_minimizes_objective(self, V, vnorm, Q, H):
        """The returned branch really is the argmin of Eq. (21)."""
        s = OnlineScheduler(V=V, L_b=10, eta=0.01, beta=0.9)
        s.Q, s.H = Q, H
        u = _user(app=True, lag=3, idle_gap=1.0)
        d = s.decide(u, vnorm)
        from repro.core.staleness import gradient_gap
        g_s = gradient_gap(vnorm, 3, 0.01, 0.9)
        g_i = u.idle_gap + s.epsilon
        c_s = V * u.p_corun - Q + H * g_s
        c_i = V * u.p_app + H * g_i
        assert d.cost == pytest.approx(min(c_s, c_i), rel=1e-9, abs=1e-9)
        assert d.schedule == (c_s <= c_i)


class TestQueues:
    def test_eq15_eq16(self):
        s = OnlineScheduler(V=10, L_b=5.0, eta=0.01, beta=0.9)
        s.update_queues(arrivals=3, served=0, gap_sum=7.0)
        assert s.Q == 3 and s.H == pytest.approx(2.0)
        s.update_queues(arrivals=0, served=2, gap_sum=1.0)
        assert s.Q == 1 and s.H == pytest.approx(0.0)  # max(2+1-5, 0)

    def test_queue_never_negative(self):
        s = OnlineScheduler(V=10, L_b=5.0, eta=0.01, beta=0.9)
        s.update_queues(arrivals=0, served=10, gap_sum=0.0)
        assert s.Q == 0.0 and s.H == 0.0

    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                              st.floats(0, 10)), min_size=1, max_size=50))
    @settings(max_examples=50, deadline=None)
    def test_queue_dynamics_invariants(self, events):
        s = OnlineScheduler(V=10, L_b=3.0, eta=0.01, beta=0.9)
        for a, b, g in events:
            prev_q = s.Q
            s.update_queues(a, b, g)
            assert s.Q >= 0 and s.H >= 0
            assert s.Q <= prev_q + a   # can't grow more than arrivals


class TestTradeoff:
    def test_energy_monotone_in_v(self):
        """Larger V weights energy more -> never more eager to schedule."""
        from repro.core.simulator import FederatedSim, SimConfig
        energies = []
        for V in (10.0, 1e3, 1e5):
            r = FederatedSim(SimConfig(policy="online", V=V, horizon_s=1500,
                                       n_users=10, seed=1)).run()
            energies.append(r.energy_j)
        assert energies[0] >= energies[1] >= energies[2] * 0.98

    def test_queue_monotone_in_v(self):
        from repro.core.simulator import FederatedSim, SimConfig
        qs = []
        for V in (10.0, 1e3, 1e5):
            r = FederatedSim(SimConfig(policy="online", V=V, horizon_s=1500,
                                       n_users=10, seed=1)).run()
            qs.append(r.mean_Q)
        assert qs[0] <= qs[1] + 1e-9 <= qs[2] + 2e-9
