"""Kernel-mode parity across every apply site: the Pallas fused apply
(interpret mode on CPU) against the reference path in the core server,
the sharded serving tier, and the fused real-ML push scan — for all four
registered aggregation rules — plus the ``SimConfig.kernel`` knob
threading and the MLP backend's golden pin.

Regenerate the MLP golden (after an intentional schedule change):

    PYTHONPATH=src python tests/test_kernel_hotpath.py
"""
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PaperFleet, Scenario, SimConfig
from repro.core.realml import MLPBackend, ImageClassifierBackend
from repro.core.server import AsyncParameterServer
from repro.core.simulator import FederatedSim
from repro.serve import ShardedAsyncParameterServer

ALL_RULES = ("replace", "fedasync_poly", "gap_aware", "hetero_aware")

MLP_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                               "mlp_golden.json")

# the real_mode_golden.json regime (see tests/test_real_mode.py): small V
# so schedules fire within the horizon, H pinned at 0
SIM_KW = dict(n_users=4, horizon_s=900, app_arrival_p=0.004, seed=0,
              ml_mode="real", V=5.0)
ML_KW = dict(n_train=256, n_test=128, seed=0, eval_every=300)


def tiny_params(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"w": jax.random.normal(ks[0], (13, 7)),
            "b": jax.random.normal(ks[1], (7,)),
            "head": {"w": jax.random.normal(ks[2], (7, 3))}}


def paper_spec(n=8, seed=0):
    return PaperFleet().build(np.random.default_rng(seed), n)


def push_stream(server, seed=1, steps=12, n_clients=3):
    """Interleaved pull/push stream (the test_serve parity shape);
    returns (weights, gaps, v_norms) observed per push."""
    rng = np.random.default_rng(seed)
    pulled = {}
    out = []
    for step in range(steps):
        cid = step % n_clients
        if cid not in pulled:
            p, _ = server.pull(cid)
            pulled[cid] = jax.tree.map(
                lambda x: x + jnp.asarray(
                    rng.normal(0, 0.1, x.shape).astype(np.float32)), p)
        if step % 2 == 1:
            res = server.push(cid, pulled.pop(cid))
            out.append((res.applied_weight, res.gap_estimate,
                        float(server.v_norm)))
    return out


class TestServerKernelParity:
    """AsyncParameterServer: kernel="pallas" applies mix + momentum +
    norm in one fused dispatch; results pin to the reference at rtol
    1e-6 for every registered rule."""

    @pytest.mark.parametrize("aggregation", ALL_RULES)
    def test_push_stream_parity(self, aggregation):
        fleet = paper_spec(8) if aggregation == "hetero_aware" else None
        kw = dict(eta=0.05, beta=0.9, aggregation=aggregation, fleet=fleet)
        ref = AsyncParameterServer(tiny_params(), kernel="reference", **kw)
        pal = AsyncParameterServer(tiny_params(), kernel="pallas", **kw)
        obs_ref = push_stream(ref)
        obs_pal = push_stream(pal)
        assert len(obs_ref) == len(obs_pal) > 0
        for (wr, gr, nr), (wp, gp, np_) in zip(obs_ref, obs_pal):
            assert wp == pytest.approx(wr, rel=1e-6, abs=1e-9)
            assert gp == pytest.approx(gr, rel=1e-5, abs=1e-9)
            assert np_ == pytest.approx(nr, rel=1e-5, abs=1e-9)
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(pal.params)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-6, atol=1e-7)
        for a, b in zip(jax.tree.leaves(ref._v), jax.tree.leaves(pal._v)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-6, atol=1e-6)

    def test_pallas_v_norm_is_plain_float(self):
        """The fused path must leave the same host-float bookkeeping the
        reference leaves (policies read server.v_norm every slot)."""
        s = AsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                 kernel="pallas")
        s.pull(0)
        s.push(0, tiny_params(1))
        assert isinstance(s.v_norm, float) and s.v_norm > 0.0

    def test_auto_resolves_by_backend(self):
        s = AsyncParameterServer(tiny_params(), eta=0.05, beta=0.9)
        expected = "pallas" if jax.default_backend() == "tpu" \
            else "reference"
        assert s.kernel == expected

    def test_unknown_kernel_rejected(self):
        with pytest.raises(ValueError, match="unknown kernel mode"):
            AsyncParameterServer(tiny_params(), eta=0.05, beta=0.9,
                                 kernel="bogus")


class TestServeKernelParity:
    """ShardedAsyncParameterServer: the flat-vector kernel entry per
    shard vs the jitted jnp apply."""

    @pytest.mark.parametrize("aggregation",
                             ["replace", "fedasync_poly", "gap_aware"])
    @pytest.mark.parametrize("n_shards", [1, 4])
    def test_push_stream_parity(self, aggregation, n_shards):
        kw = dict(eta=0.05, beta=0.9, aggregation=aggregation,
                  n_shards=n_shards)
        ref = ShardedAsyncParameterServer(tiny_params(),
                                          kernel="reference", **kw)
        pal = ShardedAsyncParameterServer(tiny_params(), kernel="pallas",
                                          **kw)
        obs_ref = push_stream(ref)
        obs_pal = push_stream(pal)
        assert len(obs_ref) == len(obs_pal) > 0
        for (wr, gr, nr), (wp, gp, np_) in zip(obs_ref, obs_pal):
            assert wp == pytest.approx(wr, rel=1e-6, abs=1e-9)
            assert np_ == pytest.approx(nr, rel=1e-5, abs=1e-9)
        pal.assert_consistent()
        for a, b in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(pal.params)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-6, atol=1e-7)

    def test_sub_block_shards_clamp(self):
        """Tiny shards (a few hundred floats) must run the clamped-block
        path without error and still agree with the reference."""
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (40, 10))}
        ref = ShardedAsyncParameterServer(params, eta=0.05, beta=0.9,
                                          n_shards=4, kernel="reference")
        pal = ShardedAsyncParameterServer(params, eta=0.05, beta=0.9,
                                          n_shards=4, kernel="pallas")
        obs_ref = push_stream(ref, steps=6, n_clients=2)
        obs_pal = push_stream(pal, steps=6, n_clients=2)
        for (_, _, nr), (_, _, np_) in zip(obs_ref, obs_pal):
            assert np_ == pytest.approx(nr, rel=1e-5, abs=1e-9)


def run_real(kernel, ml="lenet", aggregation="replace", policy="online"):
    cfg = SimConfig(policy=policy, engine="vectorized",
                    aggregation=aggregation, kernel=kernel, **SIM_KW)
    return Scenario(config=cfg, ml=ml, ml_kwargs=dict(ML_KW)).run()


def schedule_digest(push_log) -> str:
    payload = json.dumps([(e["t"], e["user"], e["lag"], e["corun"])
                          for e in push_log]).encode()
    return hashlib.sha256(payload).hexdigest()


class TestRealMLKernelParity:
    """The fused train+push scan with the Pallas apply vs the reference
    scan, end-to-end through the vectorized engine."""

    @pytest.mark.parametrize("ml,aggregation", [
        ("lenet", "replace"),
        ("lenet", "gap_aware"),
        ("mlp", "fedasync_poly"),
    ])
    def test_end_to_end_parity(self, ml, aggregation):
        r_ref = run_real("reference", ml, aggregation)
        r_pal = run_real("pallas", ml, aggregation)
        # schedule identical (push decisions are momentum-norm
        # independent in this regime), floats to kernel tolerance
        assert schedule_digest(r_ref.push_log) == \
            schedule_digest(r_pal.push_log)
        g_ref = np.array([p["gap"] for p in r_ref.push_log])
        g_pal = np.array([p["gap"] for p in r_pal.push_log])
        assert len(g_ref) > 0
        np.testing.assert_allclose(g_pal, g_ref, rtol=2e-5, atol=1e-6)
        w_ref = np.array([p["weight"] for p in r_ref.push_log])
        w_pal = np.array([p["weight"] for p in r_pal.push_log])
        np.testing.assert_allclose(w_pal, w_ref, rtol=2e-5, atol=1e-7)
        np.testing.assert_allclose(
            [a for _, a in r_pal.accuracy],
            [a for _, a in r_ref.accuracy], atol=0.03)


class TestKnobThreading:
    def test_simconfig_validates_kernel(self):
        SimConfig(kernel="pallas")
        SimConfig(kernel="reference")
        with pytest.raises(ValueError, match="unknown kernel"):
            SimConfig(kernel="fused")

    def test_scenario_threads_kernel_to_backend(self):
        sc = Scenario(config=SimConfig(kernel="reference", ml_mode="real",
                                       n_users=2),
                      ml="mlp", ml_kwargs=dict(n_train=64, n_test=32))
        sim = sc.build()
        assert sim.ml_backend.kernel == "reference"
        assert sim.ml_backend.server.kernel == "reference"

    def test_default_auto_left_to_backend(self):
        """kernel="auto" is NOT forced into ml_kwargs (custom backends
        without the kwarg must keep constructing)."""
        sc = Scenario(config=SimConfig(ml_mode="real", n_users=2),
                      ml="mlp", ml_kwargs=dict(n_train=64, n_test=32))
        sim = sc.build()
        expected = "pallas" if jax.default_backend() == "tpu" \
            else "reference"
        assert sim.ml_backend.kernel == expected

    def test_backend_registry_has_mlp(self):
        from repro.core.realml import registered_ml_backends
        assert {"lenet", "mlp"} <= set(registered_ml_backends())
        assert issubclass(MLPBackend, ImageClassifierBackend)


def run_mlp_golden():
    """The pinned MLP run: Scenario(ml="mlp") through the vectorized
    engine on the reference kernel (bit-stable on CPU)."""
    cfg = SimConfig(policy="online", engine="vectorized",
                    kernel="reference", **SIM_KW)
    return Scenario(config=cfg, ml="mlp", ml_kwargs=dict(ML_KW)).run()


def summarize_mlp(r) -> dict:
    return {
        "updates": r.updates,
        "energy_j": r.energy_j,
        "n_push": len(r.push_log),
        "schedule_sha256": schedule_digest(r.push_log),
        "accuracy": [[int(t), float(a)] for t, a in r.accuracy],
    }


class TestMLPGolden:
    """``Scenario(ml="mlp")`` runs the fused train+push scan end-to-end
    with its own golden pin (the second-model acceptance criterion)."""

    @pytest.fixture(scope="class")
    def golden(self):
        with open(MLP_GOLDEN_PATH) as f:
            return json.load(f)

    @pytest.fixture(scope="class")
    def run(self):
        return run_mlp_golden()

    def test_matches_golden(self, golden, run):
        s = summarize_mlp(run)
        assert s["updates"] == golden["updates"]
        assert s["n_push"] == golden["n_push"]
        assert s["schedule_sha256"] == golden["schedule_sha256"]
        assert s["energy_j"] == pytest.approx(golden["energy_j"],
                                              rel=1e-9)
        assert [t for t, _ in s["accuracy"]] == \
            [t for t, _ in golden["accuracy"]]
        np.testing.assert_allclose([a for _, a in s["accuracy"]],
                                   [a for _, a in golden["accuracy"]],
                                   atol=0.03)

    def test_mlp_differs_from_lenet_pytree(self, run):
        """Sanity: the MLP really is a different model shape (no conv
        leaves) going through the same machinery."""
        backend = MLPBackend(2, n_train=64, n_test=32)
        assert "conv1" not in backend.server.params
        assert {"fc1", "fc2", "fc3"} <= set(backend.server.params)


def regenerate():
    r = run_mlp_golden()
    golden = summarize_mlp(r)
    print(f"mlp: updates={r.updates} energy={r.energy_j:.3f} "
          f"acc={golden['accuracy']}")
    with open(MLP_GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {MLP_GOLDEN_PATH}")


if __name__ == "__main__":
    regenerate()
