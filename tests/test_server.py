"""Async / sync parameter servers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.server import AsyncParameterServer, SyncServer


def _params(v=0.0):
    return {"w": jnp.full((4,), v), "b": jnp.zeros((2,))}


class TestAsyncServer:
    def test_replace_rule(self):
        s = AsyncParameterServer(_params(0.0), eta=0.1, beta=0.9)
        p, ver = s.pull("a")
        res = s.push("a", _params(1.0))
        assert res.lag == 0 and res.applied_weight == 1.0
        np.testing.assert_allclose(s.params["w"], 1.0)

    def test_lag_counts_foreign_updates(self):
        s = AsyncParameterServer(_params(), eta=0.1, beta=0.9)
        s.pull("a")
        s.pull("b")
        s.push("b", _params(1.0))
        s.pull("b")
        s.push("b", _params(2.0))
        res = s.push("a", _params(3.0))
        assert res.lag == 2

    def test_fedasync_poly_dampens_stale(self):
        s = AsyncParameterServer(_params(0.0), eta=0.1, beta=0.9,
                                 aggregation="fedasync_poly",
                                 fedasync_alpha=0.6, fedasync_a=0.5)
        s.pull("a")
        s.pull("b")
        s.push("b", _params(1.0))    # advances version
        res = s.push("a", _params(10.0))
        assert res.lag == 1
        expected_w = 0.6 * (1 + 1) ** -0.5
        assert res.applied_weight == pytest.approx(expected_w)
        # b's earlier push was itself dampened: 0.6 * (1+0)^-0.5 = 0.6
        prev = 0.6 * 1.0
        np.testing.assert_allclose(
            s.params["w"], expected_w * 10.0 + (1 - expected_w) * prev,
            rtol=1e-6)

    def test_gap_aware_weight_shrinks_with_gap(self):
        s = AsyncParameterServer(_params(0.0), eta=0.1, beta=0.9,
                                 aggregation="gap_aware", gap_ref=1.0)
        s.pull("a")
        s.push("a", _params(1.0))
        w_fresh = 1.0 / (1.0 + 0.0)  # first push: v_norm 0 -> gap 0
        s.pull("c")
        s.pull("b")
        s.push("b", _params(2.0))
        res = s.push("c", _params(3.0))   # lag 1, v_norm > 0 now
        assert res.applied_weight < w_fresh

    def test_momentum_norm_tracks_motion(self):
        s = AsyncParameterServer(_params(0.0), eta=0.1, beta=0.9)
        assert s.v_norm == 0.0
        s.pull("a")
        s.push("a", _params(1.0))
        assert s.v_norm > 0.0

    def test_lag_estimate_is_other_inflight(self):
        s = AsyncParameterServer(_params(), eta=0.1, beta=0.9)
        s.pull("a")
        s.pull("b")
        assert s.lag_estimate("a") == 1   # only b counts for a
        assert s.lag_estimate("c") == 2


class TestSyncServer:
    def test_fedavg_mean(self):
        s = SyncServer(_params(0.0))
        s.submit(_params(1.0))
        s.submit(_params(3.0))
        r = s.aggregate()
        assert r == 1
        np.testing.assert_allclose(s.params["w"], 2.0)

    def test_empty_round_noop(self):
        s = SyncServer(_params(5.0))
        assert s.aggregate() == 0
        np.testing.assert_allclose(s.params["w"], 5.0)
