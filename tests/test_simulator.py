"""Slotted federated simulator: the paper's Sec. VII evaluation harness."""
import numpy as np
import pytest

from repro.core.simulator import FederatedSim, SimConfig


def run(policy, **kw):
    kw.setdefault("horizon_s", 2000)
    kw.setdefault("n_users", 12)
    kw.setdefault("seed", 2)
    return FederatedSim(SimConfig(policy=policy, **kw)).run()


class TestPolicies:
    def test_all_policies_produce_updates(self):
        for pol in ("sync", "immediate", "offline", "online"):
            r = run(pol)
            assert r.updates > 0, pol
            assert r.energy_j > 0

    def test_immediate_is_energy_upper_bound(self):
        """Fig. 4a: immediate scheduling is the energy ceiling."""
        ri = run("immediate")
        ro = run("online")
        roff = run("offline")
        assert ro.energy_j < ri.energy_j
        assert roff.energy_j < ri.energy_j

    def test_immediate_has_most_updates(self):
        ri = run("immediate")
        ro = run("online")
        assert ri.updates >= ro.updates

    def test_online_corun_fraction_exceeds_immediate(self):
        """The online controller waits for co-running opportunities."""
        ri = run("immediate", horizon_s=4000)
        ro = run("online", horizon_s=4000)
        assert ro.corun_fraction >= ri.corun_fraction

    def test_offline_prefers_corunning(self):
        roff = run("offline", horizon_s=4000)
        assert roff.corun_fraction > 0.9   # knapsack takes co-run whenever allowed

    def test_deterministic_by_seed(self):
        a = run("online", seed=7)
        b = run("online", seed=7)
        assert a.energy_j == b.energy_j and a.updates == b.updates

    def test_seed_changes_trajectory(self):
        a = run("online", seed=7)
        b = run("online", seed=8)
        assert a.energy_j != b.energy_j


class TestEnergyAccounting:
    def test_energy_at_least_idle_floor(self):
        r = run("online")
        cfg = SimConfig(policy="online", horizon_s=2000, n_users=12, seed=2)
        sim = FederatedSim(cfg)
        idle_floor = sum(u.device.p_idle for u in sim.users) * cfg.horizon_s
        assert r.energy_j >= 0.95 * idle_floor

    def test_scheduler_overhead_small(self):
        """Table III: including the per-slot decision power changes total
        energy by < 10%."""
        a = run("online", include_scheduler_overhead=False)
        b = run("online", include_scheduler_overhead=True)
        assert b.energy_j >= a.energy_j
        assert (b.energy_j - a.energy_j) / a.energy_j < 0.10


class TestStalenessTraces:
    def test_push_log_records_lag_and_gap(self):
        r = run("online")
        assert len(r.push_log) == r.updates
        lags = [e["lag"] for e in r.push_log]
        gaps = [e["gap"] for e in r.push_log]
        assert all(l >= 0 for l in lags)
        assert all(g >= 0 for g in gaps)
        # Fig. 5a: lag and gap are positively correlated
        if len(set(lags)) > 1:
            c = np.corrcoef(lags, gaps)[0, 1]
            assert c > 0

    def test_sync_policy_zero_lag(self):
        r = run("sync", horizon_s=3000)
        assert all(e["lag"] == 0 for e in r.push_log)

    def test_async_builds_lag(self):
        r = run("immediate", horizon_s=3000)
        assert max((e["lag"] for e in r.push_log), default=0) > 0


class TestQueueTraces:
    def test_traces_lengths_match(self):
        r = run("online")
        assert len(r.trace_t) == len(r.trace_energy) == len(r.trace_Q) \
            == len(r.trace_H)
        assert (np.diff(r.trace_energy) >= 0).all()   # energy is cumulative
