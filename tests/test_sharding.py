"""Sharding rules: divisibility invariants, FSDP post-pass, batch specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model, sharding


def _mesh16():
    # 16-way logical mesh on 1 device: shape (1, 1) won't exercise
    # divisibility, so build an ABSTRACT mesh via jax.sharding.Mesh over a
    # reshaped device array is impossible on CPU with 1 device. Instead use
    # AbstractMesh (no devices needed).
    from repro.launch.mesh import make_abstract_mesh
    return make_abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    """Every dim sharded over an axis must be divisible by the axis size."""
    cfg = get_config(arch)
    mesh = _mesh16()
    model = build_model(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = sharding.param_pspecs(cfg, pshape, mesh)

    def check(spec, leaf):
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (arch, leaf.shape, tuple(spec))

    jax.tree.map(check, specs, pshape,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["internvl2_76b", "qwen3_moe_30b_a3b",
                                  "internlm2_20b"])
def test_fsdp_adds_data_axis_to_large_leaves(arch):
    cfg = get_config(arch)
    mesh = _mesh16()
    model = build_model(cfg)
    pshape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    base = sharding.param_pspecs(cfg, pshape, mesh)
    fs = sharding.apply_fsdp(base, pshape, mesh)

    n_upgraded = 0
    for (bs, fss, leaf) in zip(jax.tree.leaves(base, is_leaf=lambda x: isinstance(x, P)),
                               jax.tree.leaves(fs, is_leaf=lambda x: isinstance(x, P)),
                               jax.tree.leaves(pshape)):
        flat_b = [a for a in tuple(bs) if a is not None]
        flat_f = [a for a in tuple(fss) if a is not None]
        if leaf.size >= 1 << 20:
            if "data" in str(flat_f) and "data" not in str(flat_b):
                n_upgraded += 1
            # divisibility still holds
            for dim, axes in zip(leaf.shape, tuple(fss)):
                if axes is None:
                    continue
                axes = axes if isinstance(axes, tuple) else (axes,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert dim % size == 0
        else:
            assert tuple(bs) == tuple(fss)   # small leaves untouched
    assert n_upgraded > 0


def test_batch_specs_shard_leading_or_second_dim():
    cfg = get_config("qwen3_0_6b")
    mesh = _mesh16()
    # (B, S): B divisible -> dp on dim 0
    def norm(ax):
        return ax if isinstance(ax, tuple) else (ax,) if ax else None

    b1 = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    s1 = sharding.batch_pspecs(cfg, b1, mesh)
    assert norm(tuple(s1["tokens"])[0]) == ("data",)
    # (M, mb, S): M=8 not divisible, mb=32 divisible -> dp on dim 1
    b2 = {"tokens": jax.ShapeDtypeStruct((8, 32, 4096), jnp.int32)}
    s2 = sharding.batch_pspecs(cfg, b2, mesh)
    assert tuple(s2["tokens"])[0] is None
    assert norm(tuple(s2["tokens"])[1]) == ("data",)
    # (1, seq): long-context decode -> seq on dp
    b3 = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    s3 = sharding.batch_pspecs(cfg, b3, mesh)
    assert norm(tuple(s3["tokens"])[1]) == ("data",)


def test_cache_specs_prefer_kv_head_sharding_else_seq():
    mesh = _mesh16()
    # internlm2: kv=8 not divisible by 16 -> seq axis takes "model"
    cfg = get_config("internlm2_20b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 4096))
    specs = sharding.cache_pspecs(cfg, cache, mesh)
    k_spec = tuple(specs["layers"]["k"])
    assert "model" in str(k_spec[2])     # seq dim
    # zamba2 kv=32 divisible -> heads take "model"
    cfg2 = get_config("zamba2_2_7b")
    m2 = build_model(cfg2)
    cache2 = jax.eval_shape(lambda: m2.init_cache(128, 4096))
    specs2 = sharding.cache_pspecs(cfg2, cache2, mesh)
    k2 = tuple(specs2["attn"]["k"])
    assert k2[3] == "model"


def test_multi_pod_dp_axes():
    from repro.launch.mesh import make_abstract_mesh
    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert sharding.dp_axes(mesh) == ("pod", "data")
    assert sharding._prod_dp(mesh) == 32
