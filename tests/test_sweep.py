"""Batched scenario sweeps (core.scenario.run_sweep / Scenario.sweep):
parity against the per-point jax scan (the oracle the per-point engines
already pin to the loop engine), single-compile guarantees for uneven
horizons and V-grids, cache eviction accounting, per-config overflow
retry, and the bench modules' singly-typed knob columns."""
from __future__ import annotations

import dataclasses
import os
import sys

import numpy as np
import pytest

# the bench modules live in a namespace package at the repo root
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import Scenario, run_experiment, run_sweep
from repro.core import vector_engine as ve
from repro.core.engine_state import EVENT_FIELDS
from repro.core.policies import resolve_policy
from repro.core.simulator import SimConfig, n_slots


@pytest.fixture(autouse=True)
def _x64():
    """f64 matches the host engines' float semantics; f32 is a
    documented approximation."""
    import jax
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


def _per_point(sc: Scenario):
    """The per-point jax oracle for a sweep scenario."""
    return Scenario(config=dataclasses.replace(sc.config, engine="jax"),
                    arrivals=sc.arrivals).run()


def assert_sweep_parity(grid, results, energy_rtol=1e-9):
    """Each sweep row must match its per-point run: bit-identical
    discrete outputs and queue traces, energies/gaps/weights to
    ``energy_rtol`` (fma armor keeps the scan's float products rounded
    like the host's, but batched reductions may still reassociate)."""
    assert len(grid) == len(results)
    for sc, r in zip(grid, results):
        pp = _per_point(sc)
        assert r.updates == pp.updates
        assert np.array_equal(r.trace_t, pp.trace_t)
        assert np.array_equal(r.trace_Q, pp.trace_Q)
        assert np.array_equal(r.trace_H, pp.trace_H)
        np.testing.assert_allclose(r.energy_j, pp.energy_j,
                                   rtol=energy_rtol)
        np.testing.assert_allclose(r.trace_energy, pp.trace_energy,
                                   rtol=energy_rtol)
        assert r.mean_Q == pp.mean_Q
        np.testing.assert_allclose(r.mean_H, pp.mean_H, rtol=energy_rtol)
        assert r.corun_fraction == pp.corun_fraction
        assert r.drops == pp.drops
        # push-log digest: discrete columns exact, float columns tight
        a, b = r.push_log.arrays(), pp.push_log.arrays()
        assert len(r.push_log) == len(pp.push_log)
        for j, name in enumerate(EVENT_FIELDS):
            if name in ("t", "user", "lag", "corun"):
                assert np.array_equal(a[j], b[j]), name
            else:
                np.testing.assert_allclose(a[j], b[j], rtol=energy_rtol,
                                           err_msg=name)


class TestSweepParity:
    @pytest.mark.parametrize("policy", ("online", "eps_greedy"))
    @pytest.mark.parametrize("aggregation", ("replace", "fedasync_poly"))
    @pytest.mark.parametrize("dynamics", ("none", "markov"))
    def test_matrix_vs_per_point(self, policy, aggregation, dynamics):
        kw = dict(policy=policy, n_users=10, horizon_s=900, seed=5,
                  app_arrival_p=0.02, collect_push_log=True,
                  aggregation=aggregation, V=60.0, jax_chunk=256)
        if dynamics != "none":
            kw["dynamics"] = dynamics
        grid = Scenario(**kw).grid(V=[20.0, 60.0, 200.0])
        assert_sweep_parity(grid, run_sweep(grid))

    def test_per_config_overflow_retry(self):
        # capacity 2 forces the batched push buffer to overflow and the
        # chunk to re-run doubled — per-config counts must stay exact
        grid = Scenario(policy="immediate", n_users=8, horizon_s=1200,
                        seed=1, app_arrival_p=0.02, collect_push_log=True,
                        push_log_capacity=2).grid(seed=[1, 2, 3])
        results = run_sweep(grid)
        assert_sweep_parity(grid, results)
        assert all(len(r.push_log) > 2 for r in results)

    def test_mixed_shapes_bucket_and_fallback(self):
        # two shape buckets + a vmap-ineligible offline scenario: results
        # must come back in input order, each matching its own oracle
        scs = (Scenario(policy="online", n_users=10, horizon_s=900,
                        seed=2).grid(V=[20.0, 50.0])
               + Scenario(policy="online", n_users=14, horizon_s=900,
                          seed=2).grid(V=[20.0, 50.0])
               + [Scenario(policy="offline", n_users=10, horizon_s=900,
                           seed=2)])
        results = run_sweep(scs)
        for sc, r in zip(scs, results):
            pp = Scenario(config=sc.config, arrivals=sc.arrivals).run()
            assert r.updates == pp.updates
            np.testing.assert_allclose(r.energy_j, pp.energy_j, rtol=1e-9)

    def test_sweep_rejects_non_scenarios(self):
        with pytest.raises(TypeError, match="Scenario"):
            run_sweep([SimConfig(policy="online")])

    def test_grid_order_and_arrival_rebinding(self):
        base = Scenario(policy="online", n_users=8, horizon_s=600, seed=0,
                        app_arrival_p=0.001)
        grid = base.grid(V=[1.0, 2.0], L_b=[10.0, 20.0])
        assert [(s.config.V, s.config.L_b) for s in grid] == \
            [(1.0, 10.0), (1.0, 20.0), (2.0, 10.0), (2.0, 20.0)]
        # a swept app_arrival_p must rebind the default Bernoulli
        # process, not keep the base scenario's bound rate
        lo, hi = base.grid(app_arrival_p=[0.0, 0.5])
        a = lo.build()
        b = hi.build()
        assert not a.app_sched.any()
        assert b.app_sched.mean() > 0.25


class TestSweepCompileCost:
    def test_uneven_horizon_single_compile(self):
        # T=2000, chunk=512 -> a partial tail chunk; the padded scan
        # must reuse ONE executable per (shape, policy), not compile a
        # second tail program
        kw = dict(policy="online", n_users=9, horizon_s=2000, seed=4,
                  engine="jax", jax_chunk=512, collect_push_log=True)
        before = set(ve._JAX_FN_CACHE)
        r = run_experiment(Scenario(**kw))
        assert len(set(ve._JAX_FN_CACHE) - before) == 1
        # and a repeat run compiles nothing
        before = set(ve._JAX_FN_CACHE)
        run_experiment(Scenario(**kw))
        assert set(ve._JAX_FN_CACHE) == before
        # the padded tail is a no-op: parity with the vectorized oracle
        pp = run_experiment(Scenario(**{**kw, "engine": "vectorized"}))
        assert r.updates == pp.updates
        assert np.array_equal(r.trace_Q, pp.trace_Q)
        np.testing.assert_allclose(r.energy_j, pp.energy_j, rtol=1e-9)

    def test_vgrid_compiles_at_most_two_programs(self):
        # acceptance criterion: a >=16-point V-sweep with shared static
        # shapes runs under ONE compiled program (plus at most one
        # tail-chunk/overflow-retry program)
        grid = Scenario(policy="online", n_users=25, horizon_s=600,
                        seed=0).grid(
            V=[float(10 ** (2 + 4 * k / 15)) for k in range(16)])
        before = set(ve._JAX_FN_CACHE)
        results = run_sweep(grid)
        assert len(results) == 16
        assert len(set(ve._JAX_FN_CACHE) - before) <= 2
        # distinct V must actually produce distinct schedules
        assert len({r.updates for r in results}) > 1

    def test_bucketed_sweep_never_recompiles(self, monkeypatch):
        # regression: with a too-small LRU cap a 3-bucket sweep would
        # thrash — run_sweep must reserve capacity so every bucket stays
        # resident, and a repeat sweep must be all cache hits
        monkeypatch.setattr(ve, "_JAX_FN_CACHE_MAX", 1)
        scs = []
        for pol in ("online", "immediate", "greedy"):
            scs += Scenario(policy=pol, n_users=8, horizon_s=600,
                            seed=3).grid(V=[10.0, 40.0])
        run_sweep(scs)
        assert ve._JAX_FN_CACHE_MAX >= 3    # reserved for the buckets
        stats = ve.jax_cache_stats()
        run_sweep(scs)
        stats2 = ve.jax_cache_stats()
        assert stats2["misses"] == stats["misses"]      # no recompiles
        assert stats2["evictions"] == stats["evictions"]

    def test_eviction_counter(self, monkeypatch):
        # force the cache over its cap and check the eviction is counted
        run_experiment(Scenario(policy="online", n_users=7, horizon_s=400,
                                seed=0, engine="jax"))
        assert ve._JAX_FN_CACHE
        monkeypatch.setattr(ve, "_JAX_FN_CACHE_MAX", 1)
        ev0 = ve.jax_cache_stats()["evictions"]
        run_experiment(Scenario(policy="greedy", n_users=7, horizon_s=400,
                                seed=0, engine="jax"))
        assert ve.jax_cache_stats()["evictions"] > ev0

    def test_offline_policy_not_vmapped(self):
        assert resolve_policy("offline").supports_vmap is False
        sim = Scenario(policy="offline", n_users=8, horizon_s=600,
                       seed=0).build()
        assert ve.sweep_bucket_key(sim) is None

    def test_sharded_run_never_aliases_unsharded(self):
        # the memo key carries the mesh signature + padded user-axis
        # length: an n_devices run must trace its OWN executable (the
        # sharded program bakes in collectives and device assignments),
        # never reuse — or poison — the unsharded entry of the same
        # shape, and it opts out of the batched-sweep path entirely
        import jax
        kw = dict(policy="online", n_users=8, horizon_s=600, seed=3,
                  engine="jax", jax_chunk=128)
        run_experiment(Scenario(**kw))                  # warm unsharded
        sharded = dict(kw, n_devices=len(jax.devices()))
        assert ve.sweep_bucket_key(Scenario(**sharded).build()) is None
        before = set(ve._JAX_FN_CACHE)
        stats0 = ve.jax_cache_stats()
        run_experiment(Scenario(**sharded))
        assert len(set(ve._JAX_FN_CACHE) - before) == 1  # distinct key
        stats1 = ve.jax_cache_stats()
        assert stats1["misses"] == stats0["misses"] + 1
        # repeats of either flavor are pure cache hits
        run_experiment(Scenario(**sharded))
        run_experiment(Scenario(**kw))
        assert ve.jax_cache_stats()["misses"] == stats1["misses"]

    def test_run_jax_sweep_rejects_mixed_keys(self):
        sims = [Scenario(policy="online", n_users=n, horizon_s=600,
                         seed=0).build() for n in (8, 12)]
        with pytest.raises(ValueError, match="sweep_bucket_key"):
            ve.run_jax_sweep(sims)


class TestBenchColumns:
    def test_fig4_fig6_knob_columns_singly_typed(self, tmp_path):
        from benchmarks import bench_fig4_tradeoff, bench_fig6_arrival
        rows4 = bench_fig4_tradeoff.run(
            fast=True, json_path=str(tmp_path / "fig4.json"))
        assert all(r["V"] is None or isinstance(r["V"], float)
                   for r in rows4)
        assert all(isinstance(r["L_b"], float) for r in rows4)
        assert any(r["V"] is None for r in rows4)       # baselines
        rows6 = bench_fig6_arrival.run(
            fast=True, json_path=str(tmp_path / "fig6.json"))
        assert all(r["arrival_p"] is None
                   or isinstance(r["arrival_p"], float) for r in rows6)
        assert any(r["arrival_p"] is None for r in rows6)   # bursty
        assert (tmp_path / "fig4.json").exists()
        assert (tmp_path / "fig6.json").exists()
