"""Real-ML mode: golden oracle pins + batched-engine parity.

The loop engine (``FederatedSim._run_loop`` driving per-user hooks) is the
ground truth for real-mode runs; this module pins it two ways:

* ``tests/data/real_mode_golden.json`` — seeded loop-engine trajectories
  (update counts, total energy, schedule digest, accuracy points) at
  n_users=4 on a short horizon, regenerated with
  ``PYTHONPATH=src python tests/test_real_mode.py``. Guards against
  accidental semantic drift of the oracle itself.
* loop-vs-vectorized parity — the batched backend path
  (``core/realml.LeNetBackend`` driven cohort-at-a-time by
  ``core/vector_engine``) must reproduce the oracle's schedule decisions
  EXACTLY and its float metrics within tolerance.

Tolerances, documented: under the paper's queue regime (L_b large, H == 0)
every pinned policy's schedule is independent of the momentum norm — sync /
immediate trivially, online because the H*gap term vanishes from the
argmin — so schedule equality is exact by construction, and energy (a pure
function of the schedule) matches to float-sum reordering (rtol 1e-9).
Training itself runs as one vmap'd XLA program per cohort instead of k
per-client programs, which is NOT guaranteed bit-identical, so
accuracy points and Eq. (4) gap values carry an absolute tolerance
(accuracy is quantized at 1/n_test; 0.03 absorbs a couple of flipped test
samples across platforms). The offline policy's knapsack reads the evolving
momentum norm, so its cross-engine check compares update counts and energy
rather than the per-push digest.
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.core.realml import LeNetBackend
from repro.core.simulator import FederatedSim, SimConfig

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "real_mode_golden.json")

# Short-horizon, 4-user setup: small V so the online policy's Q threshold
# (Q >= V * (P_sched - P_idle) * t_d, Eq. 22) is reachable within the
# horizon, L_b at the paper's relaxed default so H stays 0 and schedule
# decisions are momentum-norm independent (see module docstring).
SIM_KW = dict(n_users=4, horizon_s=900, app_arrival_p=0.004, seed=0,
              ml_mode="real", V=5.0)
ML_KW = dict(n_train=256, n_test=128, seed=0, eval_every=300)
GOLDEN_POLICIES = ("online", "immediate", "sync")


def run_real(policy: str, engine: str, sim_kw=None, ml_kw=None):
    sim_kw = dict(SIM_KW, **(sim_kw or {}))
    ml_kw = dict(ML_KW, **(ml_kw or {}))
    backend = LeNetBackend(sim_kw["n_users"], sync=(policy == "sync"),
                           **ml_kw)
    cfg = SimConfig(policy=policy, engine=engine, **sim_kw)
    return FederatedSim(cfg, ml_backend=backend).run()


def schedule_digest(push_log) -> str:
    """Digest of the schedule-determined push fields (no floats)."""
    payload = json.dumps([(e["t"], e["user"], e["lag"], e["corun"])
                          for e in push_log]).encode()
    return hashlib.sha256(payload).hexdigest()


def summarize(r) -> dict:
    return {
        "updates": r.updates,
        "energy_j": r.energy_j,
        "mean_Q": r.mean_Q,
        "corun_fraction": r.corun_fraction,
        "n_push": len(r.push_log),
        "schedule_sha256": schedule_digest(r.push_log),
        "accuracy": [[int(t), float(a)] for t, a in r.accuracy],
    }


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def runs():
    """One loop + one vectorized run per pinned policy (module-cached:
    real training is the slow part of this file)."""
    return {(p, e): run_real(p, e)
            for p in GOLDEN_POLICIES for e in ("loop", "vectorized")}


class TestGoldenOracle:
    @pytest.mark.parametrize("policy", GOLDEN_POLICIES)
    def test_loop_matches_golden(self, golden, runs, policy):
        g = golden[policy]
        s = summarize(runs[(policy, "loop")])
        assert s["updates"] == g["updates"]
        assert s["n_push"] == g["n_push"]
        assert s["schedule_sha256"] == g["schedule_sha256"]
        assert s["energy_j"] == pytest.approx(g["energy_j"], rel=1e-9)
        assert s["mean_Q"] == pytest.approx(g["mean_Q"], rel=1e-9)
        assert s["corun_fraction"] == pytest.approx(g["corun_fraction"])
        assert [t for t, _ in s["accuracy"]] == [t for t, _ in g["accuracy"]]
        np.testing.assert_allclose([a for _, a in s["accuracy"]],
                                   [a for _, a in g["accuracy"]],
                                   atol=0.03)

    @pytest.mark.parametrize("policy", GOLDEN_POLICIES)
    def test_vectorized_matches_golden(self, golden, runs, policy):
        """The batched engine reproduces the pinned schedule exactly and
        the float metrics within the documented tolerance."""
        g = golden[policy]
        s = summarize(runs[(policy, "vectorized")])
        assert s["updates"] == g["updates"]
        assert s["schedule_sha256"] == g["schedule_sha256"]
        assert s["energy_j"] == pytest.approx(g["energy_j"], rel=1e-9)
        np.testing.assert_allclose([a for _, a in s["accuracy"]],
                                   [a for _, a in g["accuracy"]],
                                   atol=0.03)

    @pytest.mark.parametrize("policy", GOLDEN_POLICIES)
    def test_engine_parity(self, runs, policy):
        """Loop vs vectorized, same process: schedule bit-for-bit, gaps
        and accuracy within float tolerance, queue traces equal."""
        a = runs[(policy, "loop")]
        b = runs[(policy, "vectorized")]
        assert a.updates == b.updates
        assert b.energy_j == pytest.approx(a.energy_j, rel=1e-9)
        assert b.mean_Q == pytest.approx(a.mean_Q, rel=1e-9, abs=1e-12)
        assert b.corun_fraction == pytest.approx(a.corun_fraction)
        np.testing.assert_array_equal(a.trace_t, b.trace_t)
        np.testing.assert_allclose(b.trace_energy, a.trace_energy,
                                   rtol=1e-9)
        np.testing.assert_allclose(b.trace_Q, a.trace_Q, rtol=1e-9,
                                   atol=1e-12)
        assert [(e["t"], e["user"], e["lag"], e["corun"])
                for e in a.push_log] == \
               [(e["t"], e["user"], e["lag"], e["corun"])
                for e in b.push_log]
        np.testing.assert_allclose([e["gap"] for e in b.push_log],
                                   [e["gap"] for e in a.push_log],
                                   rtol=1e-6, atol=1e-9)
        assert [t for t, _ in a.accuracy] == [t for t, _ in b.accuracy]
        np.testing.assert_allclose([x for _, x in b.accuracy],
                                   [x for _, x in a.accuracy], atol=0.03)


class TestBeyondGolden:
    def test_offline_engine_parity(self):
        """Offline's knapsack reads the evolving momentum norm, so only
        schedule-aggregate metrics are compared across engines (the
        per-push digest could legitimately differ across XLA programs)."""
        a = run_real("offline", "loop")
        b = run_real("offline", "vectorized")
        assert a.updates == b.updates
        assert b.energy_j == pytest.approx(a.energy_j, rel=1e-6)
        assert [t for t, _ in a.accuracy] == [t for t, _ in b.accuracy]
        np.testing.assert_allclose([x for _, x in b.accuracy],
                                   [x for _, x in a.accuracy], atol=0.03)

    def test_same_slot_full_cohort(self):
        """Batched-dispatch worst case: a uniform fleet with no apps makes
        every user finish in the same slot — one vmap'd cohort of the whole
        fleet — and the schedule still matches the oracle exactly."""
        from repro.core import TESTBED, CustomCatalogFleet
        fleet = CustomCatalogFleet([TESTBED["Pixel2"]])
        kw = dict(n_users=4, horizon_s=500, app_arrival_p=0.0, seed=0,
                  ml_mode="real")
        res = {}
        for engine in ("loop", "vectorized"):
            backend = LeNetBackend(4, sync=False, **ML_KW)
            cfg = SimConfig(policy="immediate", engine=engine, **kw)
            res[engine] = FederatedSim(cfg, ml_backend=backend,
                                       fleet=fleet).run()
        a, b = res["loop"], res["vectorized"]
        # all four finish together: each push slot carries the full cohort
        slots = [e["t"] for e in a.push_log]
        assert a.updates == 8 and len(set(slots)) == 2
        assert schedule_digest(a.push_log) == schedule_digest(b.push_log)
        assert b.energy_j == pytest.approx(a.energy_j, rel=1e-9)
        np.testing.assert_allclose([x for _, x in b.accuracy],
                                   [x for _, x in a.accuracy], atol=0.03)

    def test_scenario_ml_threading(self):
        """Scenario(ml="lenet") builds a fresh backend per run, forces
        ml_mode='real', matches the policy's round mode, and auto-selects
        the vectorized engine."""
        from repro.core import Scenario
        scn = Scenario(policy="sync", ml="lenet", ml_kwargs=ML_KW,
                       n_users=4, horizon_s=600, app_arrival_p=0.004,
                       seed=0)
        assert scn.config.ml_mode == "real"
        sim = scn.build()
        assert sim.ml_backend.sync is True
        assert sim.ml_backend.n_users == 4
        assert sim.resolve_engine() == "vectorized"
        r = sim.run()
        assert r.accuracy and r.accuracy[-1][0] == 600
        # a second build must not reuse consumed server state
        assert scn.build().ml_backend is not sim.ml_backend

    def test_scenario_rejects_ml_kwargs_without_ml(self):
        from repro.core import Scenario
        with pytest.raises(ValueError, match="ml_kwargs"):
            Scenario(policy="online", ml_kwargs={"n_train": 64})

    def test_backend_requires_real_mode(self):
        backend = LeNetBackend(4, **ML_KW)
        with pytest.raises(ValueError, match="real"):
            FederatedSim(SimConfig(n_users=4), ml_backend=backend)

    def test_backend_n_users_mismatch(self):
        backend = LeNetBackend(4, **ML_KW)
        cfg = SimConfig(n_users=8, ml_mode="real")
        with pytest.raises(ValueError, match="n_users"):
            FederatedSim(cfg, ml_backend=backend)

    def test_hooks_and_backend_mutually_exclusive(self):
        backend = LeNetBackend(4, **ML_KW)
        cfg = SimConfig(n_users=4, ml_mode="real")
        with pytest.raises(ValueError, match="not both"):
            FederatedSim(cfg, ml_hooks={"v_norm": lambda: 1.0},
                         ml_backend=backend)

    def test_make_ml_hooks_is_backend_adapter(self):
        """The historical entry point now rides on LeNetBackend — same
        server/client objects, same hook keys as the pre-backend dict."""
        from repro.core.realml import make_ml_hooks
        hooks, state = make_ml_hooks(4, n_train=256, n_test=128)
        assert {"pull", "local_train", "push", "evaluate",
                "v_norm", "eval_every"} <= set(hooks)
        assert state["backend"].server is state["server"]
        hooks_s, state_s = make_ml_hooks(2, sync=True, n_train=128,
                                         n_test=64)
        assert {"sync_submit", "sync_aggregate"} <= set(hooks_s)
        assert "push" not in hooks_s


def regenerate():
    golden = {}
    for policy in GOLDEN_POLICIES:
        r = run_real(policy, "loop")
        golden[policy] = summarize(r)
        print(f"{policy}: updates={r.updates} "
              f"energy={r.energy_j:.3f} acc={golden[policy]['accuracy']}")
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    regenerate()
