"""Minimal deterministic stand-in for `hypothesis`.

Installed into ``sys.modules`` by ``conftest.py`` only when the real
package is absent, so the property-based test modules still collect and
run. Each ``@given`` test is executed ``max_examples`` times with values
drawn from a per-test seeded RNG (boundary values first), which keeps runs
reproducible. This is NOT a replacement for hypothesis — no shrinking, no
sophisticated edge-case generation — install the real package
(``pip install -r requirements-dev.txt``) for full coverage.

Supported API (the subset this repo's tests use): ``given``, ``settings``,
``assume``, ``HealthCheck`` and the strategies ``integers``, ``floats``,
``booleans``, ``sampled_from``, ``just``, ``tuples``, ``lists``.
"""
from __future__ import annotations

import types
import zlib

import numpy as np


class _UnsatisfiedAssumption(Exception):
    pass


def assume(condition):
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


class _Strategy:
    """A sampler plus a short list of boundary examples tried first."""

    def __init__(self, sample, edges=()):
        self._sample = sample
        self.edges = tuple(edges)

    def example(self, rng, i):
        if i < len(self.edges):
            return self.edges[i]
        return self._sample(rng)


def integers(min_value, max_value):
    return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)),
                     edges=(int(min_value), int(max_value)))


def floats(min_value=None, max_value=None, **_kw):
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)
    return _Strategy(lambda r: float(r.uniform(lo, hi)),
                     edges=(lo, hi, (lo + hi) / 2.0))


def booleans():
    return _Strategy(lambda r: bool(r.integers(0, 2)), edges=(False, True))


def sampled_from(elements):
    seq = list(elements)
    return _Strategy(lambda r: seq[int(r.integers(0, len(seq)))],
                     edges=tuple(seq[:2]))


def just(value):
    return _Strategy(lambda r: value, edges=(value,))


def tuples(*strategies):
    def sample(r):
        return tuple(s.example(r, 10 ** 9) for s in strategies)
    return _Strategy(sample)


def lists(elements, min_size=0, max_size=None, **_kw):
    hi = (min_size + 10) if max_size is None else max_size

    def sample(r):
        k = int(r.integers(min_size, hi + 1))
        return [elements.example(r, 10 ** 9) for _ in range(k)]
    return _Strategy(sample)


class settings:
    """Decorator recording ``max_examples``; ``given`` reads it back."""

    def __init__(self, max_examples=20, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            # @settings may sit above OR below @given; check both targets
            n_ex = getattr(wrapper, "_stub_max_examples",
                           getattr(fn, "_stub_max_examples", 20))
            seed = zlib.adler32(
                f"{fn.__module__}.{getattr(fn, '__qualname__', fn.__name__)}"
                .encode())
            rng = np.random.default_rng(seed)
            for i in range(n_ex):
                vals = [s.example(rng, i) for s in strategies]
                kvals = {k: s.example(rng, i)
                         for k, s in kw_strategies.items()}
                try:
                    fn(*args, *vals, **kwargs, **kvals)
                except _UnsatisfiedAssumption:
                    continue
        # NOTE: no functools.wraps — pytest must see the (*args, **kwargs)
        # signature, not the original one, or it would try to resolve the
        # generated parameters as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


# `from hypothesis import strategies as st` / `import hypothesis.strategies`
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.booleans = booleans
strategies.sampled_from = sampled_from
strategies.just = just
strategies.tuples = tuples
strategies.lists = lists
