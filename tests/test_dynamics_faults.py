"""Fault-injection harness for the device-dynamics layer (core/dynamics.py).

Each named scenario is a churn regime — steady availability churn, a mass
dropout spike, a battery blackout, flapping availability, a bad-network
regime — run through all three engines. The suite pins:

- loop / vectorized / jax push-log DIGEST parity under every scenario and
  both dropout rules (the acceptance criterion: churn must not break the
  engines' bit-parity contract);
- queue-invariant preservation under churn (``in_flight`` never negative
  and always equal to the number of training users; Q/H never negative),
  checked every slot by an instrumented policy;
- the robustness headline: a started user goes down mid-training
  (``result.drops > 0``) and the run stays consistent;
- PushBuffer overflow round-trips losslessly when churn recovery floods a
  slot with pushes (satellite: the jax event buffer's doubling retry);
- the fault monitors (repro.fault) wired to the simulator's slot clock:
  replaying a churned run's push stream evicts exactly the users that
  went silent, and evicted users re-enter on their next push.
"""
import hashlib

import numpy as np
import pytest

from repro.core import (FederatedSim, ImmediatePolicy, MarkovChurnDynamics,
                        Scenario, SimConfig)
from repro.core.dynamics import (NoDynamics, dynamics_support,
                                 registered_dynamics, resolve_dynamics)
from repro.core.engine_state import MODE_TRAIN
from repro.fault import FleetMonitor

# ---------------------------------------------------------------------------
# Fault scenarios: name -> (sim kwargs, dynamics kwargs)
# ---------------------------------------------------------------------------
BASE = dict(n_users=16, horizon_s=1200, seed=7, app_arrival_p=0.01,
            policy="immediate")

SCENARIOS = {
    # steady background churn: the common case
    "churn": dict(p_off=0.01, p_on=0.05),
    # mass dropout spike: a fifth of the fleet drops every slot
    "mass_dropout": dict(p_off=0.2, p_on=0.05),
    # battery blackout: thin batteries + heavy train drain collapse
    # participation until chargers catch up (DEAL-style gating)
    "battery_blackout": dict(p_off=0.0, p_on=1.0, battery_init=0.35,
                             drain_train=5e-3, drain_corun=8e-3,
                             charge_rate=2e-4, battery_min=0.2),
    # flapping availability: rapid off/on cycling
    "flapping": dict(p_off=0.3, p_on=0.5),
    # bad network regime: churn plus long re-arrival delays
    "net_degraded": dict(p_off=0.02, p_on=0.1, p_net_bad=0.1,
                         p_net_recover=0.05, net_delay_slots=40),
}


def _dyn(scenario: str, dropout: str = "lose") -> MarkovChurnDynamics:
    return MarkovChurnDynamics(dropout=dropout, resume_penalty_s=20.0,
                               **SCENARIOS[scenario])


def _digest(log) -> str:
    h = hashlib.sha256()
    for e in log:
        h.update(f'{e["t"]},{e["user"]},{e["lag"]},{e["gap"]!r},'
                 f'{int(e["corun"])};'.encode())
    return h.hexdigest()


def _run(engine, dynamics, **over):
    kw = dict(BASE, **over)
    return Scenario(engine=engine, dynamics=dynamics, **kw).run()


@pytest.fixture(scope="module", autouse=True)
def _x64():
    """f64 scan parity with the host engines (same contract as the
    golden jax tests)."""
    import jax
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


# ---------------------------------------------------------------------------
# Three-engine parity under every fault scenario
# ---------------------------------------------------------------------------
class TestEngineParityUnderChurn:
    @pytest.mark.parametrize("scenario", list(SCENARIOS))
    @pytest.mark.parametrize("dropout", ["lose", "resume"])
    def test_push_log_digests_identical(self, scenario, dropout):
        dyn = _dyn(scenario, dropout)
        rl = _run("loop", dyn)
        rv = _run("vectorized", dyn)
        rj = _run("jax", dyn)
        d = _digest(rl.push_log)
        assert _digest(rv.push_log) == d
        assert _digest(rj.push_log) == d
        assert rl.updates == rv.updates == rj.updates
        assert rl.drops == rv.drops == rj.drops
        assert rv.energy_j == pytest.approx(rl.energy_j, rel=1e-9)
        assert rj.energy_j == pytest.approx(rl.energy_j, rel=1e-9)
        assert rv.mean_Q == pytest.approx(rl.mean_Q, rel=1e-9, abs=1e-12)
        assert rj.mean_Q == pytest.approx(rl.mean_Q, rel=1e-9, abs=1e-12)
        assert rv.mean_H == pytest.approx(rl.mean_H, rel=1e-6, abs=1e-9)
        assert rj.mean_H == pytest.approx(rl.mean_H, rel=1e-6, abs=1e-9)

    @pytest.mark.parametrize("policy", ["online", "offline", "sync",
                                        "eps_greedy"])
    def test_parity_holds_for_other_policies(self, policy):
        dyn = _dyn("churn")
        kw = {} if policy != "eps_greedy" else dict(seed=11)
        rl = _run("loop", dyn, policy=policy, **kw)
        rv = _run("vectorized", dyn, policy=policy, **kw)
        rj = _run("jax", dyn, policy=policy, **kw)
        assert _digest(rl.push_log) == _digest(rv.push_log) == \
            _digest(rj.push_log)
        assert rl.drops == rv.drops == rj.drops

    def test_churn_actually_bites(self):
        """The scenarios are not vacuous: churn drops trainers and costs
        updates relative to the always-on fleet."""
        r0 = _run("vectorized", "none")
        rc = _run("vectorized", _dyn("mass_dropout"))
        assert r0.drops == 0
        assert rc.drops > 0
        assert rc.updates < r0.updates
        assert rc.energy_j < r0.energy_j      # down devices draw nothing


# ---------------------------------------------------------------------------
# The robustness headline: mid-training dropout with consistent queues
# ---------------------------------------------------------------------------
class _InvariantPolicy(ImmediatePolicy):
    """Immediate scheduling plus a per-slot audit of the scheduler's
    bookkeeping: ``in_flight`` must equal the number of training users
    (a mid-training dropout must decrement it exactly once) and never go
    negative; the Lyapunov queues must stay non-negative."""

    name = "invariant-audit"

    def __init__(self):
        self.violations = []

    def decide_loop(self, sim, t, waiting, carry):
        n_train = sum(1 for u in sim.users if u.mode == "training")
        self._audit(t, sim.in_flight, n_train, sim.sched.Q, sim.sched.H)
        return super().decide_loop(sim, t, waiting, carry)

    def decide_vectorized(self, eng, t, carry):
        n_train = int(np.count_nonzero(eng.s.mode == MODE_TRAIN))
        self._audit(t, int(eng.s.in_flight), n_train,
                    eng.sched.Q, eng.sched.H)
        return super().decide_vectorized(eng, t, carry)

    def _audit(self, t, in_flight, n_train, Q, H):
        if in_flight < 0:
            self.violations.append((t, "in_flight negative", in_flight))
        if in_flight != n_train:
            self.violations.append(
                (t, "in_flight != #training", in_flight, n_train))
        if Q < 0 or H < 0:
            self.violations.append((t, "negative queue", Q, H))


class TestMidTrainingDropout:
    @pytest.mark.parametrize("engine", ["loop", "vectorized"])
    @pytest.mark.parametrize("dropout", ["lose", "resume"])
    def test_started_user_drops_and_queues_stay_consistent(self, engine,
                                                           dropout):
        pol = _InvariantPolicy()
        r = _run(engine, _dyn("mass_dropout", dropout), policy=pol)
        assert r.drops > 0            # started users went down mid-run
        assert pol.violations == []
        assert r.mean_Q >= 0.0 and r.mean_H >= 0.0

    @pytest.mark.parametrize("dropout", ["lose", "resume"])
    def test_jax_final_state_queue_consistent(self, dropout):
        """The scan cannot host a per-slot Python audit; pin the final
        carry instead — in_flight == #training and non-negative — plus
        digest parity with the audited numpy run."""
        dyn = _dyn("mass_dropout", dropout)
        cfg = SimConfig(engine="jax", dynamics=dyn, **BASE)
        sim = FederatedSim(cfg)
        rj = sim.run()
        es = sim.state
        assert int(es.in_flight) >= 0
        assert int(es.in_flight) == int(np.count_nonzero(
            es.mode == MODE_TRAIN))
        pol = _InvariantPolicy()
        rv = _run("vectorized", dyn, policy=pol)
        assert pol.violations == []
        assert _digest(rj.push_log) == _digest(rv.push_log)

    def test_resume_rule_pays_extra_lag(self):
        """A resumed dropout finishes late: with everything else equal,
        the resume fleet's pushes land with at least the lose fleet's
        total delay, and paused slots make no progress (fewer or equal
        updates than an un-churned run)."""
        r_none = _run("vectorized", "none")
        r_resume = _run("vectorized", _dyn("churn", "resume"))
        assert r_resume.drops > 0
        assert r_resume.updates <= r_none.updates

    def test_drops_counts_down_edges_of_trainers_only(self):
        """No training => no mid-training drops, however hard the
        availability churn."""
        r = _run("vectorized", _dyn("flapping"),
                 app_arrival_p=0.0, policy="online", V=1e9)
        assert r.updates == 0
        assert r.drops == 0


# ---------------------------------------------------------------------------
# PushBuffer overflow under a churn-inflated push burst (satellite)
# ---------------------------------------------------------------------------
class TestPushBufferChurnBurst:
    def test_mass_recovery_burst_round_trips_losslessly(self):
        """Flapping availability synchronizes re-entries, so single slots
        flood the jax event buffer; a capacity-1 buffer must still
        produce the exact log of an amply-sized one (doubling retry).
        Resume dropout: under "lose" this churn rate never lets a
        training run complete, so there would be no pushes to buffer."""
        dyn = MarkovChurnDynamics(p_off=0.3, p_on=0.9, dropout="resume")
        kw = dict(BASE, n_users=32, horizon_s=600)
        tiny = Scenario(engine="jax", dynamics=dyn,
                        push_log_capacity=1, **kw).run()
        ample = Scenario(engine="jax", dynamics=dyn,
                         push_log_capacity=4096, **kw).run()
        assert len(tiny.push_log) > 0
        assert _digest(tiny.push_log) == _digest(ample.push_log)
        assert [e["weight"] for e in tiny.push_log] == \
            [e["weight"] for e in ample.push_log]
        # and the host engine agrees
        host = Scenario(engine="vectorized", dynamics=dyn, **kw).run()
        assert _digest(host.push_log) == _digest(tiny.push_log)


# ---------------------------------------------------------------------------
# Fault monitors on the simulator's slot clock
# ---------------------------------------------------------------------------
class TestMonitorIntegration:
    def test_replay_evicts_churned_users_and_readmits_them(self):
        r = _run("vectorized", _dyn("churn"), horizon_s=2400)
        T = 2400
        log = list(r.push_log)
        assert len(log) > 0
        mon = FleetMonitor(timeout_slots=400)
        evictions = mon.replay(r.push_log, T)
        # churn silences users long enough to trip the heartbeat timeout
        assert evictions
        # every eviction is justified: no push from that user inside the
        # timeout window before the eviction slot
        for slot, uid in evictions:
            recent = [e for e in log
                      if e["user"] == uid and slot - 400 <= e["t"] <= slot]
            assert not recent, (slot, uid, recent)
        # eviction is non-final: at least one evicted user pushes again
        # (the simulator's recovery path re-enters the arrival process)
        readmitted = [uid for slot, uid in evictions
                      if any(e["user"] == uid and e["t"] > slot
                             for e in log)]
        assert readmitted

    def test_no_evictions_without_churn(self):
        """Timeout above the fleet's natural worst-case push interval:
        an always-on fleet must never trip the heartbeat."""
        r = _run("vectorized", "none")
        mon = FleetMonitor(timeout_slots=800)
        assert mon.replay(r.push_log, 1200) == []


# ---------------------------------------------------------------------------
# Registry / construction-time validation
# ---------------------------------------------------------------------------
class TestDynamicsConfigValidation:
    def test_registry_contains_shipped_dynamics(self):
        assert {"none", "markov"} <= set(registered_dynamics())
        assert isinstance(resolve_dynamics("none"), NoDynamics)
        assert resolve_dynamics("none") is resolve_dynamics("none")

    def test_unknown_name_rejected_at_config_time(self):
        with pytest.raises(ValueError, match="unknown dynamics"):
            SimConfig(dynamics="nope")

    def test_bad_knobs_rejected(self):
        with pytest.raises(ValueError, match="p_off"):
            MarkovChurnDynamics(p_off=1.5)
        with pytest.raises(ValueError, match="battery_min"):
            MarkovChurnDynamics(battery_min=2.0)
        with pytest.raises(ValueError, match="dropout"):
            MarkovChurnDynamics(dropout="retry")
        with pytest.raises(ValueError, match="net_delay_slots"):
            MarkovChurnDynamics(net_delay_slots=-1)

    def test_supports_jax_flag_without_hook_rejected(self):
        from repro.core.dynamics import DeviceDynamics

        class _Lying(MarkovChurnDynamics):
            name = "lying-test"
            # flag stays True but the hook is the base stub again
            scan_step = DeviceDynamics.scan_step

        lie = _Lying()
        assert not dynamics_support(lie)["jax"]
        with pytest.raises(ValueError, match="supports_jax"):
            SimConfig(dynamics=lie)

    def test_dynamics_without_jax_hook_degrades_engine(self):
        class _HostOnly(MarkovChurnDynamics):
            name = "host-only-test"
            supports_jax = False

        sim = Scenario(engine="jax", dynamics=_HostOnly(),
                       **BASE).build()
        assert sim.resolve_engine() == "vectorized"
        sim0 = Scenario(engine="jax", dynamics="none", **BASE).build()
        assert sim0.resolve_engine() == "jax"

    def test_per_device_class_probabilities_gather_per_user(self):
        # one p_off per catalog row of the paper fleet (4 device classes)
        sim = Scenario(dynamics=MarkovChurnDynamics(
            p_off=[0.1, 0.2, 0.3, 0.4],
            p_on=0.5), **BASE).build()
        dev = sim.fleet_spec.device_ids
        expected = np.asarray([0.1, 0.2, 0.3, 0.4])[dev]
        np.testing.assert_array_equal(sim.state.dyn["p_off"], expected)
        assert sim.state.dyn["p_off"].shape == (BASE["n_users"],)

    def test_wrong_length_class_vector_rejected(self):
        with pytest.raises(ValueError, match="per-device-class"):
            Scenario(dynamics=MarkovChurnDynamics(p_off=[0.1, 0.2]),
                     **BASE).build()

    def test_none_is_inactive_and_stateless(self):
        sim = Scenario(dynamics="none", **BASE).build()
        assert sim.state.dyn is None
        assert not sim.dynamics.active
