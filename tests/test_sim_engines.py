"""Vectorized SoA engine vs. the reference loop engine (the oracle).

The loop engine (``FederatedSim._run_loop``) is the ground truth for the
Sec. VII.B evaluation; these tests pin the batched engines to it: identical
decision sequences / update counts / push logs, energies within float-sum
reordering, plus scalar-vs-batch property checks for the primitives the
vectorized engine leans on (Lemma 1 bounds, Eq. 4 gaps, the batched
Lyapunov argmin)."""
import numpy as np
import pytest

from repro.core.lyapunov import OnlineScheduler, UserSlotState
from repro.core.offline import lemma1_lag_bounds, lemma1_lag_bounds_loop
from repro.core.simulator import POLICIES, FederatedSim, SimConfig
from repro.core.staleness import gradient_gap, momentum_scale


def run(policy, engine, **kw):
    kw.setdefault("horizon_s", 2000)
    kw.setdefault("n_users", 12)
    kw.setdefault("seed", 2)
    return FederatedSim(SimConfig(policy=policy, engine=engine, **kw)).run()


def assert_equivalent(a, b, energy_rtol=1e-9, push_log=True):
    assert a.updates == b.updates
    assert b.energy_j == pytest.approx(a.energy_j, rel=energy_rtol)
    assert b.mean_Q == pytest.approx(a.mean_Q, rel=1e-9, abs=1e-12)
    assert b.mean_H == pytest.approx(a.mean_H, rel=1e-6, abs=1e-9)
    assert b.corun_fraction == pytest.approx(a.corun_fraction)
    np.testing.assert_array_equal(a.trace_t, b.trace_t)
    np.testing.assert_allclose(b.trace_energy, a.trace_energy,
                               rtol=energy_rtol)
    np.testing.assert_allclose(b.trace_Q, a.trace_Q, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(b.trace_H, a.trace_H, rtol=1e-6, atol=1e-9)
    if push_log:
        assert [(e["t"], e["user"], e["lag"], e["corun"])
                for e in a.push_log] == \
               [(e["t"], e["user"], e["lag"], e["corun"])
                for e in b.push_log]
        np.testing.assert_allclose([e["gap"] for e in b.push_log],
                                   [e["gap"] for e in a.push_log],
                                   rtol=1e-9, atol=1e-15)


class TestLoopVsVectorized:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_seeded_parity(self, policy):
        a = run(policy, "loop")
        b = run(policy, "vectorized")
        assert_equivalent(a, b)

    @pytest.mark.parametrize("policy", POLICIES)
    def test_parity_other_seed_and_arrivals(self, policy):
        kw = dict(seed=7, app_arrival_p=0.01, horizon_s=1500, n_users=16)
        assert_equivalent(run(policy, "loop", **kw),
                          run(policy, "vectorized", **kw))

    def test_parity_with_staleness_pressure(self):
        """Tight L_b keeps H > 0, exercising the sequential in-slot lag
        coupling path of decide_batch."""
        kw = dict(L_b=2.0, V=2000.0, app_arrival_p=0.01, horizon_s=3000,
                  n_users=16)
        a = run("online", "loop", **kw)
        b = run("online", "vectorized", **kw)
        assert a.mean_H > 0          # the test must actually hit that path
        assert_equivalent(a, b)

    def test_parity_with_scheduler_overhead(self):
        kw = dict(include_scheduler_overhead=True)
        assert_equivalent(run("online", "loop", **kw),
                          run("online", "vectorized", **kw))

    @pytest.mark.parametrize("policy", POLICIES)
    def test_parity_with_subsecond_slots(self, policy):
        """t_d < 1 means more slots than seconds; the arrival schedule
        must cover all T slots on every engine."""
        kw = dict(t_d=0.5, horizon_s=600, n_users=8, app_arrival_p=0.01)
        assert_equivalent(run(policy, "loop", **kw),
                          run(policy, "vectorized", **kw))

    def test_parity_at_scale(self):
        """Acceptance shape: n_users=400, online, trace mode."""
        kw = dict(n_users=400, horizon_s=400, seed=0)
        assert_equivalent(run("online", "loop", **kw),
                          run("online", "vectorized", **kw))

    def test_auto_selects_vectorized_for_trace(self):
        sim = FederatedSim(SimConfig(policy="online"))
        assert sim.resolve_engine() == "vectorized"
        sim = FederatedSim(SimConfig(policy="online", ml_mode="real"))
        assert sim.resolve_engine() == "loop"

    def test_vectorized_rejects_real_ml(self):
        cfg = SimConfig(policy="online", ml_mode="real", engine="vectorized")
        with pytest.raises(ValueError):
            FederatedSim(cfg).run()

    def test_push_log_opt_out(self):
        r = run("online", "vectorized", collect_push_log=False)
        assert r.push_log == [] and r.updates > 0


class TestJaxBackend:
    @pytest.fixture(autouse=True)
    def _x64(self):
        """f64 matches the loop engine's float semantics; f32 is a
        documented approximation."""
        import jax
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        yield
        jax.config.update("jax_enable_x64", prev)

    @pytest.mark.parametrize("policy",
                             ("sync", "immediate", "online", "offline",
                              "greedy"))
    def test_seeded_parity(self, policy):
        a = run(policy, "loop", collect_push_log=False)
        b = run(policy, "jax", collect_push_log=False)
        # energies via jnp pairwise sums
        assert_equivalent(a, b, energy_rtol=1e-9, push_log=False)
        assert b.push_log == []

    @pytest.mark.parametrize("policy",
                             ("sync", "immediate", "online", "offline",
                              "greedy"))
    def test_push_log_streams_out_of_scan(self, policy):
        """collect_push_log=True on engine='jax' (regression: it used to
        warn and return an empty log): the streamed event buffer must
        reproduce the loop oracle's push log exactly — every engine, same
        events."""
        import warnings
        a = run(policy, "loop")
        with warnings.catch_warnings():
            warnings.simplefilter("error")        # the old path warned
            b = run(policy, "jax")
        assert len(b.push_log) == len(a.push_log) > 0
        assert_equivalent(a, b)

    def test_push_log_identical_across_all_three_engines(self):
        """The satellite regression pin: one seeded online run, three
        engines, byte-identical push events."""
        a, b, c = (run("online", e, app_arrival_p=0.01, horizon_s=1500,
                       n_users=16, seed=7, V=2000.0, L_b=2.0) for e in
                   ("loop", "vectorized", "jax"))
        key = [(e["t"], e["user"], e["lag"], e["corun"]) for e in a.push_log]
        assert len(key) > 0
        assert [(e["t"], e["user"], e["lag"], e["corun"])
                for e in b.push_log] == key
        assert [(e["t"], e["user"], e["lag"], e["corun"])
                for e in c.push_log] == key
        np.testing.assert_allclose([e["gap"] for e in c.push_log],
                                   [e["gap"] for e in a.push_log],
                                   rtol=1e-9, atol=1e-15)

    def test_push_log_chunk_and_overflow_invariance(self):
        """The streamed log must not depend on scan chunking or on the
        initial event-buffer capacity (overflow doubles + retries)."""
        base = run("immediate", "loop")
        tiny = run("immediate", "jax", jax_chunk=64, push_log_capacity=2)
        assert_equivalent(base, tiny)

    def test_parity_with_staleness_pressure(self):
        kw = dict(L_b=2.0, V=2000.0, app_arrival_p=0.01, horizon_s=2000,
                  n_users=16)
        a = run("online", "loop", **kw)
        b = run("online", "jax", **kw)
        assert a.mean_H > 0
        assert_equivalent(a, b)

    def test_offline_runs_on_jax(self):
        """The offline knapsack plans through a host callback at window
        slots: engine='jax' resolves to jax (it used to degrade to the
        numpy engine) and matches the oracle, push log included."""
        sim = FederatedSim(SimConfig(policy="offline", engine="jax",
                                     horizon_s=2000, n_users=12, seed=2))
        assert sim.resolve_engine() == "jax"
        a = run("offline", "loop")
        b = run("offline", "jax")
        assert a.updates > 0
        assert_equivalent(a, b)

    def test_single_core_hosts_get_second_callback_device(self):
        """On one-core hosts jax's pure_callback deadlocks: its operand
        device_put waits on the CPU device whose only thread is parked in
        the custom call waiting for the callback (hangs the offline
        policy's plan_window callback from n_users~100 up). policies.py
        must pre-set --xla_force_host_platform_device_count=2 there, and
        must leave XLA_FLAGS alone on multi-core hosts."""
        import os
        import subprocess
        import sys

        import repro.core.policies as pol
        src = os.path.dirname(os.path.dirname(os.path.dirname(pol.__file__)))
        code = ("import os; os.cpu_count = lambda: {n}; "
                "os.environ.pop('XLA_FLAGS', None); "
                "import repro.core.policies; "
                "print(os.environ.get('XLA_FLAGS', ''))")

        def probe(n):
            out = subprocess.run(
                [sys.executable, "-c", code.format(n=n)],
                env={**os.environ, "PYTHONPATH": src},
                capture_output=True, text=True, timeout=120)
            assert out.returncode == 0, out.stderr
            return out.stdout

        assert "xla_force_host_platform_device_count=2" in probe(1)
        assert "xla_force_host_platform_device_count" not in probe(4)

    def test_v_norm_hook_falls_back_to_numpy(self):
        """A Python v_norm callback can't run inside lax.scan; jax must
        degrade to the numpy engine (which honors it), not silently
        ignore the hook."""
        hooks = {"v_norm": lambda: 5.0}
        kw = dict(policy="online", L_b=2.0, V=2000.0, horizon_s=800,
                  n_users=12, seed=2, app_arrival_p=0.01)
        a = FederatedSim(SimConfig(engine="loop", **kw), ml_hooks=hooks)
        b = FederatedSim(SimConfig(engine="jax", **kw), ml_hooks=hooks)
        assert b.resolve_engine() == "vectorized"
        assert_equivalent(a.run(), b.run())


class TestBatchedPrimitives:
    def test_lemma1_matches_loop_form(self, rng):
        for n in (1, 2, 7, 40, 200):
            t = rng.uniform(0, 1000, n)
            ta = t + rng.uniform(0, 300, n)
            d = rng.uniform(1, 400, n)
            np.testing.assert_array_equal(
                lemma1_lag_bounds(t, ta, d),
                lemma1_lag_bounds_loop(t, ta, d))

    def test_lemma1_blocked_matches_unblocked(self, rng):
        n = 97
        t = rng.uniform(0, 500, n)
        ta = t + rng.uniform(0, 100, n)
        d = rng.uniform(1, 300, n)
        np.testing.assert_array_equal(
            lemma1_lag_bounds(t, ta, d, block=16),
            lemma1_lag_bounds(t, ta, d))

    def test_gradient_gap_batched_matches_scalar(self, rng):
        lags = rng.integers(0, 50, 64)
        for beta in (0.0, 0.5, 0.9):
            batch = gradient_gap(1.7, lags, 0.01, beta)
            scal = [gradient_gap(1.7, int(l), 0.01, beta) for l in lags]
            np.testing.assert_array_equal(batch, scal)
        # array v_norm broadcasting
        vns = rng.uniform(0, 2, 64)
        np.testing.assert_array_equal(
            gradient_gap(vns, lags, 0.01, 0.9),
            [gradient_gap(v, int(l), 0.01, 0.9)
             for v, l in zip(vns, lags)])

    def test_momentum_scale_scalar_type(self):
        assert isinstance(momentum_scale(3, 0.01, 0.9), float)
        assert isinstance(momentum_scale(3, 0.01, 0.0), float)

    def test_momentum_scale_stays_jit_traceable(self):
        """Eq. (3)/(4) are used inside jitted train steps; the scalar path
        must not force a traced lag to a concrete numpy value."""
        import jax
        out = jax.jit(lambda l: momentum_scale(l, 0.01, 0.9))(3)
        assert float(out) == pytest.approx(momentum_scale(3, 0.01, 0.9))

    def test_catalog_tables_are_immutable(self):
        from repro.core.energy import catalog_tables
        tab = catalog_tables()
        with pytest.raises(ValueError):
            tab.p_train[0] = 999.0
        # gathers used by the engines still produce writable copies
        assert tab.p_train[np.array([0, 1])].flags.writeable

    @pytest.mark.parametrize("Q,H", [(0.0, 0.0), (50.0, 0.0),
                                     (3.0, 40.0), (200.0, 1e4)])
    def test_decide_batch_replays_sequential_decide(self, rng, Q, H):
        """decide_batch == repeated decide() with the in-flight lag estimate
        incremented after every scheduled user (the loop engine's exact
        in-slot semantics)."""
        k = 37
        s1 = OnlineScheduler(V=1000.0, L_b=10.0, eta=0.01, beta=0.9)
        s2 = OnlineScheduler(V=1000.0, L_b=10.0, eta=0.01, beta=0.9)
        s1.Q = s2.Q = Q
        s1.H = s2.H = H
        p_train, p_idle = 1.35, 0.689
        has_app = rng.random(k) < 0.4
        p_cor = rng.uniform(1.5, 3.0, k)
        p_app = rng.uniform(0.5, 2.0, k)
        idle_gap = rng.uniform(0, 2.0, k)
        p_s = np.where(has_app, p_cor, p_train)
        p_i = np.where(has_app, p_app, p_idle)
        lag_base, vn = 3, 0.8

        in_flight = lag_base
        seq = []
        gaps = []
        for i in range(k):
            st = UserSlotState(p_corun=p_cor[i], p_app=p_app[i],
                               p_train=p_train, p_idle=p_idle,
                               app_running=bool(has_app[i]),
                               lag_estimate=in_flight,
                               idle_gap=idle_gap[i])
            d = s1.decide(st, vn)
            seq.append(d.schedule)
            gaps.append(d.gap)
            in_flight += d.schedule

        b = s2.decide_batch(p_s, p_i, idle_gap, lag_base, vn)
        np.testing.assert_array_equal(b.schedule, seq)
        np.testing.assert_allclose(b.gaps, gaps, rtol=1e-12, atol=1e-15)
        assert b.n_served == sum(seq)
        assert b.gap_sum == pytest.approx(sum(gaps), rel=1e-9)

    def test_decide_batch_survives_inverted_gap_ordering(self, rng):
        """Negative eta inverts gap monotonicity; decide_batch must fall
        back to the literal sequential replay, not the threshold trick."""
        k = 25
        s1 = OnlineScheduler(V=1000.0, L_b=10.0, eta=-0.05, beta=0.9)
        s2 = OnlineScheduler(V=1000.0, L_b=10.0, eta=-0.05, beta=0.9)
        s1.Q = s2.Q = 3.0
        s1.H = s2.H = 40.0
        p_cor = rng.uniform(1.5, 3.0, k)
        p_app = rng.uniform(0.5, 2.0, k)
        idle_gap = rng.uniform(0, 2.0, k)
        in_flight = 2
        seq = []
        for i in range(k):
            st = UserSlotState(p_corun=p_cor[i], p_app=p_app[i],
                               p_train=1.35, p_idle=0.689,
                               app_running=True, lag_estimate=in_flight,
                               idle_gap=idle_gap[i])
            d = s1.decide(st, 0.8)
            seq.append(d.schedule)
            in_flight += d.schedule
        b = s2.decide_batch(p_cor, p_app, idle_gap, 2, 0.8)
        np.testing.assert_array_equal(b.schedule, seq)


class TestConfigValidation:
    def test_rejects_unknown_policy_at_construction(self):
        with pytest.raises(ValueError, match="policy"):
            SimConfig(policy="bogus")

    def test_rejects_unknown_engine(self):
        with pytest.raises(ValueError, match="engine"):
            SimConfig(engine="cuda")

    @pytest.mark.parametrize("kw", [dict(t_d=0.0), dict(t_d=-1.0),
                                    dict(horizon_s=0), dict(horizon_s=-5),
                                    dict(n_users=0), dict(beta=1.0),
                                    dict(app_arrival_p=1.5),
                                    dict(trace_every=0),
                                    dict(offline_window=0.0),
                                    dict(eta=-0.01), dict(v_norm0=-1.0),
                                    dict(ml_mode="dream")])
    def test_rejects_bad_numerics(self, kw):
        with pytest.raises(ValueError):
            SimConfig(**kw)

    def test_zero_slot_horizon_guarded(self):
        """horizon < t_d -> T == 0; means must not divide by zero."""
        for engine in ("loop", "vectorized"):
            r = FederatedSim(SimConfig(policy="online", horizon_s=1,
                                       t_d=2.0, engine=engine)).run()
            assert r.updates == 0
            assert r.mean_Q == 0.0 and r.mean_H == 0.0
            assert r.corun_fraction == 0.0
