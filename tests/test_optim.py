"""Optimizers + the paper's fused momentum/gap update (optim.gap)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.gap import (delay_compensate, fused_momentum_gap_update,
                             gap_aware_scale)
from repro.optim.optimizers import (adamw, apply_updates,
                                    clip_by_global_norm, global_norm,
                                    momentum_sgd)


class TestMomentumSGD:
    def test_matches_closed_form(self):
        """Eq. (1): v = b v + (1-b) g ; theta -= lr v."""
        init, update = momentum_sgd(lr=0.1, beta=0.9)
        p = {"w": jnp.array([1.0, 2.0])}
        st = init(p)
        g = {"w": jnp.array([1.0, -1.0])}
        up, st = update(g, st, p)
        np.testing.assert_allclose(np.asarray(up["w"]),
                                   -0.1 * 0.1 * np.asarray(g["w"]))
        up, st = update(g, st, p)
        v2 = 0.9 * 0.1 + 0.1 * 1.0
        np.testing.assert_allclose(np.asarray(up["w"])[0], -0.1 * v2,
                                   rtol=1e-6)

    def test_apply_updates_dtype_preserved(self):
        p = {"w": jnp.zeros(3, jnp.bfloat16)}
        out = apply_updates(p, {"w": jnp.ones(3)})
        assert out["w"].dtype == jnp.bfloat16


class TestAdamW:
    def test_descends_quadratic(self):
        init, update = adamw(lr=0.1, weight_decay=0.0)
        p = {"w": jnp.array([5.0, -3.0])}
        st = init(p)
        for _ in range(100):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            up, st = update(g, st, p)
            p = apply_updates(p, up)
        assert float(jnp.abs(p["w"]).max()) < 0.5

    def test_weight_decay_pulls_to_zero(self):
        init, update = adamw(lr=0.1, weight_decay=0.5)
        p = {"w": jnp.array([10.0])}
        st = init(p)
        g = {"w": jnp.array([0.0])}
        for _ in range(50):
            up, st = update(g, st, p)
            p = apply_updates(p, up)
        assert float(jnp.abs(p["w"]).max()) < 2.0


class TestClip:
    def test_clip_by_global_norm(self):
        t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        clipped, n = clip_by_global_norm(t, 1.0)
        assert float(n) == pytest.approx(5.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_no_clip_below_threshold(self):
        t = {"a": jnp.array([0.3])}
        clipped, _ = clip_by_global_norm(t, 1.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]), 0.3)


class TestFusedGapUpdate:
    def test_equals_three_pass_reference(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        p = {"w": jax.random.normal(ks[0], (32, 8)),
             "b": jax.random.normal(ks[1], (8,))}
        v = {"w": jax.random.normal(ks[2], (32, 8)),
             "b": jax.random.normal(ks[3], (8,))}
        g = {"w": jax.random.normal(ks[4], (32, 8)),
             "b": jax.random.normal(ks[5], (8,))}
        eta, beta, lag = 0.01, 0.9, 4
        p2, v2, gap = fused_momentum_gap_update(p, v, g, eta=eta, beta=beta,
                                                lag=jnp.int32(lag))
        # three separate passes
        v_ref = jax.tree.map(lambda a, b_: beta * a + (1 - beta) * b_, v, g)
        p_ref = jax.tree.map(lambda a, b_: a - eta * b_, p, v_ref)
        from repro.core.staleness import gradient_gap, tree_l2_norm
        gap_ref = gradient_gap(tree_l2_norm(v_ref), lag, eta, beta)
        for a, b_ in zip(jax.tree.leaves(p2), jax.tree.leaves(p_ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-6)
        assert float(gap) == pytest.approx(gap_ref, rel=1e-5)

    def test_zero_lag_zero_gap(self):
        p = {"w": jnp.ones(4)}
        _, _, gap = fused_momentum_gap_update(p, p, p, eta=0.1, beta=0.9,
                                              lag=jnp.int32(0))
        assert float(gap) == pytest.approx(0.0)


class TestStalenessMitigation:
    def test_gap_aware_scale(self):
        assert float(gap_aware_scale(jnp.float32(0.0), jnp.float32(1.0))) \
            == pytest.approx(1.0)
        assert float(gap_aware_scale(jnp.float32(3.0), jnp.float32(1.0))) \
            == pytest.approx(0.25)

    def test_delay_compensation_direction(self):
        """DC-ASGD: g_dc = g + l * g*g*(now - then)."""
        g = {"w": jnp.array([2.0])}
        now = {"w": jnp.array([1.0])}
        then = {"w": jnp.array([0.5])}
        out = delay_compensate(g, now, then, lambda_dc=0.5)
        assert float(out["w"][0]) == pytest.approx(2.0 + 0.5 * 4.0 * 0.5)
