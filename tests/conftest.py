"""Shared test configuration. NOTE: no XLA_FLAGS here — smoke tests and
benches must see the host's real (single) device; only launch/dryrun.py
sets the 512-placeholder-device flag, in its own process."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
