"""Shared test configuration. NOTE: no XLA_FLAGS here — smoke tests and
benches must see the host's real (single) device; only launch/dryrun.py
sets the 512-placeholder-device flag, in its own process."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# The property-based tests want hypothesis (see requirements-dev.txt); when
# it is not installed, fall back to a tiny deterministic stub so those
# modules still collect and exercise their assertions.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
