"""The first-class aggregation layer (core/aggregation.py): registry,
rule semantics, hypothesis properties of the weight functions, server
delegation, SimConfig validation, and the weighted real-ML fused push
scan.

Properties held for every registered rule: the applied weight is a valid
mixing weight in ``[0, 1]`` over the whole (lag, v_norm) domain, and
``fedasync_poly`` is monotone non-increasing in lag (staler pushes never
count MORE). Uses the real ``hypothesis`` when installed
(requirements-dev.txt); otherwise conftest.py installs the deterministic
stub so these still collect and run boundary + sampled cases.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (AggregationRule, FedAsyncPolyRule, GapAwareRule,
                        HeteroAwareRule, PaperFleet, ReplaceRule, Scenario,
                        SimConfig, FederatedSim, gradient_gap,
                        register_aggregation, registered_aggregations,
                        resolve_aggregation)
from repro.core.aggregation import aggregation_support, hetero_scales
from repro.core.server import AsyncParameterServer

COMMON = dict(deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
ALL_RULES = ("replace", "fedasync_poly", "gap_aware", "hetero_aware")


def paper_spec(n=8, seed=0):
    return PaperFleet().build(np.random.default_rng(seed), n)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_shipped_rules_registered(self):
        assert set(ALL_RULES) <= set(registered_aggregations())

    def test_resolve_roundtrip_singleton(self):
        a = resolve_aggregation("fedasync_poly")
        assert a is resolve_aggregation("fedasync_poly")
        assert resolve_aggregation(a) is a
        assert a.name == "fedasync_poly"

    def test_resolve_unknown_raises(self):
        with pytest.raises(ValueError, match="aggregation"):
            resolve_aggregation("krum")
        with pytest.raises(ValueError, match="aggregation"):
            resolve_aggregation(7)

    def test_custom_registration(self):
        @register_aggregation
        class _Half(AggregationRule):
            name = "half-test"
            supports_jax = False

            def weight(self, lag, gap, v_norm, fleet=None, users=None):
                return 0.5 * np.ones(np.shape(lag))

        try:
            assert "half-test" in registered_aggregations()
            r = FederatedSim(SimConfig(policy="immediate", n_users=4,
                                       horizon_s=400, app_arrival_p=0.01,
                                       aggregation="half-test",
                                       seed=0)).run()
            assert r.updates > 0
            assert all(e["weight"] == 0.5 for e in r.push_log)
            # no traced hook: a jax request with a push log degrades to
            # the numpy engine instead of mis-filling the weight column
            sim = FederatedSim(SimConfig(policy="immediate", n_users=4,
                                         horizon_s=400, engine="jax",
                                         aggregation="half-test"))
            assert sim.resolve_engine() == "vectorized"
            sim2 = FederatedSim(SimConfig(policy="immediate", n_users=4,
                                          horizon_s=400, engine="jax",
                                          collect_push_log=False,
                                          aggregation="half-test"))
            assert sim2.resolve_engine() == "jax"
        finally:
            from repro.core import aggregation as _a
            _a._REGISTRY.pop("half-test", None)
            _a._INSTANCES.pop("half-test", None)

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="alpha"):
            FedAsyncPolyRule(alpha=1.5)
        with pytest.raises(ValueError, match="non-negative"):
            FedAsyncPolyRule(a=-0.1)
        with pytest.raises(ValueError, match="gap_ref"):
            GapAwareRule(gap_ref=0.0)
        with pytest.raises(ValueError, match="non-negative"):
            HeteroAwareRule(a=-1.0)

    def test_simconfig_rejects_unknown_rule(self):
        with pytest.raises(ValueError, match="aggregation"):
            SimConfig(aggregation="krum")

    def test_simconfig_rejects_flag_without_hook(self):
        class _Liar(AggregationRule):
            name = "liar-agg-test"

            def weight(self, lag, gap, v_norm, fleet=None, users=None):
                return 1.0

        with pytest.raises(ValueError, match="scan_weight"):
            SimConfig(aggregation=_Liar())

    def test_simconfig_rejects_rule_without_host_path(self):
        class _NoHost(AggregationRule):
            name = "nohost-agg-test"
            supports_jax = False

        with pytest.raises(ValueError, match="weight"):
            SimConfig(aggregation=_NoHost())

    def test_fused_finish_shares_executable_across_knob_instances(self):
        """The fused train+push program is memoized on jax_cache_key:
        fresh knob-configured instances of operand-driven rules (knobs
        ride the traced agg_ops) reuse ONE compiled executable instead
        of retracing the most expensive jit in the repo; ad-hoc
        instance-keyed rules never share."""
        from repro.core.realml import _finish_chunk_fn
        from repro.models.lenet import lenet_loss
        a = _finish_chunk_fn(FedAsyncPolyRule(0.6, 0.5), 0.01, 0.9,
                             True, True, lenet_loss, "reference")
        b = _finish_chunk_fn(FedAsyncPolyRule(0.9, 1.0), 0.01, 0.9,
                             True, True, lenet_loss, "reference")
        assert a is b
        c = _finish_chunk_fn(GapAwareRule(1.0), 0.01, 0.9, True, True,
                             lenet_loss, "reference")
        assert c is not a
        # a different model or kernel mode is a different executable
        from repro.models.mlp import mlp_loss
        d = _finish_chunk_fn(FedAsyncPolyRule(0.6, 0.5), 0.01, 0.9,
                             True, True, mlp_loss, "reference")
        assert d is not a
        e = _finish_chunk_fn(FedAsyncPolyRule(0.6, 0.5), 0.01, 0.9,
                             True, True, lenet_loss, "pallas")
        assert e is not a
        # cache keys follow the policy convention: class-keyed only when
        # provably safe (paramless, or knobs declared via scan_operands)
        assert FedAsyncPolyRule(0.6, 0.5).jax_cache_key() is \
            FedAsyncPolyRule(0.9, 1.0).jax_cache_key()

        class _AdHoc(AggregationRule):
            name = "adhoc-key-test"
            supports_jax = False

            def __init__(self, k):
                self.k = k

            def weight(self, lag, gap, v_norm, fleet=None, users=None):
                return self.k

        x, y = _AdHoc(0.5), _AdHoc(0.5)
        assert x.jax_cache_key() is x and y.jax_cache_key() is y


# ---------------------------------------------------------------------------
# Hypothesis properties of the weight functions
# ---------------------------------------------------------------------------
class TestWeightProperties:
    @settings(max_examples=60, **COMMON)
    @given(rule=st.sampled_from(ALL_RULES), lag=st.integers(0, 100000),
           v_norm=st.floats(0.0, 1e6), eta=st.floats(0.0, 1.0),
           beta=st.floats(0.0, 0.99), uid=st.integers(0, 7))
    def test_weight_bounded_in_unit_interval(self, rule, lag, v_norm, eta,
                                             beta, uid):
        r = resolve_aggregation(rule)
        gap = gradient_gap(v_norm, lag, eta, beta)
        w = r.weight(lag, gap, v_norm, fleet=paper_spec(), users=uid)
        assert 0.0 <= float(w) <= 1.0

    @settings(max_examples=40, **COMMON)
    @given(alpha=st.floats(0.0, 1.0), a=st.floats(0.0, 4.0),
           lag=st.integers(0, 10000), step=st.integers(1, 1000))
    def test_fedasync_poly_monotone_nonincreasing_in_lag(self, alpha, a,
                                                         lag, step):
        r = FedAsyncPolyRule(alpha=alpha, a=a)
        w0 = float(r.weight(lag, 0.0, 0.0))
        w1 = float(r.weight(lag + step, 0.0, 0.0))
        assert w1 <= w0
        assert w0 <= alpha        # lag 0 caps the whole curve

    @settings(max_examples=40, **COMMON)
    @given(gap=st.floats(0.0, 1e9), ref=st.floats(1e-6, 1e3))
    def test_gap_aware_shrinks_with_gap(self, gap, ref):
        r = GapAwareRule(gap_ref=ref)
        w = float(r.weight(0, gap, 1.0))
        assert 0.0 < w <= 1.0
        assert float(r.weight(0, 2.0 * gap, 1.0)) <= w

    @settings(max_examples=25, **COMMON)
    @given(lags=st.lists(st.integers(0, 500), min_size=1, max_size=8),
           rule=st.sampled_from(ALL_RULES))
    def test_array_path_matches_scalar_path(self, lags, rule):
        """The numpy cohort path (vectorized engine) must equal per-push
        scalar evaluation (the loop server) element-wise, bit for bit."""
        r = resolve_aggregation(rule)
        fleet = paper_spec()
        lags_a = np.asarray(lags)
        users = np.arange(len(lags)) % 8
        gaps = gradient_gap(1.3, lags_a, 0.01, 0.9)
        batch = np.asarray(r.weight(lags_a, gaps, 1.3, fleet=fleet,
                                    users=users), dtype=float)
        scal = [float(r.weight(int(l), float(g), 1.3, fleet=fleet,
                               users=int(u)))
                for l, g, u in zip(lags_a, gaps, users)]
        np.testing.assert_array_equal(batch, scal)


# ---------------------------------------------------------------------------
# hetero_aware fleet conditioning
# ---------------------------------------------------------------------------
class TestHeteroAware:
    def test_scales_favor_fast_devices(self):
        spec = paper_spec(8)
        sc = hetero_scales(spec)
        assert sc.max() == 1.0 and np.all((sc > 0.0) & (sc <= 1.0))
        tt = np.asarray(spec.tables.t_train)
        assert sc[np.argmin(tt)] == 1.0          # fastest class at 1.0
        # strictly slower class -> strictly smaller scale
        assert sc[np.argmax(tt)] == pytest.approx(tt.min() / tt.max())

    def test_weight_requires_fleet(self):
        r = HeteroAwareRule()
        with pytest.raises(ValueError, match="fleet"):
            r.weight(1, 0.0, 1.0)
        with pytest.raises(ValueError, match="FleetSpec"):
            r.init_carry(4)

    def test_carry_matches_host_path_per_user(self):
        spec = paper_spec(8)
        r = HeteroAwareRule(a=0.5)
        carry = r.init_carry(8, None, spec)
        for uid in range(8):
            w_host = float(r.weight(3, 0.0, 1.0, fleet=spec, users=uid))
            assert w_host == pytest.approx(
                float(carry["scale"][uid]) * 4.0 ** -0.5)

    def test_same_lag_fast_device_outweighs_slow(self):
        spec = paper_spec(8)
        tt = np.asarray(spec.tables.t_train)[spec.device_ids]
        fast, slow = int(np.argmin(tt)), int(np.argmax(tt))
        r = HeteroAwareRule()
        assert float(r.weight(2, 0.0, 1.0, fleet=spec, users=fast)) > \
            float(r.weight(2, 0.0, 1.0, fleet=spec, users=slow))


# ---------------------------------------------------------------------------
# Server delegation (the if/elif ladder is gone; the rule decides)
# ---------------------------------------------------------------------------
class TestServerDelegation:
    def _params(self, v=0.0):
        return {"w": jnp.full((4,), v)}

    def test_server_resolves_rule_and_exposes_name(self):
        s = AsyncParameterServer(self._params(), eta=0.1, beta=0.9,
                                 aggregation="fedasync_poly")
        assert isinstance(s.rule, FedAsyncPolyRule)
        assert s.aggregation == "fedasync_poly"   # compat spelling

    def test_server_accepts_rule_instance(self):
        rule = FedAsyncPolyRule(alpha=0.4, a=1.0)
        s = AsyncParameterServer(self._params(0.0), eta=0.1, beta=0.9,
                                 aggregation=rule)
        assert s.rule is rule
        s.pull("a")
        s.pull("b")
        s.push("b", self._params(1.0))
        res = s.push("a", self._params(1.0))
        assert res.lag == 1
        assert res.applied_weight == pytest.approx(0.4 * 0.5)

    def test_legacy_knob_kwargs_still_configure(self):
        s = AsyncParameterServer(self._params(), eta=0.1, beta=0.9,
                                 aggregation="fedasync_poly",
                                 fedasync_alpha=0.3, fedasync_a=1.0)
        assert s.rule.alpha == 0.3 and s.rule.a == 1.0
        s2 = AsyncParameterServer(self._params(), eta=0.1, beta=0.9,
                                  aggregation="gap_aware", gap_ref=2.0)
        assert s2.rule.gap_ref == 2.0

    def test_gap_computed_once_at_arrival(self):
        """PushResult.gap_estimate is the Eq. (4) gap at push ARRIVAL —
        the same pre-push value the rule's weight was derived from and
        the loop oracle's push log records."""
        s = AsyncParameterServer(self._params(0.0), eta=0.1, beta=0.9)
        s.pull("a")
        s.push("a", self._params(1.0))     # v_norm now > 0
        vn_before = s.v_norm
        s.pull("b")
        s.pull("c")
        s.push("c", self._params(2.0))
        vn_mid = s.v_norm
        res = s.push("b", self._params(3.0))
        assert res.gap_estimate == pytest.approx(
            gradient_gap(vn_mid, res.lag, 0.1, 0.9))
        assert vn_mid != pytest.approx(vn_before)

    def test_hetero_server_needs_bound_fleet(self):
        s = AsyncParameterServer(self._params(), eta=0.1, beta=0.9,
                                 aggregation="hetero_aware")
        s.pull(0)
        with pytest.raises(ValueError, match="fleet"):
            s.push(0, self._params(1.0))
        spec = paper_spec(4)
        s2 = AsyncParameterServer(self._params(), eta=0.1, beta=0.9,
                                  aggregation="hetero_aware", fleet=spec)
        s2.pull(0)
        res = s2.push(0, self._params(1.0))
        assert 0.0 < res.applied_weight <= 1.0


# ---------------------------------------------------------------------------
# Real mode: the weighted mix runs INSIDE the fused push scan
# ---------------------------------------------------------------------------
class TestRealModeWeighted:
    SIM = dict(n_users=4, horizon_s=900, app_arrival_p=0.004, seed=0,
               ml_mode="real", V=5.0)
    ML = dict(n_train=256, n_test=128, seed=0, eval_every=300)

    def _run(self, engine, agg, forbid_generic=False):
        from repro.core.realml import LeNetBackend
        backend = LeNetBackend(self.SIM["n_users"], sync=False,
                               aggregation=agg, **self.ML)
        if forbid_generic:
            def _boom(*a, **k):
                raise AssertionError(
                    "fused finish fell back to per-push host round-trips")
            backend.push_batch = _boom
        cfg = SimConfig(policy="online", engine=engine, aggregation=agg,
                        **self.SIM)
        return FederatedSim(cfg, ml_backend=backend).run()

    @pytest.mark.parametrize("agg", ("fedasync_poly", "hetero_aware"))
    def test_fused_weighted_parity_vs_loop(self, agg):
        """Weighted rules run fused (the generic per-push path is
        forbidden on the vectorized run) and reproduce the loop oracle's
        schedule exactly, weights/accuracy within float tolerance."""
        a = self._run("loop", agg)
        b = self._run("vectorized", agg, forbid_generic=True)
        assert a.updates == b.updates > 0
        assert [(e["t"], e["user"], e["lag"], e["corun"])
                for e in a.push_log] == \
               [(e["t"], e["user"], e["lag"], e["corun"])
                for e in b.push_log]
        np.testing.assert_allclose([e["weight"] for e in b.push_log],
                                   [e["weight"] for e in a.push_log],
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose([x for _, x in b.accuracy],
                                   [x for _, x in a.accuracy], atol=0.03)

    def test_weighted_mix_changes_training_not_schedule(self):
        """Under the paper's H == 0 regime the schedule is weight-free,
        but the learned model differs: dampened pushes pull the global
        parameters less far."""
        a = self._run("vectorized", "replace")
        b = self._run("vectorized", "fedasync_poly", forbid_generic=True)
        assert [(e["t"], e["user"]) for e in a.push_log] == \
               [(e["t"], e["user"]) for e in b.push_log]
        assert any(e["weight"] < 1.0 for e in b.push_log)
        assert all(e["weight"] == 1.0 for e in a.push_log)

    def test_backend_config_rule_mismatch_rejected(self):
        from repro.core.realml import LeNetBackend
        backend = LeNetBackend(4, sync=False, aggregation="replace",
                               **self.ML)
        cfg = SimConfig(policy="online", aggregation="fedasync_poly",
                        **self.SIM)
        with pytest.raises(ValueError, match="aggregation"):
            FederatedSim(cfg, ml_backend=backend)

    def test_backend_config_knob_mismatch_rejected(self):
        """Same rule NAME but different knobs must be rejected too —
        otherwise the run silently uses the backend's knobs while the
        config records others."""
        from repro.core.realml import LeNetBackend
        backend = LeNetBackend(4, sync=False, aggregation="fedasync_poly",
                               **self.ML)      # default alpha=0.6, a=0.5
        cfg = SimConfig(policy="online",
                        aggregation=FedAsyncPolyRule(alpha=0.9, a=1.0),
                        **self.SIM)
        with pytest.raises(ValueError, match="agree"):
            FederatedSim(cfg, ml_backend=backend)
        # equal knobs in a fresh instance are NOT a mismatch
        backend2 = LeNetBackend(4, sync=False,
                                aggregation=FedAsyncPolyRule(0.6, 0.5),
                                **self.ML)
        cfg2 = SimConfig(policy="online", aggregation="fedasync_poly",
                         **self.SIM)
        FederatedSim(cfg2, ml_backend=backend2)   # no raise

    def test_scenario_threads_aggregation_into_backend(self):
        scn = Scenario(policy="online", ml="lenet", ml_kwargs=self.ML,
                       aggregation="gap_aware", n_users=4, horizon_s=300,
                       app_arrival_p=0.004, seed=0)
        sim = scn.build()
        assert sim.ml_backend.server.rule.name == "gap_aware"
        assert sim.agg.name == "gap_aware"
