"""Gradient compression: top-k, int8, error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.compression import (ErrorFeedback, TopK, int8_dequantize,
                                     int8_quantize, topk_compress,
                                     topk_decompress)


class TestTopK:
    @given(st.integers(1, 200), st.integers(0, 10 ** 6))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_keeps_largest(self, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        k = max(1, n // 4)
        t = topk_compress(x, k)
        y = np.asarray(topk_decompress(t))
        # kept entries match, dropped are zero
        kept = np.argsort(-np.abs(np.asarray(x)))[:k]
        np.testing.assert_allclose(y[kept], np.asarray(x)[kept], rtol=1e-6)
        mask = np.ones(n, bool)
        mask[kept] = False
        assert (y[mask] == 0).all()

    def test_2d_shape_restored(self):
        x = jnp.arange(12.0).reshape(3, 4)
        t = topk_compress(x, 5)
        assert topk_decompress(t).shape == (3, 4)


class TestInt8:
    @given(st.integers(1, 500), st.integers(0, 10 ** 6),
           st.floats(1e-3, 1e3))
    @settings(max_examples=50, deadline=None)
    def test_quantization_error_bounded(self, n, seed, scale):
        rng = np.random.default_rng(seed)
        x = jnp.asarray((scale * rng.normal(0, 1, n)).astype(np.float32))
        q, s = int8_quantize(x)
        y = int8_dequantize(q, s)
        max_err = float(jnp.max(jnp.abs(y - x)))
        assert max_err <= float(s) * 0.5 + 1e-6 + float(s)  # round + clip slack

    def test_zero_vector(self):
        q, s = int8_quantize(jnp.zeros(10))
        np.testing.assert_array_equal(np.asarray(int8_dequantize(q, s)), 0.0)


class TestErrorFeedback:
    def test_residual_makes_compression_unbiased_over_time(self):
        """Sum of decompressed updates converges to sum of true updates:
        the defining property of error feedback."""
        ef = ErrorFeedback(ratio=0.25)
        rng = np.random.default_rng(0)
        true_sum = np.zeros(64, np.float32)
        sent_sum = np.zeros(64, np.float32)
        for _ in range(50):
            g = {"w": jnp.asarray(rng.normal(0, 1, 64).astype(np.float32))}
            true_sum += np.asarray(g["w"])
            payload = ef.compress(g)
            sent_sum += np.asarray(ErrorFeedback.decompress(payload)["w"])
        # residual is bounded -> averages converge
        resid = np.abs(true_sum - sent_sum)
        assert resid.max() < 10.0   # residual stays bounded, doesn't diverge
        np.testing.assert_allclose(sent_sum + np.asarray(ef.residual["w"]),
                                   true_sum, rtol=1e-4, atol=1e-4)

    def test_full_ratio_is_lossless_stream(self):
        ef = ErrorFeedback(ratio=1.0)
        g = {"w": jnp.arange(8.0)}
        payload = ef.compress(g)
        np.testing.assert_allclose(
            np.asarray(ErrorFeedback.decompress(payload)["w"]),
            np.arange(8.0), rtol=1e-6)
