"""Gradient compression: top-k, int8, error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.compression import (ErrorFeedback, TopK, int8_dequantize,
                                     int8_quantize, topk_compress,
                                     topk_decompress)


class TestTopK:
    @given(st.integers(1, 200), st.integers(0, 10 ** 6))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_keeps_largest(self, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        k = max(1, n // 4)
        t = topk_compress(x, k)
        y = np.asarray(topk_decompress(t))
        # kept entries match, dropped are zero
        kept = np.argsort(-np.abs(np.asarray(x)))[:k]
        np.testing.assert_allclose(y[kept], np.asarray(x)[kept], rtol=1e-6)
        mask = np.ones(n, bool)
        mask[kept] = False
        assert (y[mask] == 0).all()

    def test_2d_shape_restored(self):
        x = jnp.arange(12.0).reshape(3, 4)
        t = topk_compress(x, 5)
        assert topk_decompress(t).shape == (3, 4)

    def test_k_clamped_to_size_is_lossless(self):
        """k >= x.size takes the dense path (no top_k sort) and the
        round-trip is exact."""
        x = jnp.asarray([3.0, -1.0, 0.5])
        for k in (3, 7, 10 ** 6):
            t = topk_compress(x, k)
            assert t.values.shape == (3,)
            np.testing.assert_array_equal(np.asarray(topk_decompress(t)),
                                          np.asarray(x))

    def test_k_floor_is_one(self):
        t = topk_compress(jnp.asarray([0.0, 5.0]), 0)
        assert t.values.shape == (1,)
        np.testing.assert_array_equal(np.asarray(topk_decompress(t)),
                                      [0.0, 5.0])

    def test_roundtrip_traces_under_jit(self):
        """The compress/decompress pair is jit-compatible end to end —
        the flat size is computed with math.prod on the host, never via a
        device value."""
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 8))
                        .astype(np.float32))

        @jax.jit
        def roundtrip(x_):
            return topk_decompress(topk_compress(x_, 8))

        y = np.asarray(roundtrip(x))
        y_eager = np.asarray(topk_decompress(topk_compress(x, 8)))
        np.testing.assert_array_equal(y, y_eager)
        assert (np.count_nonzero(y) <= 8)


class TestInt8:
    @given(st.integers(1, 500), st.integers(0, 10 ** 6),
           st.floats(1e-3, 1e3))
    @settings(max_examples=50, deadline=None)
    def test_quantization_error_bounded(self, n, seed, scale):
        rng = np.random.default_rng(seed)
        x = jnp.asarray((scale * rng.normal(0, 1, n)).astype(np.float32))
        q, s = int8_quantize(x)
        y = int8_dequantize(q, s)
        max_err = float(jnp.max(jnp.abs(y - x)))
        assert max_err <= float(s) * 0.5 + 1e-6 + float(s)  # round + clip slack

    def test_zero_vector(self):
        q, s = int8_quantize(jnp.zeros(10))
        np.testing.assert_array_equal(np.asarray(int8_dequantize(q, s)), 0.0)


class TestErrorFeedback:
    def test_residual_makes_compression_unbiased_over_time(self):
        """Sum of decompressed updates converges to sum of true updates:
        the defining property of error feedback."""
        ef = ErrorFeedback(ratio=0.25)
        rng = np.random.default_rng(0)
        true_sum = np.zeros(64, np.float32)
        sent_sum = np.zeros(64, np.float32)
        for _ in range(50):
            g = {"w": jnp.asarray(rng.normal(0, 1, 64).astype(np.float32))}
            true_sum += np.asarray(g["w"])
            payload = ef.compress(g)
            sent_sum += np.asarray(ErrorFeedback.decompress(payload)["w"])
        # residual is bounded -> averages converge
        resid = np.abs(true_sum - sent_sum)
        assert resid.max() < 10.0   # residual stays bounded, doesn't diverge
        np.testing.assert_allclose(sent_sum + np.asarray(ef.residual["w"]),
                                   true_sum, rtol=1e-4, atol=1e-4)

    def test_compressed_stream_converges_to_uncompressed_fixed_point(self):
        """Error-feedback accumulator property: a push stream whose
        uncompressed dynamics contract to a fixed point reaches the SAME
        fixed point when every update travels top-k compressed — the
        residual carries the dropped mass forward, so nothing is lost,
        only delayed. Plain top-k without EF stalls short of the target
        on the coordinates it keeps dropping."""
        rng = np.random.default_rng(42)
        target = rng.normal(0, 1, 128).astype(np.float32)

        # gain * (1/ratio) stays < 1: error feedback delays dropped mass
        # by ~1/ratio steps, so the contraction gain must price that
        # delay in or the accumulated residual overshoots on release
        def run_stream(compress_fn, steps=400):
            x = np.zeros(128, np.float32)
            for _ in range(steps):
                update = 0.05 * (target - x)
                x = x + compress_fn(update)
            return x

        # uncompressed: plain contraction to `target`
        x_ref = run_stream(lambda u: u)
        np.testing.assert_allclose(x_ref, target, atol=1e-5)

        # EF-compressed at 10%: same fixed point
        ef = ErrorFeedback(ratio=0.1)
        x_ef = run_stream(lambda u: np.asarray(
            ErrorFeedback.decompress(ef.compress(jnp.asarray(u)))))
        np.testing.assert_allclose(x_ef, target, atol=1e-4)

        # naive top-k (no residual): visibly worse than EF at equal ratio
        k = max(int(128 * 0.1), 1)
        x_naive = run_stream(lambda u: np.asarray(
            topk_decompress(topk_compress(jnp.asarray(u), k))))
        err_naive = np.abs(x_naive - target).max()
        err_ef = np.abs(x_ef - target).max()
        assert err_ef < err_naive

    def test_full_ratio_is_lossless_stream(self):
        ef = ErrorFeedback(ratio=1.0)
        g = {"w": jnp.arange(8.0)}
        payload = ef.compress(g)
        np.testing.assert_allclose(
            np.asarray(ErrorFeedback.decompress(payload)["w"]),
            np.arange(8.0), rtol=1e-6)
