"""Offline knapsack scheduler (Sec. IV, Alg. 1, Lemma 1)."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.offline import (knapsack_schedule, lemma1_lag_bounds,
                                offline_schedule)


def brute_force(savings, gaps, L_b):
    n = len(savings)
    best, best_x = 0.0, np.zeros(n, bool)
    for bits in itertools.product([0, 1], repeat=n):
        x = np.array(bits, bool)
        if gaps[x].sum() <= L_b + 1e-12:
            v = savings[x].sum()
            if v > best:
                best, best_x = v, x
    return best, best_x


class TestKnapsack:
    @given(st.integers(1, 10), st.integers(0, 10 ** 6))
    @settings(max_examples=60, deadline=None)
    def test_matches_brute_force(self, n, seed):
        rng = np.random.default_rng(seed)
        savings = rng.uniform(-1, 5, n)
        gaps = rng.integers(0, 6, n).astype(float)   # integral weights: DP exact
        L_b = float(rng.integers(0, 12))
        x, total = knapsack_schedule(savings, gaps, L_b, resolution=1.0)
        best, _ = brute_force(savings, gaps, L_b)
        assert total == pytest.approx(best, rel=1e-9, abs=1e-9)
        # the decision is feasible and consistent with its claimed value
        assert gaps[x].sum() <= L_b + 1e-9
        assert savings[x].sum() == pytest.approx(total)

    @given(st.integers(1, 12), st.integers(0, 10 ** 6))
    @settings(max_examples=60, deadline=None)
    def test_budget_never_exceeded_fractional(self, n, seed):
        rng = np.random.default_rng(seed)
        savings = rng.uniform(0, 5, n)
        gaps = rng.uniform(0, 3, n)
        L_b = float(rng.uniform(0, 6))
        x, _ = knapsack_schedule(savings, gaps, L_b, resolution=0.1)
        # ceil-discretization guarantees feasibility
        assert gaps[x].sum() <= L_b + 1e-9

    def test_negative_savings_never_taken(self):
        x, total = knapsack_schedule([-1.0, 2.0], [0.5, 0.5], 10.0)
        assert not x[0] and x[1]
        assert total == pytest.approx(2.0)

    def test_zero_budget_takes_only_zero_weight(self):
        x, total = knapsack_schedule([1.0, 2.0], [0.0, 1.0], 0.0)
        assert x[0] and not x[1]
        assert total == pytest.approx(1.0)


class TestLemma1:
    @given(st.integers(2, 12), st.integers(0, 10 ** 6))
    @settings(max_examples=60, deadline=None)
    def test_upper_bounds_worst_case(self, n, seed):
        rng = np.random.default_rng(seed)
        t = rng.uniform(0, 100, n)
        ta = t + rng.uniform(0, 50, n)
        d = rng.uniform(1, 30, n)
        bounds = lemma1_lag_bounds(t, ta, d)
        assert (bounds <= n - 1).all()
        assert (bounds >= 0).all()

    def test_disjoint_windows_zero_lag(self):
        # users train in fully disjoint windows -> no overlap, zero bound
        t = np.array([0.0, 100.0, 200.0])
        ta = np.array([10.0, 110.0, 210.0])
        d = np.array([5.0, 5.0, 5.0])
        assert (lemma1_lag_bounds(t, ta, d) == 0).all()

    def test_identical_windows_max_lag(self):
        t = np.zeros(4)
        ta = np.zeros(4)
        d = np.ones(4)
        assert (lemma1_lag_bounds(t, ta, d) == 3).all()

    def test_lemma1_dominates_realized_lag(self):
        """Simulated realized lag (any decision combo) <= Lemma-1 bound."""
        rng = np.random.default_rng(3)
        n = 6
        t = rng.uniform(0, 50, n)
        ta = t + rng.uniform(0, 20, n)
        d = rng.uniform(1, 10, n)
        bounds = lemma1_lag_bounds(t, ta, d)
        for bits in itertools.product([0, 1], repeat=n):
            starts = np.where(bits, ta, t)
            ends = starts + d
            for i in range(n):
                lag_i = sum(1 for j in range(n)
                            if j != i and starts[i] <= ends[j] <= ends[i])
                assert lag_i <= bounds[i]


class TestOfflineSchedule:
    def test_end_to_end(self):
        rng = np.random.default_rng(0)
        n = 8
        t = rng.uniform(0, 100, n)
        ta = t + rng.uniform(0, 50, n)
        d = rng.uniform(10, 30, n)
        savings = rng.uniform(0, 500, n)
        x, total = offline_schedule(t, ta, d, savings, L_b=5.0,
                                    eta=0.01, beta=0.9, v_norm=1.0,
                                    resolution=0.01)
        assert total >= 0
        assert x.dtype == bool
