"""Launch layer: shapes/specs, lowering on an abstract production mesh,
train and serve drivers end-to-end (small scale)."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.launch import shapes as shp
from repro.launch.flops import model_flops
from repro.launch.mesh import make_host_mesh, make_mesh


class TestShapes:
    def test_cells_skip_long500k_for_attention(self):
        assert shp.applicable(get_config("mamba2-370m"), "long_500k")
        assert shp.applicable(get_config("zamba2-2.7b"), "long_500k")
        for arch in ("qwen3-0.6b", "internlm2-20b", "whisper-large-v3",
                     "internvl2-76b", "qwen3-moe-30b-a3b"):
            assert not shp.applicable(get_config(arch), "long_500k"), arch

    def test_cell_count_is_32(self):
        """10 archs x 4 shapes - 8 long_500k skips = 32 dry-run cells."""
        from repro.configs import ARCHS
        n = sum(1 for a in ARCHS for s in shp.SHAPES
                if shp.applicable(get_config(a), s))
        assert n == 32

    def test_batch_specs_train_microbatched(self):
        cfg = get_config("qwen3-0.6b")
        kind, kw = shp.input_specs(cfg, "train_4k")
        assert kind == "train"
        assert kw["batch"]["tokens"].shape == (8, 32, 4096)
        assert kw["batch"]["labels"].shape == (8, 32, 4096)

    def test_decode_specs_one_token(self):
        cfg = get_config("qwen3-0.6b")
        kind, kw = shp.input_specs(cfg, "decode_32k")
        assert kind == "decode"
        assert kw["batch"]["tokens"].shape == (128, 1)
        assert kw["cache"]["layers"]["k"].shape == (28, 128, 32768, 8, 128)

    def test_vlm_audio_stub_frontends(self):
        cfg = get_config("internvl2-76b")
        _, kw = shp.input_specs(cfg, "prefill_32k")
        assert kw["batch"]["vision_embeds"].shape == (32, 256, 8192)
        cfg = get_config("whisper-large-v3")
        _, kw = shp.input_specs(cfg, "train_4k")
        assert kw["batch"]["audio_embeds"].shape == (8, 32, 1500, 1280)

    def test_production_overrides(self):
        cfg, over = shp.production_config(get_config("internvl2-76b"),
                                          "train_4k")
        assert over["attention_impl"] == "chunked"
        assert over["shard_activations"] is True
        cfg, over = shp.production_config(get_config("mamba2-370m"),
                                          "train_4k")
        assert over == {}   # attention-free: nothing to override


class TestModelFlops:
    def test_train_6nd(self):
        cfg = get_config("qwen3-0.6b")
        f = model_flops(cfg, "train_4k")
        assert f == pytest.approx(6 * cfg.param_count() * 256 * 4096)

    def test_moe_uses_active(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        f = model_flops(cfg, "train_4k")
        assert f == pytest.approx(
            6 * cfg.active_param_count() * 256 * 4096)
        assert cfg.active_param_count() < 0.2 * cfg.param_count()

    def test_decode_per_token(self):
        cfg = get_config("qwen3-0.6b")
        assert model_flops(cfg, "decode_32k") == pytest.approx(
            2 * cfg.param_count() * 128)


class TestLowerSmallMesh:
    """Lower the real step functions on a tiny host mesh (1 device)."""

    def test_train_step_lowers_and_runs(self):
        from repro.launch.steps import make_train_step
        cfg = get_smoke_config("qwen3-0.6b")
        step = jax.jit(make_train_step(cfg, eta=0.01, beta=0.9,
                                       microbatches=2))
        from repro.models import build_model
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        v = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        B, S = 4, 32
        batch = {"tokens": jnp.zeros((2, B // 2, S), jnp.int32),
                 "labels": jnp.zeros((2, B // 2, S), jnp.int32)}
        p2, v2, metrics = step(params, v, batch, jnp.int32(1))
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["gap"]) >= 0
        # params actually moved
        delta = sum(float(jnp.sum(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(p2),
                                    jax.tree.leaves(params)))
        assert delta > 0

    def test_decode_step_lowers_and_runs(self):
        from repro.launch.steps import make_decode_step
        from repro.models import build_model
        cfg = get_smoke_config("zamba2_2_7b")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        cache = m.init_cache(2, 16)
        step = jax.jit(make_decode_step(cfg))
        tok, new_cache = step(params, cache,
                              {"tokens": jnp.zeros((2, 1), jnp.int32)})
        assert tok.shape == (2, 1)
        assert int(new_cache["pos"]) == 1


class TestTrainDriver:
    def test_federated_lm_end_to_end(self, tmp_path):
        from repro.launch.train import IslandConfig, run
        cfg = get_smoke_config("qwen3-0.6b")
        icfg = IslandConfig(n_islands=2, slots=120, local_steps=2,
                            batch=4, seq=32, eval_every=60,
                            ckpt_dir=str(tmp_path), ckpt_every=50,
                            app_arrival_p=0.05)
        out = run(cfg, icfg, log=lambda *a: None)
        assert out["updates"] > 0
        assert np.isfinite(out["final_loss"])
        # checkpoints written
        assert any(d.startswith("step_") for d in os.listdir(tmp_path))

    def test_compression_and_gap_aware_path(self, tmp_path):
        from repro.launch.train import IslandConfig, run
        cfg = get_smoke_config("qwen3-0.6b")
        icfg = IslandConfig(n_islands=2, slots=100, local_steps=2,
                            batch=4, seq=32, eval_every=100,
                            compress_ratio=0.1, aggregation="gap_aware",
                            app_arrival_p=0.05)
        out = run(cfg, icfg, log=lambda *a: None)
        assert out["updates"] > 0
        assert np.isfinite(out["final_loss"])


class TestServeDriver:
    @pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m",
                                      "whisper-large-v3"])
    def test_generate_shapes_and_determinism(self, arch):
        from repro.launch.serve import BatchedServer
        cfg = get_smoke_config(arch)
        srv = BatchedServer(cfg)
        prompts = np.ones((2, 8), np.int32)
        a = srv.generate(prompts, 6)
        b = srv.generate(prompts, 6)
        assert a.shape == (2, 6)
        np.testing.assert_array_equal(a, b)
        assert (a >= 0).all() and (a < cfg.vocab_size).all()
