"""Energy model vs Table II / Table III of the paper."""
import pytest

from repro.core.energy import APPS, TESTBED, table2_savings


class TestTable2:
    def test_power_state_ordering_modern_devices(self):
        """P^{a'} > P^a and P^b > P^d for the paper's Eq. (10) states on the
        big.LITTLE devices (Hikey970, Pixel2)."""
        for name in ("Hikey970", "Pixel2"):
            d = TESTBED[name]
            assert d.p_train > d.p_idle
            for a in APPS:
                assert d.apps[a].p_corun > d.apps[a].p_app

    def test_pixel2_savings_30_50pct(self):
        """Paper Observation 1: 30-50% saving on Pixel2 across apps
        (Table II saving column: 23%-35%)."""
        s = table2_savings()["Pixel2"]
        for app, v in s.items():
            assert 0.20 <= v <= 0.50, (app, v)

    def test_hikey_savings_match_paper_column(self):
        """Spot-check the printed saving(%) column: Hikey970/Map = 47%,
        Youtube = 33%, News = 43% (+-2% rounding)."""
        s = table2_savings()["Hikey970"]
        assert s["Map"] == pytest.approx(0.47, abs=0.02)
        assert s["Youtube"] == pytest.approx(0.33, abs=0.02)
        assert s["News"] == pytest.approx(0.43, abs=0.02)

    def test_nexus6_homogeneous_cores_can_regress(self):
        """Older homogeneous-core device: some apps show energy SURGE
        (negative saving) — CandyCru -39%, Youtube -4% in Table II."""
        s = table2_savings()["Nexus6"]
        assert s["CandyCru"] < 0
        assert s["Youtube"] < 0

    def test_positive_saving_rate_is_corun_benefit(self):
        """s_i = P^b + P^a - P^{a'} > 0 iff co-running is cheaper than
        separate execution at equal duration."""
        d = TESTBED["Pixel2"]
        for a in APPS:
            s = d.energy_saving_rate(a)
            sep = d.p_train + d.apps[a].p_app
            assert s == pytest.approx(sep - d.apps[a].p_corun)
            assert s > 0

    def test_eq10_power_function(self):
        d = TESTBED["Pixel2"]
        app = "Tiktok"
        assert d.power(True, True, app) == d.apps[app].p_corun
        assert d.power(True, False) == d.p_train
        assert d.power(False, True, app) == d.apps[app].p_app
        assert d.power(False, False) == d.p_idle


class TestTable3:
    def test_scheduler_overhead_below_10pct(self):
        """Table III: online-decision energy overhead < 10% of idle."""
        for name in ("Nexus6", "Nexus6P", "Pixel2"):
            d = TESTBED[name]
            overhead = (d.p_sched - d.p_idle) / d.p_idle
            assert 0 <= overhead < 0.10, (name, overhead)
