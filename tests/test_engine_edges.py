"""Engine edge cases, run across all three engines.

Degenerate shapes the slot loop must survive identically everywhere:
``T == 0`` (horizon shorter than one slot), ``n_users == 1`` (no
cross-user coupling), and a uniform fleet where every user finishes in
the same slot — the batched-dispatch worst case (cohort == fleet).
"""
import numpy as np
import pytest

from repro.core import TESTBED, CustomCatalogFleet
from repro.core.simulator import POLICIES, FederatedSim, SimConfig

ALL_ENGINES = ("loop", "vectorized", "jax")


def run(engine, policy="online", fleet=None, **kw):
    kw.setdefault("n_users", 4)
    kw.setdefault("horizon_s", 300)
    kw.setdefault("seed", 1)
    kw.setdefault("collect_push_log", False)
    cfg = SimConfig(policy=policy, engine=engine, **kw)
    return FederatedSim(cfg, fleet=fleet).run()


@pytest.fixture(autouse=True)
def _x64():
    """f64 keeps the jax engine float-comparable with the numpy ones."""
    import jax
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", prev)


class TestZeroSlots:
    @pytest.mark.parametrize("engine", ALL_ENGINES)
    def test_horizon_below_slot_length(self, engine):
        """horizon_s < t_d -> T == 0: no slots, no updates, no division
        by zero in the means."""
        r = run(engine, horizon_s=1, t_d=2.0)
        assert r.updates == 0
        assert r.energy_j == 0.0
        assert r.mean_Q == 0.0 and r.mean_H == 0.0
        assert r.corun_fraction == 0.0
        assert len(r.trace_t) == 0


class TestSingleUser:
    @pytest.mark.parametrize("engine", ALL_ENGINES)
    @pytest.mark.parametrize("policy", POLICIES)
    def test_n_users_1_runs_and_agrees(self, engine, policy):
        kw = dict(n_users=1, horizon_s=800, app_arrival_p=0.01, seed=5)
        a = run("loop", policy=policy, **kw)
        b = run(engine, policy=policy, **kw)
        assert b.updates == a.updates
        assert b.energy_j == pytest.approx(a.energy_j, rel=1e-9)
        assert b.mean_Q == pytest.approx(a.mean_Q, rel=1e-9, abs=1e-12)


class TestSameSlotCohort:
    """Uniform fleet + no apps: every user starts and finishes together,
    so one slot carries the whole fleet as a single finisher cohort."""

    @pytest.mark.parametrize("engine", ("vectorized", "jax"))
    @pytest.mark.parametrize("policy", ("immediate", "sync"))
    def test_full_cohort_matches_loop(self, engine, policy):
        fleet = CustomCatalogFleet([TESTBED["Nexus6P"]])
        kw = dict(n_users=6, horizon_s=500, app_arrival_p=0.0, seed=0)
        a = run("loop", policy=policy, fleet=fleet, **kw)
        b = run(engine, policy=policy, fleet=fleet, **kw)
        assert a.updates > 0
        assert b.updates == a.updates
        assert b.energy_j == pytest.approx(a.energy_j, rel=1e-9)
        assert b.corun_fraction == a.corun_fraction == 0.0

    def test_cohort_push_slots_coincide(self):
        fleet = CustomCatalogFleet([TESTBED["Nexus6P"]])
        cfg = SimConfig(policy="immediate", engine="vectorized", n_users=6,
                        horizon_s=500, app_arrival_p=0.0, seed=0)
        r = FederatedSim(cfg, fleet=fleet).run()
        slots = {}
        for e in r.push_log:
            slots.setdefault(e["t"], []).append(e["user"])
        # every push slot carries the full fleet, in user order
        for users in slots.values():
            assert users == list(range(6))
