"""Property-based tests for the device-dynamics layer (core/dynamics.py).

Whatever the knobs, the ``MarkovChurnDynamics`` transition must uphold
its state-machine contracts: battery trajectories stay inside
``[0, capacity]`` slot by slot, the availability chain's long-run on
fraction matches the two-state Markov stationary distribution
``p_on / (p_on + p_off)``, and mid-training dropout under either rule
never drives the engine invariants negative (``in_flight`` equals the
training population, queues stay non-negative).

Uses the real ``hypothesis`` when installed; otherwise conftest.py
installs the deterministic stub so these still collect and run
boundary + sampled cases.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import MarkovChurnDynamics, Scenario
from repro.core.engine_state import MODE_TRAIN, MODE_WAIT
from repro.core.policies import ImmediatePolicy
from repro.core.simulator import SimConfig

COMMON = dict(deadline=None,
              suppress_health_check=[HealthCheck.too_slow])


def _fresh_key(seed):
    import jax
    return np.asarray(jax.random.PRNGKey(seed), np.uint32)


def _step_chain(dyn_obj, n, T, seed, mode=None, corun=None, t_d=1.0,
                each_slot=None):
    """Drive host_step for T slots outside any engine; returns final dyn."""
    cfg = SimConfig()
    state = dyn_obj.init_state(n, cfg)
    key = _fresh_key(seed)
    mode = np.full(n, MODE_WAIT) if mode is None else mode
    corun = np.zeros(n, bool) if corun is None else corun
    for _ in range(T):
        state, key, eff = dyn_obj.host_step(state, key, mode, corun, t_d)
        if each_slot is not None:
            each_slot(state, eff)
    return state


class TestBatteryBounds:
    @settings(max_examples=12, **COMMON)
    @given(capacity=st.floats(0.2, 3.0), init_frac=st.floats(0.0, 1.0),
           drain=st.floats(0.0, 0.5), charge=st.floats(0.0, 0.5),
           t_d=st.floats(0.5, 4.0), seed=st.integers(0, 2 ** 16))
    def test_battery_stays_in_range_every_slot(self, capacity, init_frac,
                                               drain, charge, t_d, seed):
        """clip() must hold the trajectory in [0, capacity] under any
        drain/charge rates, including overshooting ones, for training
        and idle users alike."""
        n = 8
        dyn = MarkovChurnDynamics(
            p_off=0.1, p_on=0.3, battery_capacity=capacity,
            battery_init=init_frac, drain_train=drain,
            drain_corun=min(drain * 1.5, 0.5), charge_rate=charge,
            battery_min=0.0)
        mode = np.where(np.arange(n) % 2 == 0, MODE_TRAIN, MODE_WAIT)
        corun = np.arange(n) % 4 == 0

        def check(state, eff):
            b = state["battery"]
            assert np.all(b >= 0.0)
            assert np.all(b <= capacity)

        _step_chain(dyn, n, 150, seed, mode=mode, corun=corun, t_d=t_d,
                    each_slot=check)

    def test_battery_collapse_gates_participation(self):
        """Draining past battery_min turns the user down even while the
        availability chain stays on (p_off=0)."""
        dyn = MarkovChurnDynamics(
            p_off=0.0, p_on=1.0, battery_init=0.3, drain_train=0.05,
            charge_rate=0.0, battery_min=0.1)
        n = 4
        mode = np.full(n, MODE_TRAIN)
        downs = []
        _step_chain(dyn, n, 20, seed=0, mode=mode,
                    each_slot=lambda s, e: downs.append(~e.up))
        assert np.all(downs[-1])      # everyone below threshold => down
        assert not np.any(downs[0])   # but not on slot one


class TestMarkovStationary:
    @settings(max_examples=10, **COMMON)
    @given(p_off=st.floats(0.05, 0.5), p_on=st.floats(0.05, 0.5),
           seed=st.integers(0, 2 ** 16))
    def test_on_fraction_matches_stationary_distribution(self, p_off, p_on,
                                                         seed):
        """Long-run fraction of available slots ~ p_on / (p_on + p_off).
        Battery is configured inert (no drain, min 0) so availability is
        the chain alone; 200 burn-in slots wash out the all-on start."""
        n, T, burn = 64, 600, 200
        dyn = MarkovChurnDynamics(
            p_off=p_off, p_on=p_on, drain_train=0.0, drain_corun=0.0,
            charge_rate=0.0, battery_min=0.0)
        on_frac = []

        def tally(state, eff):
            on_frac.append(float(np.mean(state["on"])))

        _step_chain(dyn, n, T, seed, each_slot=tally)
        measured = float(np.mean(on_frac[burn:]))
        expected = p_on / (p_on + p_off)
        assert measured == pytest.approx(expected, abs=0.12)


class _AuditPolicy(ImmediatePolicy):
    """Immediate policy that audits engine invariants before every
    decision: in_flight tracks the training population exactly and never
    goes negative, queues stay non-negative — under churn included."""

    name = "props-audit"

    def __init__(self):
        self.violations = []

    def _audit(self, n_training, in_flight, Q, H):
        if in_flight != n_training:
            self.violations.append(
                f"in_flight {in_flight} != training {n_training}")
        if in_flight < 0:
            self.violations.append(f"in_flight {in_flight} < 0")
        if Q < 0 or H < 0:
            self.violations.append(f"negative queue Q={Q} H={H}")

    def decide_loop(self, sim, t, waiting, carry):
        n_tr = sum(u.mode == "training" for u in sim.users)
        self._audit(n_tr, sim.in_flight, sim.sched.Q, sim.sched.H)
        return super().decide_loop(sim, t, waiting, carry)

    def decide_vectorized(self, eng, t, carry):
        s = eng.s
        n_tr = int(np.count_nonzero(s.mode == MODE_TRAIN))
        self._audit(n_tr, int(s.in_flight), float(eng.sched.Q),
                    float(eng.sched.H))
        return super().decide_vectorized(eng, t, carry)


class TestDropoutInvariants:
    @settings(max_examples=10, **COMMON)
    @given(p_off=st.floats(0.02, 0.4), p_on=st.floats(0.05, 0.6),
           dropout=st.sampled_from(["lose", "resume"]),
           engine=st.sampled_from(["loop", "vectorized"]),
           seed=st.integers(0, 2 ** 16))
    def test_churn_never_corrupts_queues_or_in_flight(self, p_off, p_on,
                                                      dropout, engine,
                                                      seed):
        pol = _AuditPolicy()
        dyn = MarkovChurnDynamics(p_off=p_off, p_on=p_on, dropout=dropout,
                                  resume_penalty_s=10.0)
        r = Scenario(engine=engine, policy=pol, dynamics=dyn, n_users=8,
                     horizon_s=300, seed=seed, app_arrival_p=0.02).run()
        assert pol.violations == []
        assert r.drops >= 0
