"""Fault tolerance: heartbeats, straggler detection, elastic membership."""
import pytest

from repro.fault.monitor import (ElasticCohort, HeartbeatMonitor,
                                 StragglerDetector)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class TestHeartbeat:
    def test_dead_after_timeout(self):
        clk = FakeClock()
        hb = HeartbeatMonitor(timeout=10.0, clock=clk)
        hb.beat("a")
        hb.beat("b")
        clk.advance(5)
        hb.beat("b")
        clk.advance(6)
        assert hb.dead() == {"a"}
        hb.remove("a")
        assert hb.dead() == set()


class TestStraggler:
    def test_flags_slow_worker(self):
        clk = FakeClock()
        sd = StragglerDetector(alpha=1.0, factor=3.0, clock=clk)
        # 3 fast workers at 1 s cadence, one at 10 s
        for step in range(5):
            for w in ("f1", "f2", "f3"):
                sd.on_update(w)
            if step % 10 == 0:
                sd.on_update("slow")
            clk.advance(1.0)
        # give slow one more update to compute its interval
        clk.advance(35.0)
        sd.on_update("slow")
        assert "slow" in sd.stragglers()
        assert not {"f1", "f2", "f3"} & sd.stragglers()

    def test_no_stragglers_with_uniform_cohort(self):
        clk = FakeClock()
        sd = StragglerDetector(clock=clk)
        for _ in range(5):
            for w in ("a", "b"):
                sd.on_update(w)
            clk.advance(1.0)
        assert sd.stragglers() == set()


class TestElasticCohort:
    def test_join_leave_evict(self):
        c = ElasticCohort(shards=[0, 1, 2])
        s_a = c.join("a")
        s_b = c.join("b")
        assert {s_a, s_b} <= {0, 1, 2}
        assert c.active == {"a", "b"}
        freed = c.evict(["a"])
        assert freed == [s_a]
        # shard is recycled
        s_c = c.join("c")
        assert s_c in {0, 1, 2}
        assert c.active == {"b", "c"}

    def test_exhausted_pool_raises(self):
        c = ElasticCohort(shards=[0])
        c.join("a")
        with pytest.raises(RuntimeError):
            c.join("b")
