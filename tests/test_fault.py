"""Fault tolerance: heartbeats, straggler detection, elastic membership,
and the slot-clock wiring that runs them on the simulator's timeline."""
import pytest

from repro.fault.monitor import (ElasticCohort, FleetMonitor,
                                 HeartbeatMonitor, SlotClock,
                                 StragglerDetector)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class TestHeartbeat:
    def test_dead_after_timeout(self):
        clk = FakeClock()
        hb = HeartbeatMonitor(timeout=10.0, clock=clk)
        hb.beat("a")
        hb.beat("b")
        clk.advance(5)
        hb.beat("b")
        clk.advance(6)
        assert hb.dead() == {"a"}
        hb.remove("a")
        assert hb.dead() == set()


class TestStraggler:
    def test_flags_slow_worker(self):
        clk = FakeClock()
        sd = StragglerDetector(alpha=1.0, factor=3.0, clock=clk)
        # 3 fast workers at 1 s cadence, one at 10 s
        for step in range(5):
            for w in ("f1", "f2", "f3"):
                sd.on_update(w)
            if step % 10 == 0:
                sd.on_update("slow")
            clk.advance(1.0)
        # give slow one more update to compute its interval
        clk.advance(35.0)
        sd.on_update("slow")
        assert "slow" in sd.stragglers()
        assert not {"f1", "f2", "f3"} & sd.stragglers()

    def test_no_stragglers_with_uniform_cohort(self):
        clk = FakeClock()
        sd = StragglerDetector(clock=clk)
        for _ in range(5):
            for w in ("a", "b"):
                sd.on_update(w)
            clk.advance(1.0)
        assert sd.stragglers() == set()


class TestElasticCohort:
    def test_join_leave_evict(self):
        c = ElasticCohort(shards=[0, 1, 2])
        s_a = c.join("a")
        s_b = c.join("b")
        assert {s_a, s_b} <= {0, 1, 2}
        assert c.active == {"a", "b"}
        freed = c.evict(["a"])
        assert freed == [s_a]
        # shard is recycled
        s_c = c.join("c")
        assert s_c in {0, 1, 2}
        assert c.active == {"b", "c"}

    def test_exhausted_pool_raises(self):
        c = ElasticCohort(shards=[0])
        c.join("a")
        with pytest.raises(RuntimeError):
            c.join("b")


class TestSlotClock:
    def test_reads_slot_times_t_d(self):
        clk = SlotClock(t_d=1.6)
        assert clk() == 0.0
        clk.advance(3)
        assert clk() == pytest.approx(3 * 1.6)
        clk.advance()
        assert clk.slot == 4

    def test_seek_is_forward_only(self):
        clk = SlotClock()
        clk.seek(10)
        clk.seek(10)            # same slot is fine (in-slot events)
        with pytest.raises(ValueError, match="rewind"):
            clk.seek(9)

    def test_rejects_nonpositive_t_d(self):
        with pytest.raises(ValueError, match="t_d"):
            SlotClock(t_d=0.0)

    def test_drives_heartbeat_timeout_in_slots(self):
        """A HeartbeatMonitor on a SlotClock times out after
        timeout / t_d slots of silence — slot arithmetic, no wall time."""
        clk = SlotClock(t_d=2.0)
        hb = HeartbeatMonitor(timeout=10.0, clock=clk)   # 5 slots
        hb.beat("u")
        clk.seek(5)
        assert hb.dead() == set()   # exactly at timeout: not yet dead
        clk.seek(6)
        assert hb.dead() == {"u"}


class TestFleetMonitor:
    def test_sweep_evicts_silent_user_from_both_monitors(self):
        mon = FleetMonitor(timeout_slots=5)
        for slot in range(4):
            mon.observe_push(slot, 1)
            mon.observe_push(slot, 2)
        mon.observe_push(4, 1)      # user 2 falls silent after slot 3
        for slot in range(5, 10):
            mon.observe_push(slot, 1)
            dead = mon.sweep(slot)
        assert (9, 2) in mon.evictions
        assert 2 not in mon.heartbeat.workers
        assert 2 not in mon.straggler.workers
        assert mon.active == {1}

    def test_eviction_is_not_final(self):
        """An evicted user's next push re-registers it — the server-side
        mirror of a churned device re-entering the arrival process."""
        mon = FleetMonitor(timeout_slots=3)
        mon.observe_push(0, 7)
        mon.sweep(10)
        assert mon.active == set()
        mon.observe_push(10, 7)
        assert mon.active == {7}
        assert mon.sweep(11) == set()

    def test_rejects_nonpositive_timeout(self):
        with pytest.raises(ValueError, match="timeout_slots"):
            FleetMonitor(timeout_slots=0)

    def test_eviction_reregistration_against_live_server_stream(self):
        """The monitor watching a LIVE ``AsyncParameterServer`` push
        stream (not a replayed log): three clients push at their own
        cadences, one goes dark mid-run and is evicted, then its next
        real push re-registers it. The uneven cadences drive the
        SlotClock seek path — slots jump forward, never one at a time."""
        import jax.numpy as jnp

        from repro.core.server import AsyncParameterServer

        server = AsyncParameterServer({"w": jnp.zeros(8)}, eta=0.05,
                                      beta=0.9)
        mon = FleetMonitor(timeout_slots=6)
        pulled = {}

        def train_push(uid, slot):
            params, _ = server.pull(uid)
            r = server.push(uid, {"w": params["w"] + 0.1})
            mon.observe_push(slot, uid)
            return r

        # cadences: u0 every 2 slots, u1 every 3, u2 pushes twice then dies
        for slot in range(0, 30, 1):
            if slot % 2 == 0:
                train_push(0, slot)
            if slot % 3 == 0:
                train_push(1, slot)
            if slot in (0, 3):
                train_push(2, slot)
            mon.sweep(slot)
        # u2's last push was slot 3; timeout 6 -> evicted at slot 10
        assert (10, 2) in mon.evictions
        assert mon.active == {0, 1}
        # recovery: u2 pushes again through the SAME live server; the
        # seek jumps the clock from 29 to 35 in one step
        train_push(2, 35)
        assert 2 in mon.active
        # the jump also aged out u0/u1 (quiet since slots 28/27): the
        # sweep at the new clock position evicts exactly them
        assert mon.sweep(35) == {0, 1}
        assert mon.active == {2}
        # lag bookkeeping survived the eviction: u2's pull/push round
        # trips still produce sane lags on the live server
        params, v = server.pull(2)
        r = server.push(2, {"w": params["w"] + 0.1})
        assert r.lag == 0 and r.version == server.lag_tracker.version

    def test_seek_rejects_out_of_order_live_stream(self):
        """Live observation is forward-only: a push reported for an
        older slot than the clock has reached is a caller bug."""
        mon = FleetMonitor(timeout_slots=4)
        mon.observe_push(9, 1)
        with pytest.raises(ValueError, match="rewind"):
            mon.observe_push(3, 1)

    def test_replay_matches_live_observation(self):
        """replay() over a push-log list equals the same events fed
        live through observe_push/sweep."""
        events = [(0, 1), (0, 2), (3, 1), (7, 1), (12, 1)]
        log = [{"t": t, "user": u} for t, u in events]
        replayed = FleetMonitor(timeout_slots=4).replay(log, 15)
        live = FleetMonitor(timeout_slots=4)
        k = 0
        for slot in range(15):
            while k < len(events) and events[k][0] == slot:
                live.observe_push(slot, events[k][1])
                k += 1
            live.sweep(slot)
        assert replayed == live.evictions
        assert [u for _, u in replayed].count(2) == 1
