"""End-to-end dry-run integration: run launch.dryrun in a SUBPROCESS (it
must own the XLA placeholder-device flag before jax init) for one fast cell
on the real production mesh and validate the JSON artifact + roofline terms.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.parametrize("arch,shape", [("qwen3-0.6b", "decode_32k")])
def test_dryrun_cell_subprocess(tmp_path, arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path),
         "--no-calibrate"],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]

    rec = json.load(open(tmp_path / f"{arch}__{shape}__single.json"))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["mesh_shape"] == {"data": 16, "model": 16}
    mem = rec["real"]["memory"]
    # the fit proof: per-device bytes within a v5e's 16 GiB
    assert (mem["argument_bytes"] + mem["temp_bytes"]) < 16 * 2 ** 30
    assert rec["real"]["flops"] > 0
    assert rec["real"]["hbm_bytes"] > 0

    from repro.launch.roofline import cell_terms
    t = cell_terms(rec, source="real")
    assert t is not None
    assert t["compute_s"] > 0 and t["memory_s"] > 0
    assert t["dominant"] in ("compute", "memory", "collective")
