"""Engine-dispatch matrix: every (engine x ml_mode x policy) combination
either resolves to a documented engine or raises the documented error —
plus the carry-protocol parity matrix pinning every registry policy's
schedule on every engine against the loop oracle, bit for bit, and the
aggregation-rule parity matrix pinning the weighted push path
(core/aggregation.py) across rule x engine x policy.

``FederatedSim.resolve_engine`` encodes the fallback rules this repo's
engines rely on (and which the batched real-ML path relaxed):

* trace mode, no hooks: ``auto`` -> vectorized when the policy has the
  hook; ``jax`` runs every policy with the ``scan_step`` carry hook (all
  registry policies, offline and greedy included) and degrades to
  vectorized only for custom policies without it.
* real mode WITH a batched ml_backend: vectorized-capable — ``auto`` and
  ``vectorized`` run the batched engine, ``jax`` degrades to vectorized
  (per-slot Python callbacks cannot live inside lax.scan), ``loop``
  drives the same backend through its hooks() adapter.
* real mode WITHOUT a backend (per-user hooks or nothing): loop only —
  ``vectorized``/``jax`` raise ValueError.

Each resolvable combination is also *run* for a short horizon, so the
matrix pins behaviour, not just the resolver's return value.
"""
import hashlib

import numpy as np
import pytest

from repro.core.policies import registered_policies, resolve_policy
from repro.core.realml import LeNetBackend
from repro.core.simulator import ENGINES, FederatedSim, SimConfig

ALL_POLICIES = registered_policies()

TINY_ML = dict(n_train=64, n_test=32, seed=0, eval_every=300)


def expected_engine(engine: str, ml_mode: str, policy: str,
                    with_backend: bool):
    """The documented resolution, or ValueError when the combo must
    raise. Mirrors the docstring of FederatedSim.resolve_engine."""
    pol = resolve_policy(policy)
    vec_ok = ml_mode == "trace" or with_backend
    if engine == "auto":
        return "vectorized" if (vec_ok and pol.supports_vectorized) \
            else "loop"
    if engine == "loop":
        return "loop"
    if not vec_ok:
        return ValueError
    if engine == "vectorized":
        return "vectorized" if pol.supports_vectorized else ValueError
    # engine == "jax": real-mode backends and hook-less trace differ
    if ml_mode == "real":
        return "vectorized" if pol.supports_vectorized else "loop"
    return "jax" if pol.supports_jax else (
        "vectorized" if pol.supports_vectorized else "loop")


def build(engine, ml_mode, policy):
    n = 4
    backend = None
    if ml_mode == "real":
        backend = LeNetBackend(n, sync=resolve_policy(policy).sync_rounds,
                               **TINY_ML)
    cfg = SimConfig(policy=policy, engine=engine, ml_mode=ml_mode,
                    n_users=n, horizon_s=60, app_arrival_p=0.01, seed=3,
                    collect_push_log=False)
    return FederatedSim(cfg, ml_backend=backend)


class TestDispatchMatrix:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    @pytest.mark.parametrize("ml_mode", ("trace", "real"))
    @pytest.mark.parametrize("engine", ENGINES)
    def test_resolution_and_run(self, engine, ml_mode, policy):
        exp = expected_engine(engine, ml_mode, policy,
                              with_backend=(ml_mode == "real"))
        sim = build(engine, ml_mode, policy)
        if exp is ValueError:
            with pytest.raises(ValueError):
                sim.run()
            return
        assert sim.resolve_engine() == exp
        r = sim.run()         # the combination must actually execute
        assert np.isfinite(r.energy_j) and r.energy_j > 0

    @pytest.mark.parametrize("engine", ("vectorized", "jax"))
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_real_mode_without_backend_raises(self, engine, policy):
        """The pre-backend rule survives: hook-based (or hook-less) real
        mode cannot run on the batched engines."""
        cfg = SimConfig(policy=policy, engine=engine, ml_mode="real",
                        n_users=4, horizon_s=60)
        with pytest.raises(ValueError, match="ml_backend|trace"):
            FederatedSim(cfg).run()

    def test_real_mode_auto_without_backend_is_loop(self):
        cfg = SimConfig(policy="online", ml_mode="real", n_users=4,
                        horizon_s=60)
        assert FederatedSim(cfg).resolve_engine() == "loop"

    def test_trace_mode_hooks_still_force_loop(self):
        """Per-user hooks other than v_norm keep trace mode on the
        loop engine under auto (unchanged rule)."""
        cfg = SimConfig(policy="online", n_users=4, horizon_s=60)
        sim = FederatedSim(cfg, ml_hooks={"pull": lambda uid: None})
        assert sim.resolve_engine() == "loop"

    def test_v_norm_hook_keeps_vectorized(self):
        cfg = SimConfig(policy="online", n_users=4, horizon_s=60)
        sim = FederatedSim(cfg, ml_hooks={"v_norm": lambda: 1.0})
        assert sim.resolve_engine() == "vectorized"


# ---------------------------------------------------------------------------
# Carry-protocol parity matrix (policy x engine): the batched engines must
# reproduce the loop oracle's SCHEDULE bit for bit — every push event's
# (slot, user, lag, corun) — including the stateful policies whose carry
# (greedy wait counters, offline plan slots) now threads through lax.scan.
# ---------------------------------------------------------------------------
def schedule_digest(push_log) -> str:
    return hashlib.sha256(
        ";".join(f'{e["t"]},{e["user"]},{e["lag"]},{int(e["corun"])}'
                 for e in push_log).encode()).hexdigest()


class TestCarryProtocolParity:
    @pytest.fixture(autouse=True)
    def _x64(self):
        """f64 keeps the jax engine bit-comparable with the loop oracle."""
        import jax
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        yield
        jax.config.update("jax_enable_x64", prev)

    # tight L_b builds staleness pressure (H > 0) inside the short
    # horizon, so the online policy's sequential in-slot coupling and the
    # offline knapsack's budget both actually bind
    KW = dict(n_users=10, horizon_s=1500, app_arrival_p=0.01, seed=11,
              V=2000.0, L_b=2.0)

    @pytest.fixture(scope="class")
    def oracle(self):
        cache = {}

        def get(policy):
            if policy not in cache:
                cache[policy] = FederatedSim(SimConfig(
                    policy=policy, engine="loop", **self.KW)).run()
            return cache[policy]

        return get

    @pytest.mark.parametrize("engine", ("vectorized", "jax"))
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_schedule_bit_equality_vs_loop(self, oracle, policy, engine):
        a = oracle(policy)
        r = FederatedSim(SimConfig(policy=policy, engine=engine,
                                   **self.KW)).run()
        assert r.updates == a.updates
        assert len(r.push_log) == len(a.push_log)
        assert schedule_digest(r.push_log) == schedule_digest(a.push_log)
        assert r.energy_j == pytest.approx(a.energy_j, rel=1e-9)
        assert r.mean_Q == pytest.approx(a.mean_Q, rel=1e-9, abs=1e-12)
        assert r.mean_H == pytest.approx(a.mean_H, rel=1e-6, abs=1e-9)
        np.testing.assert_allclose([e["gap"] for e in r.push_log],
                                   [e["gap"] for e in a.push_log],
                                   rtol=1e-9, atol=1e-15)


# ---------------------------------------------------------------------------
# Aggregation-rule parity matrix (rule x engine x policy): the loop oracle
# is pinned as ground truth for the staleness-aware weighted rules — the
# batched engines must reproduce its SCHEDULE bit for bit and its applied
# per-push weights (the push log's sixth column, computed in-jit on the
# jax engine through the rule's scan_weight hook).
# ---------------------------------------------------------------------------
class TestAggregationRuleParity:
    @pytest.fixture(autouse=True)
    def _x64(self):
        import jax
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        yield
        jax.config.update("jax_enable_x64", prev)

    ALL_RULES = ("replace", "fedasync_poly", "gap_aware", "hetero_aware")
    # online (queue-coupled) + eps_greedy (stochastic, rng in the carry
    # protocol): the two policies whose engine hooks exercise every piece
    # of shared scan machinery the weight column rides on
    POLICIES = ("online", "eps_greedy")
    KW = dict(n_users=10, horizon_s=1500, app_arrival_p=0.01, seed=11,
              V=2000.0, L_b=2.0)

    @pytest.fixture(scope="class")
    def oracle(self):
        cache = {}

        def get(policy, rule):
            if (policy, rule) not in cache:
                cache[(policy, rule)] = FederatedSim(SimConfig(
                    policy=policy, engine="loop", aggregation=rule,
                    **self.KW)).run()
            return cache[(policy, rule)]

        return get

    @pytest.mark.parametrize("engine", ("vectorized", "jax"))
    @pytest.mark.parametrize("rule", ALL_RULES)
    @pytest.mark.parametrize("policy", POLICIES)
    def test_weights_match_loop_oracle(self, oracle, policy, rule, engine):
        a = oracle(policy, rule)
        r = FederatedSim(SimConfig(policy=policy, engine=engine,
                                   aggregation=rule, **self.KW)).run()
        assert a.updates == r.updates > 0
        assert schedule_digest(r.push_log) == schedule_digest(a.push_log)
        np.testing.assert_allclose([e["weight"] for e in r.push_log],
                                   [e["weight"] for e in a.push_log],
                                   rtol=1e-9, atol=1e-15)
        np.testing.assert_allclose([e["gap"] for e in r.push_log],
                                   [e["gap"] for e in a.push_log],
                                   rtol=1e-9, atol=1e-15)

    @pytest.mark.parametrize("rule", ALL_RULES)
    def test_weights_are_valid_mixing_weights(self, oracle, rule):
        ws = np.array([e["weight"] for e in oracle("online", rule).push_log])
        assert len(ws) and np.all((ws >= 0.0) & (ws <= 1.0))
        if rule == "replace":
            assert np.all(ws == 1.0)    # the paper's Sec. VI rule
        else:
            assert ws.min() < 1.0       # staleness actually dampens

    def test_trace_schedule_is_rule_independent(self, oracle):
        """In trace mode the weight is observational: the schedule the
        engines produce must not depend on the aggregation rule (only
        real mode feeds the weight back into training)."""
        a = oracle("online", "replace")
        b = oracle("online", "fedasync_poly")
        assert schedule_digest(a.push_log) == schedule_digest(b.push_log)
