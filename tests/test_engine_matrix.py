"""Engine-dispatch matrix: every (engine x ml_mode x policy) combination
either resolves to a documented engine or raises the documented error.

``FederatedSim.resolve_engine`` encodes the fallback rules this repo's
engines rely on (and which the batched real-ML path relaxed):

* trace mode, no hooks: ``auto`` -> vectorized when the policy has the
  hook; ``jax`` degrades to vectorized for policies without a jax hook
  (offline, greedy).
* real mode WITH a batched ml_backend: vectorized-capable — ``auto`` and
  ``vectorized`` run the batched engine, ``jax`` degrades to vectorized
  (Python callbacks cannot live inside lax.scan), ``loop`` drives the
  same backend through its hooks() adapter.
* real mode WITHOUT a backend (per-user hooks or nothing): loop only —
  ``vectorized``/``jax`` raise ValueError.

Each resolvable combination is also *run* for a short horizon, so the
matrix pins behaviour, not just the resolver's return value.
"""
import numpy as np
import pytest

from repro.core.policies import registered_policies, resolve_policy
from repro.core.realml import LeNetBackend
from repro.core.simulator import ENGINES, FederatedSim, SimConfig

ALL_POLICIES = registered_policies()

TINY_ML = dict(n_train=64, n_test=32, seed=0, eval_every=300)


def expected_engine(engine: str, ml_mode: str, policy: str,
                    with_backend: bool):
    """The documented resolution, or ValueError when the combo must
    raise. Mirrors the docstring of FederatedSim.resolve_engine."""
    pol = resolve_policy(policy)
    vec_ok = ml_mode == "trace" or with_backend
    if engine == "auto":
        return "vectorized" if (vec_ok and pol.supports_vectorized) \
            else "loop"
    if engine == "loop":
        return "loop"
    if not vec_ok:
        return ValueError
    if engine == "vectorized":
        return "vectorized" if pol.supports_vectorized else ValueError
    # engine == "jax": real-mode backends and hook-less trace differ
    if ml_mode == "real":
        return "vectorized" if pol.supports_vectorized else "loop"
    return "jax" if pol.supports_jax else (
        "vectorized" if pol.supports_vectorized else "loop")


def build(engine, ml_mode, policy):
    n = 4
    backend = None
    if ml_mode == "real":
        backend = LeNetBackend(n, sync=resolve_policy(policy).sync_rounds,
                               **TINY_ML)
    cfg = SimConfig(policy=policy, engine=engine, ml_mode=ml_mode,
                    n_users=n, horizon_s=60, app_arrival_p=0.01, seed=3,
                    collect_push_log=False)
    return FederatedSim(cfg, ml_backend=backend)


class TestDispatchMatrix:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    @pytest.mark.parametrize("ml_mode", ("trace", "real"))
    @pytest.mark.parametrize("engine", ENGINES)
    def test_resolution_and_run(self, engine, ml_mode, policy):
        exp = expected_engine(engine, ml_mode, policy,
                              with_backend=(ml_mode == "real"))
        sim = build(engine, ml_mode, policy)
        if exp is ValueError:
            with pytest.raises(ValueError):
                sim.run()
            return
        assert sim.resolve_engine() == exp
        r = sim.run()         # the combination must actually execute
        assert np.isfinite(r.energy_j) and r.energy_j > 0

    @pytest.mark.parametrize("engine", ("vectorized", "jax"))
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_real_mode_without_backend_raises(self, engine, policy):
        """The pre-backend rule survives: hook-based (or hook-less) real
        mode cannot run on the batched engines."""
        cfg = SimConfig(policy=policy, engine=engine, ml_mode="real",
                        n_users=4, horizon_s=60)
        with pytest.raises(ValueError, match="ml_backend|trace"):
            FederatedSim(cfg).run()

    def test_real_mode_auto_without_backend_is_loop(self):
        cfg = SimConfig(policy="online", ml_mode="real", n_users=4,
                        horizon_s=60)
        assert FederatedSim(cfg).resolve_engine() == "loop"

    def test_trace_mode_hooks_still_force_loop(self):
        """Per-user hooks other than v_norm keep trace mode on the
        loop engine under auto (unchanged rule)."""
        cfg = SimConfig(policy="online", n_users=4, horizon_s=60)
        sim = FederatedSim(cfg, ml_hooks={"pull": lambda uid: None})
        assert sim.resolve_engine() == "loop"

    def test_v_norm_hook_keeps_vectorized(self):
        cfg = SimConfig(policy="online", n_users=4, horizon_s=60)
        sim = FederatedSim(cfg, ml_hooks={"v_norm": lambda: 1.0})
        assert sim.resolve_engine() == "vectorized"
