"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, output shapes + no NaNs; prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import build_model

LM_ARCHS = [a for a in ARCHS]


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["audio_embeds"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.num_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestSmoke:
    def test_forward_no_nan(self, arch):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        loss, metrics = m.loss(params, _batch(cfg, jax.random.PRNGKey(1)))
        assert np.isfinite(float(loss))
        assert float(loss) >= 0

    def test_one_train_step_reduces_loss_shape_stable(self, arch):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))

        def loss_fn(p):
            return m.loss(p, batch)[0]

        l0, grads = jax.value_and_grad(loss_fn)(params)
        # shapes preserved, grads finite
        for g, p in zip(jax.tree.leaves(grads), jax.tree.leaves(params)):
            assert g.shape == p.shape
            assert np.isfinite(np.asarray(g)).all()
        new = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
        l1 = loss_fn(new)
        assert np.isfinite(float(l1))
        assert float(l1) < float(l0)   # one step on the same batch descends

    def test_full_config_matches_assignment(self, arch):
        """The full (non-smoke) config carries the assigned dimensions."""
        cfg = get_config(arch)
        assigned = {
            "mamba2_370m": dict(num_layers=48, d_model=1024, vocab_size=50280,
                                ssm_state=128),
            "qwen3_moe_30b_a3b": dict(num_layers=48, d_model=2048,
                                      num_heads=32, num_kv_heads=4,
                                      d_ff=768, vocab_size=151936,
                                      num_experts=128, num_experts_per_tok=8),
            "granite_moe_1b_a400m": dict(num_layers=24, d_model=1024,
                                         num_heads=16, num_kv_heads=8,
                                         d_ff=512, vocab_size=49155,
                                         num_experts=32,
                                         num_experts_per_tok=8),
            "internlm2_20b": dict(num_layers=48, d_model=6144, num_heads=48,
                                  num_kv_heads=8, d_ff=16384,
                                  vocab_size=92544),
            "qwen3_0_6b": dict(num_layers=28, d_model=1024, num_heads=16,
                               num_kv_heads=8, d_ff=3072, vocab_size=151936,
                               qk_norm=True),
            "qwen2_5_3b": dict(num_layers=36, d_model=2048, num_heads=16,
                               num_kv_heads=2, d_ff=11008,
                               vocab_size=151936, qkv_bias=True),
            "phi4_mini_3_8b": dict(num_layers=32, d_model=3072, num_heads=24,
                                   num_kv_heads=8, d_ff=8192,
                                   vocab_size=200064),
            "whisper_large_v3": dict(num_layers=32, d_model=1280,
                                     num_heads=20, num_kv_heads=20,
                                     d_ff=5120, vocab_size=51866,
                                     encoder_layers=32),
            "zamba2_2_7b": dict(num_layers=54, d_model=2560, num_heads=32,
                                num_kv_heads=32, d_ff=10240,
                                vocab_size=32000, ssm_state=64),
            "internvl2_76b": dict(num_layers=80, d_model=8192, num_heads=64,
                                  num_kv_heads=8, d_ff=28672,
                                  vocab_size=128256),
        }[arch]
        for k, v in assigned.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forcing consistency: prefill(prompt) + decode_step(tok_i)
    reproduces the full-sequence forward logits."""
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        # dispatch-impl equivalence is covered separately; the sorted path
        # legitimately drops tokens at tiny T, breaking exact consistency
        cfg = dataclasses.replace(cfg, moe_impl="dense")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, jax.random.PRNGKey(1), B=B, S=S)
    tokens = batch["tokens"]

    # full forward logits via loss-path model internals: use prefill over the
    # whole sequence, then compare last-token logits with prefill of S-1 +
    # one decode step.
    cache_full = m.init_cache(B, S)
    full_logits, _ = m.prefill(params, batch, cache_full)

    prompt = dict(batch)
    prompt["tokens"] = tokens[:, : S - 1]
    if "labels" in prompt:
        prompt["labels"] = prompt["labels"][:, : S - 1]
    cache = m.init_cache(B, S)
    _, cache = m.prefill(params, prompt, cache)
    step_logits, cache = m.decode_step(params, cache,
                                       {"tokens": tokens[:, S - 1:]})
    tol = 6e-2 if cfg.sub_quadratic else 2e-2   # f32 ssd state round-trip
    np.testing.assert_allclose(np.asarray(full_logits[:, -1]),
                               np.asarray(step_logits[:, -1]),
                               rtol=tol, atol=tol)


def test_param_count_analytic_matches_actual():
    for arch in LM_ARCHS:
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        assert cfg.param_count() == actual, arch


def test_moe_sorted_equals_dense_dispatch():
    cfg = get_smoke_config("granite_moe_1b_a400m")
    cfg_d = dataclasses.replace(cfg, moe_impl="dense")
    cfg_s = dataclasses.replace(cfg, moe_impl="sorted",
                                moe_capacity_factor=8.0)  # no drops
    m_d, m_s = build_model(cfg_d), build_model(cfg_s)
    params = m_d.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l_d, _ = m_d.loss(params, batch)
    l_s, _ = m_s.loss(params, batch)
    assert float(l_d) == pytest.approx(float(l_s), rel=2e-3)


def test_scan_equals_unrolled():
    """cfg.scan_layers=False is semantically identical (dry-run calibration
    correctness precondition)."""
    for arch in ("qwen3_0_6b", "mamba2_370m", "zamba2_2_7b",
                 "whisper_large_v3"):
        cfg = get_smoke_config(arch)
        cfg_u = dataclasses.replace(cfg, scan_layers=False)
        m, mu = build_model(cfg), build_model(cfg_u)
        params = m.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        l, _ = m.loss(params, batch)
        lu, _ = mu.loss(params, batch)
        # bf16 accumulation order differs between scan and unrolled
        assert float(l) == pytest.approx(float(lu), rel=3e-3), arch
