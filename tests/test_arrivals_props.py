"""Property-based tests for ``ArrivalProcess`` implementations.

Every registered process must uphold the engine contract regardless of
parameters: ``sample`` returns a boolean ``(T, n_users)`` mask plus an
app-choice array of the same shape with every entry in
``[0, len(APPS))`` — out-of-range choices would index the catalog tables
from the end (numpy) or clamp (jax gather), silently corrupting energy
accounting. ``TraceArrivals`` must replay any recorded schedule
round-trip, including wrap-around for shorter traces.

Uses the real ``hypothesis`` when installed (requirements-dev.txt);
otherwise conftest.py installs the deterministic stub so these still
collect and run boundary + sampled cases.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.arrivals import (BernoulliArrivals, DiurnalArrivals,
                                 MarkovModulatedArrivals, TraceArrivals,
                                 registered_arrivals, resolve_arrival)
from repro.core.energy import APPS

COMMON = dict(deadline=None,
              suppress_health_check=[HealthCheck.too_slow])


def check_contract(proc, T, n_users, seed):
    rng = np.random.default_rng(seed)
    sched, choice = proc.sample(rng, T, n_users, len(APPS))
    sched = np.asarray(sched)
    choice = np.asarray(choice)
    assert sched.shape == (T, n_users)
    assert choice.shape == (T, n_users)
    assert sched.dtype == np.bool_
    assert np.issubdtype(choice.dtype, np.integer)
    if T and n_users:
        assert choice.min() >= 0
        assert choice.max() < len(APPS)
    return sched, choice


class TestSampleContract:
    @settings(max_examples=25, **COMMON)
    @given(T=st.integers(0, 400), n=st.integers(1, 40),
           p=st.floats(0.0, 1.0), seed=st.integers(0, 2 ** 20))
    def test_bernoulli(self, T, n, p, seed):
        sched, _ = check_contract(BernoulliArrivals(p), T, n, seed)
        if p == 0.0:
            assert not sched.any()
        if p == 1.0 and T:
            assert sched.all()

    @settings(max_examples=25, **COMMON)
    @given(T=st.integers(0, 400), n=st.integers(1, 40),
           p=st.floats(0.0, 0.5), depth=st.floats(0.0, 1.0),
           period=st.floats(1.0, 1e5), phase=st.floats(0.0, 0.99),
           seed=st.integers(0, 2 ** 20))
    def test_diurnal(self, T, n, p, depth, period, phase, seed):
        proc = DiurnalArrivals(p_mean=p, depth=depth, period_s=period,
                               phase=phase)
        check_contract(proc, T, n, seed)
        rate = proc.rate(T)
        assert rate.shape == (T,)
        assert (rate >= 0.0).all() and (rate <= 1.0).all()

    @settings(max_examples=20, **COMMON)
    @given(T=st.integers(0, 250), n=st.integers(1, 30),
           p_calm=st.floats(0.0, 1.0), p_burst=st.floats(0.0, 1.0),
           start=st.floats(0.0, 1.0), stop=st.floats(0.0, 1.0),
           seed=st.integers(0, 2 ** 20))
    def test_bursty(self, T, n, p_calm, p_burst, start, stop, seed):
        proc = MarkovModulatedArrivals(p_calm=p_calm, p_burst=p_burst,
                                       burst_start=start, burst_stop=stop)
        check_contract(proc, T, n, seed)

    @settings(max_examples=15, **COMMON)
    @given(T=st.integers(1, 200), n=st.integers(1, 16),
           seed=st.integers(0, 2 ** 20))
    def test_registered_default_instances(self, T, n, seed):
        for name in registered_arrivals():
            if name == "trace":       # needs a recorded schedule
                continue
            check_contract(resolve_arrival(name), T, n, seed)


class TestTraceRoundTrip:
    @settings(max_examples=25, **COMMON)
    @given(Tr=st.integers(1, 120), T=st.integers(1, 300),
           n=st.integers(1, 12), p=st.floats(0.0, 0.3),
           seed=st.integers(0, 2 ** 20))
    def test_replay_wraps_and_preserves(self, Tr, T, n, p, seed):
        rng = np.random.default_rng(seed)
        base = rng.random((Tr, n)) < p
        choice = rng.integers(0, len(APPS), (Tr, n))
        proc = TraceArrivals(base, choice)
        sched, ch = check_contract(proc, T, n, seed + 1)
        reps = -(-T // Tr)
        np.testing.assert_array_equal(sched,
                                      np.tile(base, (reps, 1))[:T])
        np.testing.assert_array_equal(ch,
                                      np.tile(choice, (reps, 1))[:T])

    @settings(max_examples=10, **COMMON)
    @given(seed=st.integers(0, 2 ** 20))
    def test_from_sim_round_trip(self, seed):
        """Snapshot a constructed sim's schedule, replay it through a new
        sim, and the replayed arrivals must be draw-for-draw identical."""
        from repro.core.simulator import FederatedSim, SimConfig
        cfg = SimConfig(policy="immediate", n_users=6, horizon_s=300,
                        app_arrival_p=0.02, seed=seed)
        sim = FederatedSim(cfg)
        replay = TraceArrivals.from_sim(sim)
        sim2 = FederatedSim(cfg, arrivals=replay)
        np.testing.assert_array_equal(sim2.app_sched, sim.app_sched)
        np.testing.assert_array_equal(sim2.app_choice, sim.app_choice)

    def test_user_axis_mismatch_raises(self):
        proc = TraceArrivals(np.zeros((10, 4), dtype=bool))
        with pytest.raises(ValueError, match="users"):
            proc.sample(np.random.default_rng(0), 10, 5, len(APPS))

    def test_out_of_range_choice_raises(self):
        sched = np.zeros((5, 2), dtype=bool)
        choice = np.full((5, 2), len(APPS))
        proc = TraceArrivals(sched, choice)
        with pytest.raises(ValueError, match="choices"):
            proc.sample(np.random.default_rng(0), 5, 2, len(APPS))
