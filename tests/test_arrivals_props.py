"""Property-based tests for ``ArrivalProcess`` implementations.

Every registered process must uphold the engine contract regardless of
parameters: ``sample`` returns a boolean ``(T, n_users)`` mask plus an
app-choice array of the same shape with every entry in
``[0, len(APPS))`` — out-of-range choices would index the catalog tables
from the end (numpy) or clamp (jax gather), silently corrupting energy
accounting. ``TraceArrivals`` must replay any recorded schedule
round-trip, including wrap-around for shorter traces.

Uses the real ``hypothesis`` when installed (requirements-dev.txt);
otherwise conftest.py installs the deterministic stub so these still
collect and run boundary + sampled cases.
"""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.arrivals import (BernoulliArrivals, DiurnalArrivals,
                                 MarkovModulatedArrivals, TraceArrivals,
                                 registered_arrivals, resolve_arrival)
from repro.core.energy import APPS

COMMON = dict(deadline=None,
              suppress_health_check=[HealthCheck.too_slow])


def check_contract(proc, T, n_users, seed):
    rng = np.random.default_rng(seed)
    sched, choice = proc.sample(rng, T, n_users, len(APPS))
    sched = np.asarray(sched)
    choice = np.asarray(choice)
    assert sched.shape == (T, n_users)
    assert choice.shape == (T, n_users)
    assert sched.dtype == np.bool_
    assert np.issubdtype(choice.dtype, np.integer)
    if T and n_users:
        assert choice.min() >= 0
        assert choice.max() < len(APPS)
    return sched, choice


class TestSampleContract:
    @settings(max_examples=25, **COMMON)
    @given(T=st.integers(0, 400), n=st.integers(1, 40),
           p=st.floats(0.0, 1.0), seed=st.integers(0, 2 ** 20))
    def test_bernoulli(self, T, n, p, seed):
        sched, _ = check_contract(BernoulliArrivals(p), T, n, seed)
        if p == 0.0:
            assert not sched.any()
        if p == 1.0 and T:
            assert sched.all()

    @settings(max_examples=25, **COMMON)
    @given(T=st.integers(0, 400), n=st.integers(1, 40),
           p=st.floats(0.0, 0.5), depth=st.floats(0.0, 1.0),
           period=st.floats(1.0, 1e5), phase=st.floats(0.0, 0.99),
           seed=st.integers(0, 2 ** 20))
    def test_diurnal(self, T, n, p, depth, period, phase, seed):
        proc = DiurnalArrivals(p_mean=p, depth=depth, period_s=period,
                               phase=phase)
        check_contract(proc, T, n, seed)
        rate = proc.rate(T)
        assert rate.shape == (T,)
        assert (rate >= 0.0).all() and (rate <= 1.0).all()

    @settings(max_examples=20, **COMMON)
    @given(T=st.integers(0, 250), n=st.integers(1, 30),
           p_calm=st.floats(0.0, 1.0), p_burst=st.floats(0.0, 1.0),
           start=st.floats(0.0, 1.0), stop=st.floats(0.0, 1.0),
           seed=st.integers(0, 2 ** 20))
    def test_bursty(self, T, n, p_calm, p_burst, start, stop, seed):
        proc = MarkovModulatedArrivals(p_calm=p_calm, p_burst=p_burst,
                                       burst_start=start, burst_stop=stop)
        check_contract(proc, T, n, seed)

    @settings(max_examples=15, **COMMON)
    @given(T=st.integers(1, 200), n=st.integers(1, 16),
           seed=st.integers(0, 2 ** 20))
    def test_registered_default_instances(self, T, n, seed):
        for name in registered_arrivals():
            if name == "trace":       # needs a recorded schedule
                continue
            check_contract(resolve_arrival(name), T, n, seed)


class TestPerUserRates:
    """Per-user arrival-rate heterogeneity: BernoulliArrivals accepts an
    (n_users,) rate vector; a vector of identical entries must be
    bit-identical to the scalar process (same uniform block, same
    comparison), so existing seeded runs are untouched."""

    @settings(max_examples=25, **COMMON)
    @given(T=st.integers(0, 300), n=st.integers(1, 32),
           p=st.floats(0.0, 1.0), seed=st.integers(0, 2 ** 20))
    def test_uniform_vector_bit_identical_to_scalar(self, T, n, p, seed):
        a_sched, a_choice = BernoulliArrivals(p).sample(
            np.random.default_rng(seed), T, n, len(APPS))
        b_sched, b_choice = BernoulliArrivals(np.full(n, p)).sample(
            np.random.default_rng(seed), T, n, len(APPS))
        np.testing.assert_array_equal(a_sched, b_sched)
        np.testing.assert_array_equal(a_choice, b_choice)

    @settings(max_examples=25, **COMMON)
    @given(T=st.integers(1, 300), n=st.integers(2, 32),
           seed=st.integers(0, 2 ** 20))
    def test_heterogeneous_rates_respected(self, T, n, seed):
        rates = np.zeros(n)
        rates[0] = 1.0                 # always arrives
        sched, _ = check_contract(BernoulliArrivals(rates), T, n, seed)
        assert sched[:, 0].all()
        assert not sched[:, 1:].any()  # zero-rate users never arrive

    def test_vector_length_mismatch_raises(self):
        proc = BernoulliArrivals(np.full(4, 0.01))
        with pytest.raises(ValueError, match="users"):
            proc.sample(np.random.default_rng(0), 10, 5, len(APPS))

    def test_bad_vector_rejected(self):
        with pytest.raises(ValueError, match="\\[0, 1\\]"):
            BernoulliArrivals(np.array([0.1, 1.5]))
        with pytest.raises(ValueError, match="scalar or"):
            BernoulliArrivals(np.zeros((2, 2)))

    def test_simconfig_accepts_and_validates_vector(self):
        from repro.core.simulator import FederatedSim, SimConfig
        rates = np.linspace(0.0, 0.05, 6)
        cfg = SimConfig(policy="immediate", n_users=6, horizon_s=300,
                        app_arrival_p=rates, seed=0)
        sim = FederatedSim(cfg)
        assert not sim.app_sched[:, 0].any()    # rate-0 user
        with pytest.raises(ValueError, match="entries"):
            SimConfig(policy="immediate", n_users=4,
                      app_arrival_p=np.zeros(3))
        with pytest.raises(ValueError, match="\\[0, 1\\]"):
            SimConfig(policy="immediate", n_users=2,
                      app_arrival_p=[0.5, 1.5])

    def test_vector_rate_engine_parity(self):
        """Heterogeneous rates flow through Scenario to every engine."""
        from repro.core import Scenario
        rates = np.linspace(0.002, 0.03, 8)
        kw = dict(policy="online", n_users=8, horizon_s=900, seed=3,
                  app_arrival_p=rates)
        a = Scenario(engine="loop", **kw).run()
        b = Scenario(engine="vectorized", **kw).run()
        assert a.updates == b.updates
        assert a.energy_j == pytest.approx(b.energy_j, rel=1e-9)
        assert [(e["t"], e["user"]) for e in a.push_log] == \
               [(e["t"], e["user"]) for e in b.push_log]


class TestTraceRoundTrip:
    @settings(max_examples=25, **COMMON)
    @given(Tr=st.integers(1, 120), T=st.integers(1, 300),
           n=st.integers(1, 12), p=st.floats(0.0, 0.3),
           seed=st.integers(0, 2 ** 20))
    def test_replay_wraps_and_preserves(self, Tr, T, n, p, seed):
        rng = np.random.default_rng(seed)
        base = rng.random((Tr, n)) < p
        choice = rng.integers(0, len(APPS), (Tr, n))
        proc = TraceArrivals(base, choice)
        sched, ch = check_contract(proc, T, n, seed + 1)
        reps = -(-T // Tr)
        np.testing.assert_array_equal(sched,
                                      np.tile(base, (reps, 1))[:T])
        np.testing.assert_array_equal(ch,
                                      np.tile(choice, (reps, 1))[:T])

    @settings(max_examples=10, **COMMON)
    @given(seed=st.integers(0, 2 ** 20))
    def test_from_sim_round_trip(self, seed):
        """Snapshot a constructed sim's schedule, replay it through a new
        sim, and the replayed arrivals must be draw-for-draw identical."""
        from repro.core.simulator import FederatedSim, SimConfig
        cfg = SimConfig(policy="immediate", n_users=6, horizon_s=300,
                        app_arrival_p=0.02, seed=seed)
        sim = FederatedSim(cfg)
        replay = TraceArrivals.from_sim(sim)
        sim2 = FederatedSim(cfg, arrivals=replay)
        np.testing.assert_array_equal(sim2.app_sched, sim.app_sched)
        np.testing.assert_array_equal(sim2.app_choice, sim.app_choice)

    def test_user_axis_mismatch_raises(self):
        proc = TraceArrivals(np.zeros((10, 4), dtype=bool))
        with pytest.raises(ValueError, match="users"):
            proc.sample(np.random.default_rng(0), 10, 5, len(APPS))

    def test_out_of_range_choice_raises(self):
        sched = np.zeros((5, 2), dtype=bool)
        choice = np.full((5, 2), len(APPS))
        proc = TraceArrivals(sched, choice)
        with pytest.raises(ValueError, match="choices"):
            proc.sample(np.random.default_rng(0), 5, 2, len(APPS))
