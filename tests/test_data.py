"""Data pipeline: synthetic datasets, federated partitioning, loader."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import (cifarlike_dataset, dirichlet_partition,
                                  synthetic_tokens, token_batches)


class TestCifarlike:
    def test_shapes_and_determinism(self):
        x1, y1 = cifarlike_dataset(100, seed=3)
        x2, y2 = cifarlike_dataset(100, seed=3)
        assert x1.shape == (100, 32, 32, 3) and y1.shape == (100,)
        np.testing.assert_array_equal(x1, x2)
        assert set(np.unique(y1)) <= set(range(10))

    def test_class_conditional_structure(self):
        """Within-class distance < between-class distance (learnable)."""
        x, y = cifarlike_dataset(500, noise=0.3, seed=0)
        c0 = x[y == 0].mean(axis=0)
        c1 = x[y == 1].mean(axis=0)
        within = np.linalg.norm(x[y == 0][0] - c0)
        between = np.linalg.norm(c0 - c1)
        assert between > within * 0.3


class TestDirichlet:
    @given(st.integers(2, 10), st.floats(0.1, 10.0), st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_partition_disjoint_and_complete(self, n_clients, alpha, seed):
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, 10, 500)
        shards = dirichlet_partition(labels, n_clients, alpha, seed)
        allidx = np.concatenate(shards)
        assert len(allidx) == len(labels)
        assert len(np.unique(allidx)) == len(labels)

    def test_low_alpha_is_more_skewed(self):
        labels = np.repeat(np.arange(10), 100)
        sk, un = [], []
        for alpha, acc in ((0.1, sk), (100.0, un)):
            shards = dirichlet_partition(labels, 5, alpha, seed=0)
            for s in shards:
                hist = np.bincount(labels[s], minlength=10) / max(len(s), 1)
                acc.append(float((hist ** 2).sum()))   # HHI concentration
        assert np.mean(sk) > np.mean(un)


class TestTokens:
    def test_markov_structure_learnable(self):
        """The deterministic recurrence is recoverable from the stream."""
        s = synthetic_tokens(5000, 97, seed=0, noise=0.1)
        a, b = 31, 17
        pred = (a * s[1:-1].astype(np.int64) + b * s[:-2] + 7) % 97
        acc = (pred == s[2:]).mean()
        assert acc > 0.85   # only noise tokens disagree

    def test_batches_shapes_and_alignment(self):
        s = synthetic_tokens(2000, 50, seed=1)
        for batch in token_batches(s, 4, 16, 3, seed=0):
            assert batch["tokens"].shape == (4, 16)
            assert batch["labels"].shape == (4, 16)
            np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                          batch["labels"][:, :-1])


class TestShardedLoader:
    def test_prefetch_preserves_order_and_content(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.data.loader import ShardedLoader
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1, 1), ("data", "model"))
        sh = NamedSharding(mesh, P())
        batches = [{"x": np.full((2, 2), i, np.float32)} for i in range(7)]
        loader = ShardedLoader(iter(batches), {"x": sh}, depth=3)
        out = [np.asarray(b["x"])[0, 0] for b in loader]
        assert out == list(range(7))
