"""Unit tests for core/engine_state.py: the unified EngineState pytree,
the PushLog fixed-width accumulator, and the jax engine's chunked
streaming behaviour built on top of them."""
import numpy as np
import pytest

from repro.core.engine_state import (EVENT_FIELDS, EngineState, PushBuffer,
                                     PushLog, MODE_COOL, PLAN_HOLD)
from repro.core.policies import resolve_policy
from repro.core.simulator import FederatedSim, SimConfig


class TestEngineState:
    def test_init_shapes_and_defaults(self):
        cfg = SimConfig(policy="online", n_users=7)
        es = EngineState.init(7, cfg, resolve_policy("online"))
        for f in ("mode", "cooldown", "app", "app_rem", "train_rem",
                  "corun", "idle_gap", "pulled_at", "energy", "updates",
                  "plan"):
            assert getattr(es, f).shape == (7,), f
        assert (es.mode == MODE_COOL).all()
        assert (es.app == -1).all()
        assert (es.plan == PLAN_HOLD).all()
        assert es.version == 0 and es.in_flight == 0
        assert es.round_open is False
        assert es.Q == 0.0 and es.H == 0.0
        assert es.carry is None and es.events is None
        assert es.agg_carry is None      # no rule bound in the bare init

    def test_rng_key_is_seed_derived(self):
        cfg = SimConfig(policy="online", n_users=3, seed=42)
        es = EngineState.init(3, cfg, resolve_policy("online"))
        assert es.rng_key.dtype == np.uint32
        assert es.rng_key.shape == (2,)
        assert es.rng_key[1] == 42

    def test_policy_carry_is_initialized(self):
        cfg = SimConfig(policy="greedy", n_users=5)
        es = EngineState.init(5, cfg, resolve_policy("greedy"))
        assert es.carry["waited"].shape == (5,)
        cfg2 = SimConfig(policy="offline", n_users=5)
        es2 = EngineState.init(5, cfg2, resolve_policy("offline"))
        assert es2.carry == {"next_plan": 0.0}

    def test_is_a_jax_pytree(self):
        import jax

        cfg = SimConfig(policy="greedy", n_users=4)
        es = EngineState.init(4, cfg, resolve_policy("greedy"))
        leaves, treedef = jax.tree.flatten(es)
        es2 = jax.tree.unflatten(treedef, leaves)
        assert isinstance(es2, EngineState)
        np.testing.assert_array_equal(es2.mode, es.mode)
        np.testing.assert_array_equal(es2.carry["waited"],
                                      es.carry["waited"])
        # tree.map over the whole state (what the scan machinery does)
        doubled = jax.tree.map(lambda a: a, es)
        assert isinstance(doubled, EngineState)

    def test_replace(self):
        cfg = SimConfig(policy="online", n_users=2)
        es = EngineState.init(2, cfg, resolve_policy("online"))
        es2 = es.replace(version=9)
        assert es2.version == 9 and es.version == 0
        assert es2.mode is es.mode

    @pytest.mark.parametrize("engine", ("loop", "vectorized"))
    def test_repeat_run_starts_fresh(self, engine):
        """run() twice on one sim must give identical results — the
        consumed EngineState/UserState objects are reallocated (warmup-
        then-timed callers relied on this)."""
        sim = FederatedSim(SimConfig(policy="greedy", n_users=6,
                                     horizon_s=400, engine=engine,
                                     app_arrival_p=0.01, seed=0))
        a = sim.run()
        b = sim.run()
        assert b.updates == a.updates
        assert b.energy_j == a.energy_j
        assert list(b.push_log) == list(a.push_log)

    def test_jax_run_writes_final_state_back(self):
        """sim.state reflects the finished run on EVERY engine — the jax
        driver copies the final device pytree back to the host."""
        import jax

        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            kw = dict(policy="greedy", n_users=6, horizon_s=900,
                      app_arrival_p=0.01, seed=0)
            sv = FederatedSim(SimConfig(engine="vectorized", **kw))
            rv = sv.run()
            sj = FederatedSim(SimConfig(engine="jax", **kw))
            rj = sj.run()
        finally:
            jax.config.update("jax_enable_x64", prev)
        assert rj.updates == rv.updates > 0
        assert sj.state.version == sv.state.version > 0
        assert int(sj.state.updates.sum()) == rj.updates
        np.testing.assert_array_equal(np.asarray(sj.state.mode),
                                      sv.state.mode)
        np.testing.assert_allclose(np.asarray(sj.state.energy),
                                   sv.state.energy, rtol=1e-9)
        np.testing.assert_array_equal(
            np.asarray(sj.state.carry["waited"]), sv.state.carry["waited"])
        assert type(sj.state.version) is int

    def test_simconfig_equality_with_rate_vectors(self):
        """(n_users,) app_arrival_p must not break the dataclass __eq__:
        vectors are normalized to tuples at construction."""
        a = SimConfig(policy="online", n_users=3,
                      app_arrival_p=np.array([0.1, 0.2, 0.3]))
        b = SimConfig(policy="online", n_users=3,
                      app_arrival_p=[0.1, 0.2, 0.3])
        c = SimConfig(policy="online", n_users=3,
                      app_arrival_p=[0.1, 0.2, 0.4])
        assert a == b
        assert a != c
        assert a.app_arrival_p == (0.1, 0.2, 0.3)

    def test_sim_exposes_state_and_scalar_views(self):
        """FederatedSim threads ONE EngineState; the historical
        sim.version / sim.in_flight spellings are views into it."""
        sim = FederatedSim(SimConfig(policy="online", n_users=4,
                                     horizon_s=60))
        assert isinstance(sim.state, EngineState)
        sim.version = 3
        assert sim.state.version == 3 and sim.version == 3
        sim.in_flight += 2
        assert sim.state.in_flight == 2
        sim._round_open = True
        assert sim.state.round_open is True


class TestShapeValidation:
    """EngineState.init/replace validate per-user array shapes against n
    — a mis-shaped write fails loudly at the write, not slots later as a
    broadcast error (or worse, silently)."""

    def _state(self, n=4):
        cfg = SimConfig(policy="online", n_users=n)
        return EngineState.init(n, cfg, resolve_policy("online"))

    def test_replace_rejects_wrong_length_per_user_array(self):
        es = self._state(4)
        with pytest.raises(ValueError, match="mode"):
            es.replace(mode=np.zeros(3, dtype=es.mode.dtype))
        with pytest.raises(ValueError, match="energy"):
            es.replace(energy=np.zeros(5))

    def test_replace_rejects_scalar_for_per_user_field(self):
        es = self._state(4)
        with pytest.raises(ValueError, match="train_rem"):
            es.replace(train_rem=np.float64(0.0))

    def test_replace_accepts_correct_shapes(self):
        es = self._state(4)
        es2 = es.replace(energy=np.ones(4), version=3)
        assert es2.version == 3
        np.testing.assert_array_equal(es2.energy, np.ones(4))

    def test_replace_validates_dyn_tree_leaves(self):
        es = self._state(4)
        dyn = {"battery": np.ones(4), "up": np.ones(4, bool)}
        es2 = es.replace(dyn=dyn)
        assert es2.dyn is dyn
        with pytest.raises(ValueError, match="dyn"):
            es.replace(dyn={"battery": np.ones(3)})

    def test_dyn_scalar_leaves_are_allowed(self):
        """Run-constant scalars inside the dynamics pytree (0-d leaves)
        are not per-user arrays and must pass."""
        es = self._state(4)
        es2 = es.replace(dyn={"battery": np.ones(4),
                              "threshold": np.float64(0.2)})
        assert es2.dyn["threshold"] == 0.2

    def test_init_validates_dynamics_state(self):
        from repro.core.dynamics import MarkovChurnDynamics

        class _Broken(MarkovChurnDynamics):
            name = "broken-shape-test"

            def init_state(self, n, cfg, fleet=None):
                state = super().init_state(n, cfg, fleet)
                state["battery"] = state["battery"][:-1]
                return state

        cfg = SimConfig(policy="online", n_users=4)
        with pytest.raises(ValueError, match="dyn"):
            EngineState.init(4, cfg, resolve_policy("online"),
                             dynamics=_Broken())


class TestPushLog:
    def test_empty_equals_empty_list(self):
        log = PushLog()
        assert log == []
        assert len(log) == 0 and not log
        assert list(log) == []

    def test_append_and_decode_python_scalars(self):
        log = PushLog()
        log.append(5, 2, 1, 0.25, True, 0.5)
        assert len(log) == 1
        e = log[0]
        assert e == {"t": 5, "user": 2, "lag": 1, "gap": 0.25,
                     "corun": True, "weight": 0.5}
        # digests/reprs depend on python scalar types, not numpy ones
        assert type(e["t"]) is int and type(e["gap"]) is float
        assert type(e["corun"]) is bool
        assert type(e["weight"]) is float
        # weight defaults to the replace rule's full-weight push
        log.append(6, 0, 0, 0.0, False)
        assert log[1]["weight"] == 1.0

    def test_extend_block(self):
        log = PushLog()
        log.extend(7, np.array([3, 1]), np.array([0, 2]),
                   np.array([0.5, 0.75]), np.array([True, False]))
        assert [e["user"] for e in log] == [3, 1]
        assert [e["t"] for e in log] == [7, 7]
        np.testing.assert_array_equal(log.field("lag"), [0, 2])

    def test_extend_rows_matches_event_fields_order(self):
        log = PushLog()
        rows = np.array([[4.0, 9.0, 2.0, 0.125, 1.0, 0.75],
                         [4.0, 11.0, 3.0, 0.5, 0.0, 1.0]])
        log.extend_rows(rows)
        assert log[0] == {"t": 4, "user": 9, "lag": 2, "gap": 0.125,
                          "corun": True, "weight": 0.75}
        assert log[1]["corun"] is False
        assert tuple(EVENT_FIELDS) == ("t", "user", "lag", "gap", "corun",
                                       "weight")

    def test_mixed_parts_preserve_order(self):
        log = PushLog()
        log.append(1, 0, 0, 0.0, False)
        log.extend(2, np.array([5]), np.array([1]), np.array([0.1]),
                   np.array([True]))
        log.append(3, 4, 2, 0.2, True)
        assert [e["t"] for e in log] == [1, 2, 3]

    def test_negative_index_and_slice(self):
        log = PushLog()
        for t in range(4):
            log.append(t, t, 0, 0.0, False)
        assert log[-1]["t"] == 3
        assert [e["t"] for e in log[1:3]] == [1, 2]
        with pytest.raises(IndexError):
            log[4]

    def test_equality_with_dict_list(self):
        log = PushLog()
        log.append(1, 2, 3, 0.5, False)
        assert log == [{"t": 1, "user": 2, "lag": 3, "gap": 0.5,
                        "corun": False, "weight": 1.0}]
        assert not (log == [])


class TestPushBufferStreaming:
    """The jax engine's chunked event streaming, end to end."""

    @pytest.fixture(autouse=True)
    def _x64(self):
        import jax
        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        yield
        jax.config.update("jax_enable_x64", prev)

    def run(self, **kw):
        kw.setdefault("policy", "immediate")
        kw.setdefault("n_users", 8)
        kw.setdefault("horizon_s", 900)
        kw.setdefault("seed", 3)
        kw.setdefault("app_arrival_p", 0.01)
        return FederatedSim(SimConfig(engine="jax", **kw)).run()

    def test_chunking_invariance(self):
        a = self.run(jax_chunk=50)
        b = self.run(jax_chunk=10 ** 6)
        assert a.energy_j == b.energy_j
        assert a.updates == b.updates
        assert list(a.push_log) == list(b.push_log)
        np.testing.assert_array_equal(a.trace_Q, b.trace_Q)

    def test_overflow_retry_is_lossless(self):
        """A deliberately tiny initial buffer must overflow, double and
        retry without losing or duplicating events."""
        small = self.run(push_log_capacity=2)
        big = self.run(push_log_capacity=4096)
        assert len(small.push_log) == len(big.push_log) > 0
        assert list(small.push_log) == list(big.push_log)

    def test_event_count_is_exact_under_overflow(self):
        r = self.run(push_log_capacity=1, jax_chunk=64)
        assert len(r.push_log) == r.updates

    def test_push_buffer_is_pytree(self):
        import jax
        import jax.numpy as jnp

        buf = PushBuffer(jnp.zeros((4, 6)), jnp.asarray(0))
        leaves, treedef = jax.tree.flatten(buf)
        assert len(leaves) == 2
        buf2 = jax.tree.unflatten(treedef, leaves)
        assert isinstance(buf2, PushBuffer)


class TestConfigKnobs:
    def test_jax_chunk_validation(self):
        with pytest.raises(ValueError, match="jax_chunk"):
            SimConfig(jax_chunk=-1)
        # 0 is the auto-tune sentinel (core/autotune.py), not an error
        assert SimConfig(jax_chunk=0).jax_chunk == 0

    def test_push_log_capacity_validation(self):
        with pytest.raises(ValueError, match="push_log_capacity"):
            SimConfig(push_log_capacity=-1)

    def test_flag_without_hook_rejected_at_construction(self):
        """supports_jax without scan_step must fail at SimConfig
        construction with a clear message, not NotImplementedError
        mid-run (the historical failure mode)."""
        from repro.core.policies import Policy

        class _Liar(Policy):
            name = "liar-test"
            supports_vectorized = True
            supports_jax = True

            def decide_loop(self, sim, t, waiting, carry):
                return 0, 0.0

            def decide_vectorized(self, eng, t, carry):
                return 0, 0.0

        with pytest.raises(ValueError, match="scan_step"):
            SimConfig(policy=_Liar(), engine="jax")
        # the mismatch is a property of the policy, not of the requested
        # engine: auto (which dispatches on the flags) must reject it too
        with pytest.raises(ValueError, match="scan_step"):
            SimConfig(policy=_Liar())

    def test_vectorized_flag_without_hook_rejected(self):
        from repro.core.policies import Policy

        class _NoVec(Policy):
            name = "novec-test"
            supports_vectorized = True

            def decide_loop(self, sim, t, waiting, carry):
                return 0, 0.0

        for engine in ("vectorized", "auto", "loop"):
            with pytest.raises(ValueError, match="decide_vectorized"):
                SimConfig(policy=_NoVec(), engine=engine)

    def test_ad_hoc_instance_state_never_shares_compiled_scan(self):
        """A custom policy whose scan_step reads an instance attribute
        directly (no scan_operands) must be instance-keyed: two instances
        with different knobs may not share one baked-in executable."""
        import jax

        from repro.core.policies import Policy

        class _Lazy(Policy):
            name = "lazy-key-test"
            supports_vectorized = True
            supports_jax = True

            def __init__(self, go):
                self.go = go

            def decide_loop(self, sim, t, waiting, carry):
                return 0, 0.0

            def decide_vectorized(self, eng, t, carry):
                return 0, 0.0

            def scan_step(self, carry, sv):
                jnp = sv.jnp
                start = sv.waiting if self.go else \
                    jnp.zeros(sv.n, dtype=bool)
                return carry, (start, jnp.asarray(0.0, sv.float_dtype))

        assert _Lazy(True).jax_cache_key() != _Lazy(False).jax_cache_key()
        # registry policies stay class-keyed (paramless, or knobs routed
        # through scan_operands)
        from repro.core import GreedyThresholdPolicy, OnlinePolicy
        assert OnlinePolicy().jax_cache_key() is type(OnlinePolicy())
        assert GreedyThresholdPolicy(0.1).jax_cache_key() is \
            GreedyThresholdPolicy(0.9).jax_cache_key()

        prev = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            kw = dict(n_users=4, horizon_s=900, engine="jax", seed=0,
                      collect_push_log=False)
            a = FederatedSim(SimConfig(policy=_Lazy(True), **kw)).run()
            b = FederatedSim(SimConfig(policy=_Lazy(False), **kw)).run()
        finally:
            jax.config.update("jax_enable_x64", prev)
        assert a.updates > 0 and b.updates == 0

    def test_nan_arrival_rate_rejected(self):
        with pytest.raises(ValueError, match="\\[0, 1\\]"):
            SimConfig(app_arrival_p=float("nan"))
        with pytest.raises(ValueError, match="\\[0, 1\\]"):
            SimConfig(n_users=2, app_arrival_p=[0.1, float("nan")])
        from repro.core.arrivals import BernoulliArrivals
        with pytest.raises(ValueError, match="\\[0, 1\\]"):
            BernoulliArrivals(float("nan"))
