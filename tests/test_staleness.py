"""Staleness metrics: Eq. (1)-(4) + Def. 1 lag tracking."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.staleness import (LagTracker, gradient_gap, momentum_scale,
                                  predict_weights, tree_l2_norm, true_gap)


class TestMomentumScale:
    @given(st.integers(0, 200), st.floats(1e-4, 1.0),
           st.floats(0.0, 0.99))
    @settings(max_examples=200, deadline=None)
    def test_nonnegative_and_bounded(self, lag, eta, beta):
        s = momentum_scale(lag, eta, beta)
        assert s >= 0.0
        # geometric series bound: eta * (1 - b^l)/(1 - b) <= eta/(1-b)
        if beta < 1.0:
            assert s <= eta / (1.0 - beta) + 1e-9

    @given(st.floats(1e-4, 1.0), st.floats(0.01, 0.99))
    @settings(max_examples=100, deadline=None)
    def test_zero_lag_zero_scale(self, eta, beta):
        assert momentum_scale(0, eta, beta) == pytest.approx(0.0)

    @given(st.integers(1, 100), st.floats(1e-3, 0.5), st.floats(0.01, 0.99))
    @settings(max_examples=100, deadline=None)
    def test_monotone_in_lag(self, lag, eta, beta):
        assert momentum_scale(lag + 1, eta, beta) >= \
            momentum_scale(lag, eta, beta)

    def test_beta_zero(self):
        # no momentum: one update moves by eta * v exactly
        assert momentum_scale(1, 0.1, 0.0) == pytest.approx(0.1)
        assert momentum_scale(5, 0.1, 0.0) == pytest.approx(0.1)

    def test_closed_form(self):
        eta, beta, lag = 0.01, 0.9, 7
        expected = eta * (1 - beta ** lag) / (1 - beta)
        assert momentum_scale(lag, eta, beta) == pytest.approx(expected)


class TestGradientGap:
    @given(st.floats(0.0, 100.0), st.integers(0, 50))
    @settings(max_examples=100, deadline=None)
    def test_proportional_to_vnorm(self, vnorm, lag):
        g = gradient_gap(vnorm, lag, 0.01, 0.9)
        g2 = gradient_gap(2 * vnorm, lag, 0.01, 0.9)
        assert g2 == pytest.approx(2 * g, rel=1e-6, abs=1e-12)

    def test_lwp_exact_under_momentum_decay_model(self):
        """Eq. (3) models future steps as pure momentum decay
        (v_{t+k} = beta^k v_t, no new gradient): under that dynamics the
        prediction and the Eq. (4) gap are EXACT."""
        eta, beta, lag = 0.05, 0.9, 6
        theta = {"w": jnp.array([1.0, -2.0, 3.0])}
        v = {"w": jnp.array([0.5, 0.25, -1.0])}
        th, vv = theta, v
        for _ in range(lag):
            vv = jax.tree.map(lambda a: beta * a, vv)        # s_t = 0
            th = jax.tree.map(lambda t, m: t - eta * m, th, vv)
        pred = predict_weights(theta, v, lag, eta, beta)
        # LWP sums eta * sum_{k=0}^{l-1} beta^k v_t; decay starts at beta^1
        # in our roll-out, so compare against the paper's convention directly
        lwp_delta = eta * (1 - beta ** lag) / (1 - beta)
        np.testing.assert_allclose(
            np.asarray(pred["w"]),
            np.asarray(theta["w"]) - lwp_delta * np.asarray(v["w"]),
            rtol=1e-6)
        gap_est = gradient_gap(tree_l2_norm(v), lag, eta, beta)
        assert gap_est == pytest.approx(
            lwp_delta * float(tree_l2_norm(v)), rel=1e-5)
        # and the roll-out (beta^1..beta^l) is the same up to one beta factor
        rolled = float(true_gap(theta, th))
        assert rolled == pytest.approx(beta * gap_est, rel=1e-4)

    def test_tree_l2_norm(self):
        t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        assert tree_l2_norm(t) == pytest.approx(5.0)


class TestLagTracker:
    def test_def1_counting(self):
        lt = LagTracker()
        lt.on_pull("i")         # i pulls at version 0
        lt.on_pull("j")
        lt.on_pull("k")
        assert lt.on_push("j") == 0   # no foreign updates yet
        assert lt.on_push("k") == 1   # j landed during k's window
        assert lt.on_push("i") == 2   # paper Fig. 3: l_tau = 2

    def test_sync_has_zero_lag(self):
        lt = LagTracker()
        for r in range(3):
            lt.on_pull("a")
            assert lt.on_push("a") == 0

    def test_unknown_client_lag_zero(self):
        lt = LagTracker()
        assert lt.lag("ghost") == 0
