"""HLO-text analysis unit tests (synthetic snippets + a real compile)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo import (collective_bytes, collective_group_sizes,
                              hbm_bytes, quadratic_traffic, shape_bytes,
                              split_computations)

SYNTH = """\
HloModule test

%add.clone (x.1: f32[], y.1: f32[]) -> f32[] {
  %x.1 = f32[] parameter(0)
  %y.1 = f32[] parameter(1)
  ROOT %add.2 = f32[] add(%x.1, %y.1)
}

%body (p.0: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %p.0 = (s32[], f32[16,128]) parameter(0)
  %iter = s32[] get-tuple-element(%p.0), index=0
  %buf = f32[16,128]{1,0} get-tuple-element(%p.0), index=1
  %ar = f32[16,128]{1,0} all-reduce(%buf), replica_groups=[4,4]<=[16], to_apply=%add.clone
  ROOT %t = (s32[], f32[16,128]) tuple(%iter, %ar)
}

%cond (p.1: (s32[], f32[16,128])) -> pred[] {
  %p.1 = (s32[], f32[16,128]) parameter(0)
  %i = s32[] get-tuple-element(%p.1), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (arg: f32[16,128]) -> f32[16,128] {
  %arg = f32[16,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%arg), replica_groups=[4,4]<=[16], dimensions={0}
  %sl = f32[16,128]{1,0} slice(%ag), slice={[0:16], [0:128]}
  %tup = (s32[], f32[16,128]) tuple(%sl, %sl)
  %w = (s32[], f32[16,128]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[16,128]{1,0} get-tuple-element(%w), index=1
}
"""


class TestShapeBytes:
    def test_simple(self):
        assert shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
        assert shape_bytes("bf16[4]") == 8
        assert shape_bytes("(f32[2], s32[3])") == 8 + 12
        assert shape_bytes("f32[]") == 4
        assert shape_bytes("pred[8]") == 8


class TestCollectives:
    def test_trip_count_weighting(self):
        d = collective_bytes(SYNTH)
        # all-gather once: 64*128*4 = 32768; all-reduce in 7-trip while body:
        # 7 * 16*128*4 = 57344
        assert d["all-gather"] == 64 * 128 * 4
        assert d["all-reduce"] == 7 * 16 * 128 * 4

    def test_group_sizes(self):
        g = collective_group_sizes(SYNTH)
        assert g["all-reduce"] == 4.0
        assert g["all-gather"] == 4.0

    def test_split(self):
        comps, entry = split_computations(SYNTH)
        assert entry == "main"
        assert {"add.clone", "body", "cond", "main"} <= set(comps)


class TestHbmBytes:
    def test_counts_real_ops_skips_free(self):
        b = hbm_bytes(SYNTH)
        # entry: ag (out 32768 + in 8192) + slice (8192+32768) + while body
        # 7x (ar: 8192+8192); tuples/gte/params free
        expected = (32768 + 8192) + (8192 + 32768) + 7 * (8192 + 8192)
        assert b == expected


class TestQuadraticTraffic:
    def test_detects_score_tensors(self):
        hlo = """\
ENTRY %main (a: f32[2,4096,4096]) -> f32[2,4096,4096] {
  %a = f32[2,4096,4096]{2,1,0} parameter(0)
  ROOT %e = f32[2,4096,4096]{2,1,0} exponential(%a)
}
"""
        b = quadratic_traffic(hlo, 2048, (-2, -1))
        assert b == 2 * (2 * 4096 * 4096 * 4)

    def test_ignores_thin_tensors(self):
        hlo = """\
ENTRY %main (a: f32[8192,688]) -> f32[8192,688] {
  %a = f32[8192,688]{1,0} parameter(0)
  ROOT %e = f32[8192,688]{1,0} exponential(%a)
}
"""
        assert quadratic_traffic(hlo, 2048, (-2, -1)) == 0


def test_real_compile_collectives_parse():
    """End-to-end: a psum under a 1-device mesh parses without error."""
    from repro.launch.mesh import make_mesh, set_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    with set_mesh(mesh):
        f = jax.jit(lambda x: x @ x.T,
                    in_shardings=NamedSharding(mesh, P("data", "model")))
        c = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    txt = c.as_text()
    assert hbm_bytes(txt) > 0
    assert isinstance(collective_bytes(txt), dict)
